"""Make `compile.*` importable when pytest runs from the repo root
(e.g. `pytest python/tests/ -q`); the Makefile's `cd python` path works
either way."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
