//! Prometheus-style text exposition + the minimal HTTP plumbing the
//! reactor needs to serve it.
//!
//! [`render_prometheus`] turns a live [`ServerStats`] snapshot (plus
//! per-shard health, for a cluster frontend) into `text/plain;
//! version=0.0.4` exposition: throughput, per-rung fill, reuse
//! counters, queue depth, shard health states, and the latency
//! histogram as cumulative `_bucket{le=…}` series with explicit
//! quantile gauges alongside. Rendering is pure string building over
//! an already-assembled snapshot — the reactor callback that serves
//! `/metrics` takes the state lock only long enough to clone the
//! stats, never across a write.
//!
//! The HTTP half is deliberately tiny: `/metrics` consumers send one
//! `GET` and read to EOF, so [`http_request_complete`] /
//! [`http_request_path`] / [`http_response`] (plus `Connection:
//! close`) are the whole protocol. No keep-alive, no chunking.

use crate::obs::hist::{bucket_upper, LatencyHist};
use crate::serve::router::ServerStats;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Render one scrape. `shard_health` is `(addr, state)` per shard —
/// empty for a single-node service.
pub fn render_prometheus(
    stats: &ServerStats,
    shard_health: &[(String, String)],
) -> String {
    let mut out = String::with_capacity(4096);
    let mut counter = |name: &str, help: &str, v: u64| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
    };
    counter(
        "tqdit_requests_total",
        "Requests accepted by this service.",
        stats.requests,
    );
    counter(
        "tqdit_images_total",
        "Real (non-padding) images delivered.",
        stats.images,
    );
    counter(
        "tqdit_batches_total",
        "Batches dispatched to workers.",
        stats.batches,
    );
    counter(
        "tqdit_padded_slots_total",
        "Padding slots burned to fill dispatched rungs.",
        stats.padded_slots,
    );
    counter(
        "tqdit_failed_requests_total",
        "Requests that received a typed error instead of images.",
        stats.failed_requests,
    );
    counter(
        "tqdit_reuse_hits_total",
        "Sampler steps served from the step-reuse cache.",
        stats.reuse_hits,
    );
    counter(
        "tqdit_steps_skipped_total",
        "Forward passes the reuse policy skipped.",
        stats.steps_skipped,
    );
    counter(
        "tqdit_uploads_saved_total",
        "Host-to-device uploads avoided by the resident trajectory.",
        stats.uploads_saved,
    );
    counter(
        "tqdit_requeued_total",
        "Requests re-queued onto a surviving shard after node loss.",
        stats.requeued,
    );
    counter(
        "tqdit_nodes_lost_total",
        "Shard nodes declared dead.",
        stats.nodes_lost,
    );
    counter(
        "tqdit_nodes_readmitted_total",
        "Recovered shard nodes re-admitted into placement.",
        stats.nodes_readmitted,
    );

    let mut gauge = |name: &str, help: &str, v: f64| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {v}");
    };
    gauge(
        "tqdit_queue_depth",
        "Image slots queued but not yet computing.",
        stats.pending as f64,
    );
    gauge(
        "tqdit_throughput_img_per_s",
        "Lifetime images per second of wall clock.",
        stats.throughput(),
    );
    gauge(
        "tqdit_batch_fill",
        "Mean per-dispatch fill, normalized per rung.",
        stats.batch_fill,
    );

    let _ = writeln!(
        out,
        "# HELP tqdit_rung_fill Mean fill of each ladder rung's \
         dispatches."
    );
    let _ = writeln!(out, "# TYPE tqdit_rung_fill gauge");
    for r in &stats.rungs {
        let _ = writeln!(
            out,
            "tqdit_rung_fill{{rung=\"{}\"}} {}",
            r.rung,
            r.fill()
        );
    }
    let _ = writeln!(
        out,
        "# HELP tqdit_rung_batches_total Batches dispatched per \
         ladder rung."
    );
    let _ = writeln!(out, "# TYPE tqdit_rung_batches_total counter");
    for r in &stats.rungs {
        let _ = writeln!(
            out,
            "tqdit_rung_batches_total{{rung=\"{}\"}} {}",
            r.rung, r.batches
        );
    }

    let _ = writeln!(
        out,
        "# HELP tqdit_shard_state Shard health (1 = in the labelled \
         state)."
    );
    let _ = writeln!(out, "# TYPE tqdit_shard_state gauge");
    for (addr, state) in shard_health {
        let _ = writeln!(
            out,
            "tqdit_shard_state{{shard=\"{addr}\",state=\"{state}\"}} 1"
        );
    }

    render_latency(&mut out, &stats.latency);
    out
}

fn render_latency(out: &mut String, hist: &LatencyHist) {
    let name = "tqdit_request_latency_seconds";
    let _ = writeln!(
        out,
        "# HELP {name} Per-request latency (queue + compute)."
    );
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (i, c) in hist.nonzero_buckets() {
        cum += c;
        let _ = writeln!(
            out,
            "{name}_bucket{{le=\"{}\"}} {cum}",
            bucket_upper(i)
        );
    }
    let _ =
        writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", hist.count());
    let _ = writeln!(out, "{name}_sum {}", hist.sum_s());
    let _ = writeln!(out, "{name}_count {}", hist.count());
    let qname = "tqdit_request_latency_quantile_seconds";
    let _ = writeln!(
        out,
        "# HELP {qname} Latency quantiles from the live histogram."
    );
    let _ = writeln!(out, "# TYPE {qname} gauge");
    for q in [0.5, 0.95, 0.99] {
        let _ = writeln!(
            out,
            "{qname}{{q=\"{q}\"}} {}",
            hist.quantile(q)
        );
    }
}

/// Parse an exposition body into `name{labels} → value` (comments
/// skipped, malformed lines dropped). The smoke tests use this to
/// assert required series exist *and* parse.
pub fn parse_exposition(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((key, val)) = line.rsplit_once(' ') else {
            continue;
        };
        if let Ok(v) = val.parse::<f64>() {
            out.insert(key.to_string(), v);
        }
    }
    out
}

// -- HTTP glue -------------------------------------------------------------

/// Has a full request head arrived? (`/metrics` requests have no
/// body, so the blank line ends them.)
pub fn http_request_complete(buf: &[u8]) -> bool {
    buf.windows(4).any(|w| w == b"\r\n\r\n")
}

/// Path of a `GET` request line (`None` for anything else — the
/// caller answers 405/400 and closes).
pub fn http_request_path(buf: &[u8]) -> Option<String> {
    let text = std::str::from_utf8(buf).ok()?;
    let line = text.lines().next()?;
    let mut parts = line.split_whitespace();
    if parts.next()? != "GET" {
        return None;
    }
    let path = parts.next()?;
    parts.next()?; // HTTP version must be present
    Some(path.to_string())
}

/// Build a complete `Connection: close` HTTP/1.1 response.
pub fn http_response(
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
) -> Vec<u8> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\n\
         Content-Type: {content_type}\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    );
    let mut out = head.into_bytes();
    out.extend_from_slice(body);
    out
}

/// The exposition content type scrapers expect.
pub const EXPOSITION_CONTENT_TYPE: &str =
    "text/plain; version=0.0.4; charset=utf-8";

/// Answer one parsed request against a rendered exposition body.
pub fn respond(path: Option<&str>, exposition: &str) -> Vec<u8> {
    match path {
        Some("/metrics") | Some("/") => http_response(
            200,
            "OK",
            EXPOSITION_CONTENT_TYPE,
            exposition.as_bytes(),
        ),
        Some(_) => {
            http_response(404, "Not Found", "text/plain", b"not found\n")
        }
        None => http_response(
            400,
            "Bad Request",
            "text/plain",
            b"only GET is served here\n",
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stats() -> ServerStats {
        let mut s = ServerStats {
            requests: 10,
            images: 40,
            batches: 6,
            batch_fill: 0.8,
            padded_slots: 8,
            pending: 3,
            wall_s: 2.0,
            reuse_hits: 17,
            ..ServerStats::default()
        };
        s.rungs.push(crate::serve::router::RungStats {
            rung: 8,
            batches: 6,
            images: 40,
            padded_slots: 8,
            busy_s: 1.0,
        });
        for v in [0.01, 0.02, 0.02, 0.5] {
            s.latency.record(v);
        }
        s
    }

    #[test]
    fn exposition_has_required_series() {
        let health =
            vec![("127.0.0.1:7001".to_string(), "alive".to_string())];
        let text = render_prometheus(&sample_stats(), &health);
        let series = parse_exposition(&text);
        assert_eq!(series.get("tqdit_images_total"), Some(&40.0));
        assert_eq!(series.get("tqdit_queue_depth"), Some(&3.0));
        assert_eq!(series.get("tqdit_reuse_hits_total"), Some(&17.0));
        assert_eq!(
            series.get("tqdit_throughput_img_per_s"),
            Some(&20.0)
        );
        assert_eq!(
            series.get("tqdit_rung_fill{rung=\"8\"}"),
            Some(&(40.0 / 48.0))
        );
        assert_eq!(
            series.get(
                "tqdit_shard_state{shard=\"127.0.0.1:7001\",\
                 state=\"alive\"}"
            ),
            Some(&1.0)
        );
        assert_eq!(
            series
                .get("tqdit_request_latency_seconds_bucket{le=\"+Inf\"}"),
            Some(&4.0)
        );
        assert_eq!(
            series.get("tqdit_request_latency_seconds_count"),
            Some(&4.0)
        );
        let p95 = series
            .get("tqdit_request_latency_quantile_seconds{q=\"0.95\"}")
            .copied()
            .expect("p95 gauge");
        assert!((p95 - 0.5).abs() / 0.5 < 0.06, "p95 {p95}");
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_monotone() {
        let text = render_prometheus(&sample_stats(), &[]);
        let mut last = 0.0;
        let mut seen = 0;
        for (k, v) in parse_exposition(&text) {
            if k.starts_with("tqdit_request_latency_seconds_bucket") {
                // BTreeMap order is lexicographic, not numeric le
                // order — just check every bucket is a sane count.
                assert!(v >= 0.0 && v <= 4.0, "{k} {v}");
                last = v.max(last);
                seen += 1;
            }
        }
        assert!(seen >= 3, "expected several emitted buckets");
        assert_eq!(last, 4.0, "+Inf bucket must equal count");
    }

    #[test]
    fn http_request_parsing() {
        assert!(!http_request_complete(b"GET /metrics HTTP/1.1\r\n"));
        let full = b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n";
        assert!(http_request_complete(full));
        assert_eq!(
            http_request_path(full).as_deref(),
            Some("/metrics")
        );
        assert_eq!(http_request_path(b"POST / HTTP/1.1\r\n\r\n"), None);
        assert_eq!(http_request_path(b"\xff\xfe\r\n\r\n"), None);
    }

    #[test]
    fn responses_are_well_formed() {
        let body = render_prometheus(&sample_stats(), &[]);
        let resp = respond(Some("/metrics"), &body);
        let text = String::from_utf8_lossy(&resp);
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Connection: close"));
        assert!(
            text.contains(&format!("Content-Length: {}", body.len()))
        );
        assert!(text.ends_with(body.as_str()));
        let nf = respond(Some("/nope"), &body);
        assert!(String::from_utf8_lossy(&nf)
            .starts_with("HTTP/1.1 404"));
        let bad = respond(None, &body);
        assert!(String::from_utf8_lossy(&bad)
            .starts_with("HTTP/1.1 400"));
    }
}
