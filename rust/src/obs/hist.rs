//! Mergeable log-linear latency histogram.
//!
//! Replaces the bounded `push_latency` sample ring: a fixed array of
//! geometrically spaced buckets over `[1µs, ~18h]` whose merge is
//! plain element-wise addition — exactly commutative and associative —
//! so per-worker, per-shard, and per-epoch histograms fold into one
//! without the max-of-p95 distortion the old `ServerStats::absorb`
//! had. Quantiles come from a cumulative rank walk and are accurate
//! to one bucket (relative error ≤ `GROWTH − 1` ≈ 5%), clamped to the
//! observed min/max so tiny samples stay exact at the extremes.
//!
//! The struct is pure data (no atomics, no locks): writers own their
//! histogram and hand copies/deltas across threads the same way the
//! rest of `ServerStats` moves. Counters-style wire deltas subtract
//! per bucket ([`LatencyHist::delta_since`]) and re-accumulate with
//! [`LatencyHist::merge`] on the folding side.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Lower edge of bucket 0, in seconds (1µs).
const MIN_S: f64 = 1e-6;
/// Geometric bucket growth factor.
const GROWTH: f64 = 1.05;
/// Bucket count: covers up to `MIN_S * GROWTH^512` ≈ 6.9e4 s.
const NUM_BUCKETS: usize = 512;

/// Worst-case relative quantile error: a value is reported as its
/// bucket's geometric midpoint, off by at most `sqrt(GROWTH) − 1`
/// from either edge; `GROWTH − 1` gives comfortable slack.
pub const QUANTILE_REL_ERROR: f64 = GROWTH - 1.0;

/// Fixed-capacity log-linear histogram of latencies in seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyHist {
    buckets: Vec<u64>,
    count: u64,
    sum_s: f64,
    min_s: f64,
    max_s: f64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist {
            buckets: vec![0; NUM_BUCKETS],
            count: 0,
            sum_s: 0.0,
            min_s: f64::INFINITY,
            max_s: 0.0,
        }
    }
}

fn bucket_index(v: f64) -> usize {
    if !(v > MIN_S) {
        return 0;
    }
    let idx = (v / MIN_S).ln() / GROWTH.ln();
    if idx >= (NUM_BUCKETS - 1) as f64 {
        NUM_BUCKETS - 1
    } else {
        idx as usize
    }
}

/// Geometric midpoint of bucket `i` — the value a quantile landing in
/// that bucket reports.
fn bucket_mid(i: usize) -> f64 {
    MIN_S * GROWTH.powf(i as f64 + 0.5)
}

/// Exclusive upper edge of bucket `i` (Prometheus `le` label).
pub fn bucket_upper(i: usize) -> f64 {
    MIN_S * GROWTH.powf(i as f64 + 1.0)
}

impl LatencyHist {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation (seconds). Non-finite or negative
    /// values are dropped rather than poisoning the sums.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum_s += v;
        if v < self.min_s {
            self.min_s = v;
        }
        if v > self.max_s {
            self.max_s = v;
        }
    }

    /// Fold `other` into `self` — element-wise bucket addition, so
    /// merge order can never change the result.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b = b.saturating_add(*o);
        }
        self.count = self.count.saturating_add(other.count);
        self.sum_s += other.sum_s;
        if other.min_s < self.min_s {
            self.min_s = other.min_s;
        }
        if other.max_s > self.max_s {
            self.max_s = other.max_s;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_s(&self) -> f64 {
        self.sum_s
    }

    pub fn min_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min_s
        }
    }

    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    /// Quantile estimate for `q ∈ [0, 1]`: walk the cumulative counts
    /// to the bucket holding rank `ceil(q·count)` and report its
    /// geometric midpoint, clamped to the observed `[min, max]`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen = seen.saturating_add(c);
            if seen >= rank {
                return bucket_mid(i).clamp(self.min_s, self.max_s);
            }
        }
        self.max_s
    }

    /// Non-empty buckets as `(index, count)` pairs — the sparse form
    /// used on the wire and in the Prometheus exposition.
    pub fn nonzero_buckets(
        &self,
    ) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
    }

    /// Counter-style delta: per-bucket `cur − prev` (saturating, so a
    /// restarted node that reset its counts yields its full current
    /// histogram rather than garbage), with min/max carried as the
    /// current absolutes — a later [`merge`](Self::merge) folds them
    /// with `min`/`max`, which is correct for gauges.
    pub fn delta_since(&self, prev: &LatencyHist) -> LatencyHist {
        let mut out = LatencyHist::new();
        let mut count = 0u64;
        for (i, (&cur, &old)) in
            self.buckets.iter().zip(prev.buckets.iter()).enumerate()
        {
            let d = cur.saturating_sub(old);
            out.buckets[i] = d;
            count = count.saturating_add(d);
        }
        out.count = count;
        out.sum_s = (self.sum_s - prev.sum_s).max(0.0);
        out.min_s = if self.count == 0 {
            f64::INFINITY
        } else {
            self.min_s
        };
        out.max_s = self.max_s;
        out
    }

    // -- wire form --------------------------------------------------------

    /// Sparse JSON form: `{"n":…,"sum":…,"min":…,"max":…,"b":[[i,c],…]}`.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("n".to_string(), Json::Num(self.count as f64));
        m.insert("sum".to_string(), Json::Num(self.sum_s));
        m.insert("min".to_string(), Json::Num(self.min_s()));
        m.insert("max".to_string(), Json::Num(self.max_s));
        let pairs = self
            .nonzero_buckets()
            .map(|(i, c)| {
                Json::Arr(vec![
                    Json::Num(i as f64),
                    Json::Num(c as f64),
                ])
            })
            .collect();
        m.insert("b".to_string(), Json::Arr(pairs));
        Json::Obj(m)
    }

    /// Parse the sparse form; malformed or missing fields degrade to
    /// an empty histogram (old peers simply don't send one).
    pub fn from_json(v: &Json) -> LatencyHist {
        let mut out = LatencyHist::new();
        let n = v.get("n").and_then(Json::as_f64).unwrap_or(0.0);
        if n <= 0.0 {
            return out;
        }
        out.count = n as u64;
        out.sum_s =
            v.get("sum").and_then(Json::as_f64).unwrap_or(0.0).max(0.0);
        // a non-empty histogram's min stays a plain number (0.0 is a
        // legal observation) — restoring the empty-state INFINITY here
        // would put min above max and panic the quantile clamp
        out.min_s =
            v.get("min").and_then(Json::as_f64).unwrap_or(0.0).max(0.0);
        out.max_s = v
            .get("max")
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
            .max(out.min_s);
        if let Some(pairs) = v.get("b").and_then(Json::as_arr) {
            for pair in pairs {
                let items = match pair.as_arr() {
                    Some(items) if items.len() == 2 => items,
                    _ => continue,
                };
                let i = items[0].as_f64().unwrap_or(-1.0);
                let c = items[1].as_f64().unwrap_or(0.0);
                if i >= 0.0 && (i as usize) < NUM_BUCKETS && c > 0.0 {
                    out.buckets[i as usize] = c as u64;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    fn hist_of(vals: &[f64]) -> LatencyHist {
        let mut h = LatencyHist::new();
        for &v in vals {
            h.record(v);
        }
        h
    }

    #[test]
    fn empty_is_zeroes() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.min_s(), 0.0);
        assert_eq!(h.max_s(), 0.0);
        assert_eq!(h.mean_s(), 0.0);
    }

    #[test]
    fn single_value_is_exact_at_every_quantile() {
        let h = hist_of(&[0.125]);
        for q in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(h.quantile(q), 0.125);
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn rejects_garbage_observations() {
        let h = hist_of(&[f64::NAN, f64::INFINITY, -1.0]);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn quantile_error_bounded_vs_exact_sort() {
        check("hist_quantile_error", 50, |g| {
            let n = g.usize_in(1, 400);
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                // spread over ~5 decades
                let exp = g.f32_in(-4.0, 1.0) as f64;
                vals.push(10f64.powf(exp));
            }
            let h = hist_of(&vals);
            let mut sorted = vals.clone();
            sorted.sort_by(|a, b| a.total_cmp(b));
            for q in [0.5, 0.9, 0.95, 0.99] {
                let rank =
                    (((q * n as f64).ceil() as usize).max(1)) - 1;
                let exact = sorted[rank];
                let est = h.quantile(q);
                let rel = (est - exact).abs() / exact;
                if rel > QUANTILE_REL_ERROR {
                    return Err(format!(
                        "q{q}: est {est} vs exact {exact} (rel {rel})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn merge_commutative_and_associative() {
        check("hist_merge_algebra", 60, |g| {
            let mut parts = Vec::new();
            for _ in 0..3 {
                let n = g.usize_in(0, 60);
                let mut vals = Vec::with_capacity(n);
                for _ in 0..n {
                    vals.push(g.f32_in(1e-5, 30.0) as f64);
                }
                parts.push(hist_of(&vals));
            }
            let (a, b, c) = (&parts[0], &parts[1], &parts[2]);
            // a ⊕ b == b ⊕ a
            let mut ab = a.clone();
            ab.merge(b);
            let mut ba = b.clone();
            ba.merge(a);
            if ab != ba {
                return Err("merge is not commutative".into());
            }
            // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c), up to float sum order
            let mut abc = ab.clone();
            abc.merge(c);
            let mut bc = b.clone();
            bc.merge(c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            if abc.count() != a_bc.count()
                || abc.buckets != a_bc.buckets
                || (abc.sum_s - a_bc.sum_s).abs()
                    > 1e-9 * abc.sum_s.max(1.0)
            {
                return Err("merge is not associative".into());
            }
            Ok(())
        });
    }

    #[test]
    fn merged_quantile_beats_max_of_parts() {
        // The bug this replaces: absorb took max(p95_a, p95_b). With
        // one fast and one slow shard, the true merged p50 must sit
        // between the two parts, not at either extreme.
        let fast = hist_of(&vec![0.010; 95]);
        let slow = hist_of(&vec![1.0; 5]);
        let mut merged = fast.clone();
        merged.merge(&slow);
        let p50 = merged.quantile(0.5);
        assert!(
            (p50 - 0.010).abs() / 0.010 <= QUANTILE_REL_ERROR,
            "p50 {p50} should track the fast majority"
        );
        let p99 = merged.quantile(0.99);
        assert!(
            (p99 - 1.0).abs() / 1.0 <= QUANTILE_REL_ERROR,
            "p99 {p99} should see the slow tail"
        );
    }

    #[test]
    fn delta_then_merge_conserves() {
        check("hist_delta_conserves", 40, |g| {
            // Simulate the node-push cycle: cumulative histogram on
            // the node, periodic deltas folded on the frontend.
            let mut node = LatencyHist::new();
            let mut prev = LatencyHist::new();
            let mut folded = LatencyHist::new();
            for _ in 0..g.usize_in(1, 5) {
                for _ in 0..g.usize_in(0, 40) {
                    node.record(g.f32_in(1e-4, 5.0) as f64);
                }
                let d = node.delta_since(&prev);
                prev = node.clone();
                folded.merge(&d);
            }
            if folded.count() != node.count()
                || folded.buckets != node.buckets
            {
                return Err(format!(
                    "fold lost counts: {} vs {}",
                    folded.count(),
                    node.count()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn delta_after_restart_yields_current_counts() {
        // A restarted node's cumulative counters reset below `prev`;
        // saturating subtraction must hand back its fresh histogram
        // instead of wrapping.
        let before = hist_of(&[0.2, 0.4, 0.6]);
        let after_restart = hist_of(&[0.1]);
        let d = after_restart.delta_since(&before);
        assert_eq!(d.count(), 1);
        assert!((d.quantile(0.5) - 0.1).abs() / 0.1 <= QUANTILE_REL_ERROR);
    }

    #[test]
    fn json_roundtrip() {
        check("hist_json_roundtrip", 30, |g| {
            let n = g.usize_in(0, 80);
            let mut h = LatencyHist::new();
            for _ in 0..n {
                h.record(g.f32_in(1e-5, 60.0) as f64);
            }
            let text = h.to_json().dump();
            let parsed = match Json::parse(&text) {
                Ok(v) => v,
                Err(e) => return Err(format!("reparse failed: {e}")),
            };
            let back = LatencyHist::from_json(&parsed);
            if back.buckets != h.buckets || back.count() != h.count() {
                return Err("bucket roundtrip mismatch".into());
            }
            if (back.sum_s() - h.sum_s()).abs() > 1e-9 {
                return Err("sum roundtrip mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn from_json_tolerates_garbage() {
        for text in
            ["{}", "null", "[1,2]", "{\"n\":3,\"b\":[[9999,1],[-1,2],\"x\"]}"]
        {
            let v = Json::parse(text).unwrap();
            let h = LatencyHist::from_json(&v);
            assert!(h.quantile(0.95).is_finite());
        }
    }

    #[test]
    fn bucket_edges_are_monotone() {
        for i in 1..NUM_BUCKETS {
            assert!(bucket_upper(i) > bucket_upper(i - 1));
        }
        assert!(bucket_upper(NUM_BUCKETS - 1) > 6e4);
    }
}
