//! Request-scoped tracing: trace contexts, a lock-free span ring, and
//! Chrome trace-event export.
//!
//! A [`TraceCtx`] (trace id + parent span id) is minted at `submit`
//! and rides the request through batcher slots, router workers, the
//! wire (`WIRE_TRACE`-negotiated fields on `Submit`/`Response`), and
//! the sampler's per-group step runs. Each stage closes a span with
//! [`record_span`]; finished spans land in a fixed-capacity ring of
//! plain atomics — recording is wait-free (one `fetch_add` + relaxed
//! stores) and collapses to a single load-and-branch when tracing is
//! off, so the hot path never pays for a disabled recorder. There are
//! no mutexes here, hence nothing to register in the lint's
//! `LOCK_RANKS`.
//!
//! Ids are 64-bit and seeded per process from wall clock ⊕ pid, so a
//! frontend can ingest a node's spans verbatim ([`record`]) without
//! collision in practice. Readers ([`snapshot`]) run off the hot path
//! and use a per-slot seqlock (odd = in-flight, even = published) to
//! skip torn slots instead of blocking writers.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Trace context carried by a request: the trace id and the span id
/// that new child spans parent under. `trace == 0` means "untraced".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace: u64,
    pub span: u64,
}

impl TraceCtx {
    pub const NONE: TraceCtx = TraceCtx { trace: 0, span: 0 };

    pub fn is_active(&self) -> bool {
        self.trace != 0
    }

    /// The same trace, re-parented under `span` — what a stage hands
    /// to the stages it encloses.
    pub fn child_of(&self, span: u64) -> TraceCtx {
        TraceCtx { trace: self.trace, span }
    }
}

/// Span stage names. The discriminant is the ring's storage form.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Whole request, minted at `submit` (frontend root).
    Request = 0,
    /// Slot sat in the batcher FIFO before a worker popped it.
    Queue = 1,
    /// Policy held a ready batch back waiting for fill.
    Linger = 2,
    /// Ladder rung selection (`a` = rung, `b` = take).
    RungPick = 3,
    /// One batch forward on a worker (`a` = rung, `b` = batch).
    Generate = 4,
    /// Full quantized transformer steps. `a`/`b` carry the half-open
    /// step-index range `[start, end)` of the sampler run, so a
    /// timeline shows *which* steps each span covered and the reuse
    /// decision per run (this kind = every step dispatched).
    StepsFull = 5,
    /// Reuse-fused closed-form steps — same `[start, end)` step-index
    /// range in `a`/`b`; this kind = the whole run was skipped on
    /// device and applied as one fused host update.
    StepsReuse = 6,
    /// Response copy-out / encode on delivery.
    Encode = 7,
    /// Frontend→node wire hop (cluster dispatch to reply).
    Dispatch = 8,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::Queue => "queue",
            SpanKind::Linger => "linger",
            SpanKind::RungPick => "rung_pick",
            SpanKind::Generate => "generate",
            SpanKind::StepsFull => "steps_full",
            SpanKind::StepsReuse => "steps_reuse",
            SpanKind::Encode => "encode",
            SpanKind::Dispatch => "dispatch",
        }
    }

    fn from_u64(v: u64) -> Option<SpanKind> {
        Some(match v {
            0 => SpanKind::Request,
            1 => SpanKind::Queue,
            2 => SpanKind::Linger,
            3 => SpanKind::RungPick,
            4 => SpanKind::Generate,
            5 => SpanKind::StepsFull,
            6 => SpanKind::StepsReuse,
            7 => SpanKind::Encode,
            8 => SpanKind::Dispatch,
            _ => return None,
        })
    }

    fn from_name(s: &str) -> Option<SpanKind> {
        Some(match s {
            "request" => SpanKind::Request,
            "queue" => SpanKind::Queue,
            "linger" => SpanKind::Linger,
            "rung_pick" => SpanKind::RungPick,
            "generate" => SpanKind::Generate,
            "steps_full" => SpanKind::StepsFull,
            "steps_reuse" => SpanKind::StepsReuse,
            "encode" => SpanKind::Encode,
            "dispatch" => SpanKind::Dispatch,
            _ => return None,
        })
    }
}

/// One finished span. Times are process-monotonic nanoseconds
/// ([`now_ns`]); cross-process spans are re-based by the ingesting
/// side before [`record`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanRec {
    pub trace: u64,
    pub span: u64,
    pub parent: u64,
    pub kind: SpanKind,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Kind-specific detail (rung / TGQ group / shard).
    pub a: u64,
    /// Kind-specific detail (take / run length / bytes).
    pub b: u64,
}

impl SpanRec {
    /// Wire form. Ids go as hex *strings* — they are full 64-bit
    /// values and would be mangled by JSON's f64 numbers.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("tr".into(), Json::Str(format!("{:016x}", self.trace)));
        m.insert("sp".into(), Json::Str(format!("{:016x}", self.span)));
        m.insert(
            "pa".into(),
            Json::Str(format!("{:016x}", self.parent)),
        );
        m.insert("k".into(), Json::Str(self.kind.name().to_string()));
        m.insert("st".into(), Json::Num(self.start_ns as f64));
        m.insert("du".into(), Json::Num(self.dur_ns as f64));
        m.insert("a".into(), Json::Num(self.a as f64));
        m.insert("b".into(), Json::Num(self.b as f64));
        Json::Obj(m)
    }

    /// Parse the wire form; `None` for malformed entries or span
    /// kinds this build doesn't know (forward-compatible skip).
    pub fn from_json(v: &Json) -> Option<SpanRec> {
        let hex = |key: &str| -> Option<u64> {
            u64::from_str_radix(v.get(key)?.as_str()?, 16).ok()
        };
        let num = |key: &str| -> u64 {
            v.get(key)
                .and_then(Json::as_f64)
                .filter(|x| *x >= 0.0)
                .unwrap_or(0.0) as u64
        };
        let kind = SpanKind::from_name(v.get("k")?.as_str()?)?;
        Some(SpanRec {
            trace: hex("tr")?,
            span: hex("sp")?,
            parent: hex("pa").unwrap_or(0),
            kind,
            start_ns: num("st"),
            dur_ns: num("du"),
            a: num("a"),
            b: num("b"),
        })
    }
}

// -- recorder state --------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static RING: OnceLock<SpanRing> = OnceLock::new();
static NEXT_ID: AtomicU64 = AtomicU64::new(1);
static ID_SEED: OnceLock<u64> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Default ring capacity: ~64k spans ≈ a few thousand requests.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Is the recorder on? One relaxed load — the entire cost of tracing
/// when disabled.
#[inline]
pub fn tracing_on() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the recorder on, allocating the ring on first use. Capacity
/// is fixed at whatever the *first* enable call asked for.
pub fn enable(capacity: usize) {
    RING.get_or_init(|| SpanRing::new(capacity.max(16)));
    ENABLED.store(true, Ordering::Release);
}

/// Toggle recording without touching the ring (bench overhead legs
/// flip this between runs).
pub fn set_enabled(on: bool) {
    if on {
        enable(DEFAULT_CAPACITY);
    } else {
        ENABLED.store(false, Ordering::Release);
    }
}

/// Process-monotonic nanoseconds (first call pins the epoch).
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

fn id_seed() -> u64 {
    *ID_SEED.get_or_init(|| {
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5EED);
        splitmix64(nanos ^ ((std::process::id() as u64) << 32))
    })
}

/// A fresh nonzero 64-bit id, unique within the process and seeded
/// per process for cross-process uniqueness in practice.
pub fn next_id() -> u64 {
    let n = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    let id = splitmix64(id_seed().wrapping_add(n));
    if id == 0 {
        1
    } else {
        id
    }
}

/// Mint a root context for a new request: fresh trace id, with the
/// request span itself as the parent for stage spans. Returns
/// [`TraceCtx::NONE`] when tracing is off, which every downstream
/// recording site treats as "skip".
pub fn mint() -> TraceCtx {
    if !tracing_on() {
        return TraceCtx::NONE;
    }
    TraceCtx { trace: next_id(), span: next_id() }
}

/// Close a stage span under `ctx`: mints the span id, records it,
/// and returns the id so callers can parent sub-stages. No-op
/// (returns 0) when untraced or disabled.
pub fn record_span(
    ctx: TraceCtx,
    kind: SpanKind,
    start_ns: u64,
    end_ns: u64,
    a: u64,
    b: u64,
) -> u64 {
    if !ctx.is_active() || !tracing_on() {
        return 0;
    }
    let span = next_id();
    record(SpanRec {
        trace: ctx.trace,
        span,
        parent: ctx.span,
        kind,
        start_ns,
        dur_ns: end_ns.saturating_sub(start_ns),
        a,
        b,
    });
    span
}

/// Record a finished span verbatim (ids already assigned) — the
/// ingest path for spans shipped across the wire.
pub fn record(rec: SpanRec) {
    if !tracing_on() {
        return;
    }
    if let Some(ring) = RING.get() {
        ring.push(rec);
    }
}

/// Copy out every published span, oldest first. Off the hot path —
/// export, tests, and `/metrics`-adjacent debugging only.
pub fn snapshot() -> Vec<SpanRec> {
    let mut out = match RING.get() {
        Some(ring) => ring.read_all(),
        None => Vec::new(),
    };
    out.sort_by_key(|r| (r.start_ns, r.span));
    out
}

/// Published spans belonging to one trace, oldest first.
pub fn spans_for_trace(trace: u64) -> Vec<SpanRec> {
    let mut out = snapshot();
    out.retain(|r| r.trace == trace);
    out
}

// -- ring ------------------------------------------------------------------

/// Per-slot seqlock over plain atomics: `seq == 0` empty, odd while a
/// writer is mid-publish, even (= 2·generation) once readable.
struct Slot {
    seq: AtomicU64,
    trace: AtomicU64,
    span: AtomicU64,
    parent: AtomicU64,
    kind: AtomicU64,
    start: AtomicU64,
    dur: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            trace: AtomicU64::new(0),
            span: AtomicU64::new(0),
            parent: AtomicU64::new(0),
            kind: AtomicU64::new(0),
            start: AtomicU64::new(0),
            dur: AtomicU64::new(0),
            a: AtomicU64::new(0),
            b: AtomicU64::new(0),
        }
    }
}

struct SpanRing {
    slots: Box<[Slot]>,
    cursor: AtomicU64,
}

impl SpanRing {
    fn new(capacity: usize) -> SpanRing {
        let slots: Vec<Slot> =
            (0..capacity).map(|_| Slot::empty()).collect();
        SpanRing {
            slots: slots.into_boxed_slice(),
            cursor: AtomicU64::new(0),
        }
    }

    /// Wait-free: claim a slot by ticket, publish under the seqlock.
    /// Two writers lapping each other on the same slot can interleave;
    /// the reader-side seq check discards such torn slots — acceptable
    /// for a debugging ring, and impossible without wrap pressure.
    fn push(&self, rec: SpanRec) {
        let ticket = self.cursor.fetch_add(1, Ordering::Relaxed);
        let cap = self.slots.len() as u64;
        let slot = &self.slots[(ticket % cap) as usize];
        let generation = ticket / cap + 1;
        slot.seq.store(generation * 2 - 1, Ordering::Release);
        slot.trace.store(rec.trace, Ordering::Relaxed);
        slot.span.store(rec.span, Ordering::Relaxed);
        slot.parent.store(rec.parent, Ordering::Relaxed);
        slot.kind.store(rec.kind as u64, Ordering::Relaxed);
        slot.start.store(rec.start_ns, Ordering::Relaxed);
        slot.dur.store(rec.dur_ns, Ordering::Relaxed);
        slot.a.store(rec.a, Ordering::Relaxed);
        slot.b.store(rec.b, Ordering::Relaxed);
        slot.seq.store(generation * 2, Ordering::Release);
    }

    fn read_all(&self) -> Vec<SpanRec> {
        let mut out = Vec::new();
        for slot in self.slots.iter() {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                continue;
            }
            let rec = SpanRec {
                trace: slot.trace.load(Ordering::Relaxed),
                span: slot.span.load(Ordering::Relaxed),
                parent: slot.parent.load(Ordering::Relaxed),
                kind: match SpanKind::from_u64(
                    slot.kind.load(Ordering::Relaxed),
                ) {
                    Some(k) => k,
                    None => continue,
                },
                start_ns: slot.start.load(Ordering::Relaxed),
                dur_ns: slot.dur.load(Ordering::Relaxed),
                a: slot.a.load(Ordering::Relaxed),
                b: slot.b.load(Ordering::Relaxed),
            };
            let s2 = slot.seq.load(Ordering::Acquire);
            if s1 == s2 {
                out.push(rec);
            }
        }
        out
    }
}

// -- thread-local current context ------------------------------------------

thread_local! {
    static CURRENT: std::cell::Cell<TraceCtx> =
        std::cell::Cell::new(TraceCtx::NONE);
}

/// Install the batch's trace context on this worker thread so layers
/// below the router (the sampler) can record spans without threading
/// a context through `GenBackend::generate`'s signature.
pub fn set_current(ctx: TraceCtx) {
    CURRENT.with(|c| c.set(ctx));
}

/// The trace context installed on this thread (NONE outside a traced
/// batch).
pub fn current() -> TraceCtx {
    CURRENT.with(|c| c.get())
}

/// RAII guard: installs `ctx` for the scope, restores the previous
/// context on drop (worker loops nest cleanly).
pub struct CurrentGuard {
    prev: TraceCtx,
}

impl CurrentGuard {
    pub fn enter(ctx: TraceCtx) -> CurrentGuard {
        let prev = current();
        set_current(ctx);
        CurrentGuard { prev }
    }
}

impl Drop for CurrentGuard {
    fn drop(&mut self) {
        set_current(self.prev);
    }
}

// -- export ----------------------------------------------------------------

/// Render spans as Chrome trace-event JSON (Perfetto / chrome://tracing
/// "X" complete events). Each trace id becomes one `tid` row so a
/// request reads as a single timeline; ids ride along in `args` as
/// hex strings.
pub fn chrome_trace_json(spans: &[SpanRec]) -> String {
    // Stable small tids per trace, in first-seen (time) order.
    let mut tids: BTreeMap<u64, u64> = BTreeMap::new();
    for rec in spans {
        let next = tids.len() as u64 + 1;
        tids.entry(rec.trace).or_insert(next);
    }
    let events: Vec<Json> = spans
        .iter()
        .map(|rec| {
            let mut args = BTreeMap::new();
            args.insert(
                "trace".to_string(),
                Json::Str(format!("{:016x}", rec.trace)),
            );
            args.insert(
                "span".to_string(),
                Json::Str(format!("{:016x}", rec.span)),
            );
            args.insert(
                "parent".to_string(),
                Json::Str(format!("{:016x}", rec.parent)),
            );
            // name the payload for the kinds whose a/b have a fixed
            // meaning, so a Perfetto row is legible without this file
            match rec.kind {
                SpanKind::StepsFull | SpanKind::StepsReuse => {
                    args.insert(
                        "step_start".to_string(),
                        Json::Num(rec.a as f64),
                    );
                    args.insert(
                        "step_end".to_string(),
                        Json::Num(rec.b as f64),
                    );
                    args.insert(
                        "reuse".to_string(),
                        Json::Bool(rec.kind == SpanKind::StepsReuse),
                    );
                }
                _ => {
                    args.insert("a".to_string(), Json::Num(rec.a as f64));
                    args.insert("b".to_string(), Json::Num(rec.b as f64));
                }
            }
            let mut e = BTreeMap::new();
            e.insert(
                "name".to_string(),
                Json::Str(rec.kind.name().to_string()),
            );
            e.insert("cat".to_string(), Json::Str("serve".to_string()));
            e.insert("ph".to_string(), Json::Str("X".to_string()));
            e.insert(
                "ts".to_string(),
                Json::Num(rec.start_ns as f64 / 1_000.0),
            );
            e.insert(
                "dur".to_string(),
                Json::Num((rec.dur_ns as f64 / 1_000.0).max(0.001)),
            );
            e.insert("pid".to_string(), Json::Num(1.0));
            e.insert(
                "tid".to_string(),
                Json::Num(*tids.get(&rec.trace).unwrap_or(&0) as f64),
            );
            e.insert("args".to_string(), Json::Obj(args));
            Json::Obj(e)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(events));
    top.insert(
        "displayTimeUnit".to_string(),
        Json::Str("ms".to_string()),
    );
    Json::Obj(top).dump()
}

/// Dump the whole ring to `path` as Chrome trace JSON (`--trace-json`).
pub fn write_chrome_json(path: &Path) -> std::io::Result<usize> {
    let spans = snapshot();
    std::fs::write(path, chrome_trace_json(&spans))?;
    Ok(spans.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unique_ctx() -> TraceCtx {
        set_enabled(true);
        mint()
    }

    #[test]
    fn disabled_recording_is_inert() {
        // NONE ctx spans never record, whatever the global flag says.
        assert_eq!(
            record_span(TraceCtx::NONE, SpanKind::Queue, 0, 10, 0, 0),
            0
        );
    }

    #[test]
    fn ids_are_nonzero_and_distinct() {
        let a = next_id();
        let b = next_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn spans_stitch_by_trace_and_parent() {
        let ctx = unique_ctx();
        let gen_span = record_span(
            ctx,
            SpanKind::Generate,
            1_000,
            9_000,
            2,
            4,
        );
        assert_ne!(gen_span, 0);
        let child = ctx.child_of(gen_span);
        // a/b on step spans are the run's step-index range
        record_span(child, SpanKind::StepsFull, 1_100, 4_000, 0, 12);
        record_span(child, SpanKind::StepsReuse, 4_000, 4_100, 12, 49);
        let spans = spans_for_trace(ctx.trace);
        assert_eq!(spans.len(), 3);
        let full = spans
            .iter()
            .find(|r| r.kind == SpanKind::StepsFull)
            .expect("steps_full span");
        assert_eq!(full.parent, gen_span);
        assert_eq!(full.b, 12);
        let generate = spans
            .iter()
            .find(|r| r.kind == SpanKind::Generate)
            .expect("generate span");
        assert_eq!(generate.parent, ctx.span);
    }

    #[test]
    fn remote_spans_ingest_verbatim() {
        let ctx = unique_ctx();
        let rec = SpanRec {
            trace: ctx.trace,
            span: 0xABCD,
            parent: ctx.span,
            kind: SpanKind::Encode,
            start_ns: 5,
            dur_ns: 6,
            a: 0,
            b: 1024,
        };
        record(rec);
        let spans = spans_for_trace(ctx.trace);
        assert!(spans.iter().any(|r| *r == rec));
    }

    #[test]
    fn span_json_roundtrip() {
        let rec = SpanRec {
            trace: u64::MAX - 3, // would not survive f64
            span: 1 << 60,
            parent: 7,
            kind: SpanKind::Dispatch,
            start_ns: 123_456_789,
            dur_ns: 42,
            a: 3,
            b: 9,
        };
        let text = rec.to_json().dump();
        let back =
            SpanRec::from_json(&Json::parse(&text).expect("reparse"))
                .expect("decode");
        assert_eq!(back, rec);
    }

    #[test]
    fn malformed_span_json_is_skipped() {
        for text in
            ["{}", "null", "{\"k\":\"warp\"}", "{\"k\":\"queue\"}"]
        {
            let v = Json::parse(text).expect("parse");
            assert!(SpanRec::from_json(&v).is_none(), "{text}");
        }
    }

    #[test]
    fn chrome_export_is_valid_json_with_events() {
        let ctx = unique_ctx();
        record_span(ctx, SpanKind::Queue, 0, 500, 0, 0);
        let spans = spans_for_trace(ctx.trace);
        let text = chrome_trace_json(&spans);
        let v = Json::parse(&text).expect("chrome json parses");
        let events =
            v.get("traceEvents").and_then(Json::as_arr).expect("events");
        assert_eq!(events.len(), spans.len());
        assert_eq!(
            events[0].get("ph").and_then(Json::as_str),
            Some("X")
        );
    }

    #[test]
    fn chrome_step_spans_carry_named_step_range() {
        let ctx = unique_ctx();
        record_span(ctx, SpanKind::StepsReuse, 0, 500, 12, 49);
        record_span(ctx, SpanKind::Queue, 500, 600, 3, 4);
        let spans = spans_for_trace(ctx.trace);
        let v = Json::parse(&chrome_trace_json(&spans)).expect("parses");
        let events =
            v.get("traceEvents").and_then(Json::as_arr).expect("events");
        let args_of = |name: &str| {
            events
                .iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .and_then(|e| e.get("args"))
                .cloned()
                .expect("event args")
        };
        let steps = args_of("steps_reuse");
        assert_eq!(steps.get("step_start").and_then(Json::as_f64), Some(12.0));
        assert_eq!(steps.get("step_end").and_then(Json::as_f64), Some(49.0));
        assert!(matches!(steps.get("reuse"), Some(Json::Bool(true))));
        // other kinds keep the generic payload names
        let queue = args_of("queue");
        assert_eq!(queue.get("a").and_then(Json::as_f64), Some(3.0));
        assert!(queue.get("step_start").is_none());
    }

    #[test]
    fn concurrent_recording_smoke() {
        set_enabled(true);
        let threads: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let ctx = mint();
                    for i in 0..200u64 {
                        record_span(
                            ctx,
                            SpanKind::Queue,
                            i,
                            i + 1,
                            t,
                            i,
                        );
                    }
                    ctx.trace
                })
            })
            .collect();
        for t in threads {
            let trace = t.join().expect("thread");
            assert!(!spans_for_trace(trace).is_empty());
        }
    }

    #[test]
    fn current_guard_nests_and_restores() {
        let outer = TraceCtx { trace: 11, span: 1 };
        let inner = TraceCtx { trace: 22, span: 2 };
        {
            let _a = CurrentGuard::enter(outer);
            assert_eq!(current(), outer);
            {
                let _b = CurrentGuard::enter(inner);
                assert_eq!(current(), inner);
            }
            assert_eq!(current(), outer);
        }
        assert_eq!(current(), TraceCtx::NONE);
    }
}
