//! Observability for the quantized serve stack: request-scoped
//! tracing, mergeable latency histograms, and a live Prometheus-style
//! metrics plane. Std-only, like everything else in this crate.
//!
//! # Why this layer exists
//!
//! The cluster can shard, batch, reuse steps, and survive node death,
//! but end-of-run counters can't answer *where one request's latency
//! went* — queue wait vs. batch linger vs. the quantized forward vs.
//! the reuse-fused host update vs. the wire hop — and cross-shard
//! percentiles used to be merged by `max` over a bounded sample ring,
//! which is statistically wrong. Both the drift-calibration carry-over
//! and quality-tiered serving need per-stage, per-time-group timing to
//! make decisions; this module is the layer they read from.
//!
//! # The three pieces
//!
//! * **[`trace`]** — a [`trace::TraceCtx`] (trace id + span id) is
//!   minted at `submit`, threaded through the batcher's slots, the
//!   router worker, and (via a thread-local) the sampler's per-group
//!   step runs, and propagated across the wire behind `WIRE_TRACE`
//!   negotiation so a clustered request stitches frontend spans
//!   (queue / linger / dispatch) and node spans (rung pick, Full vs.
//!   Reuse step runs, encode) into one timeline keyed by one trace
//!   id. Spans land in a fixed-capacity ring of plain atomics —
//!   recording is wait-free, and a single relaxed load when tracing
//!   is off — and export as Chrome trace-event JSON (`--trace-json`,
//!   viewable in Perfetto).
//! * **[`hist`]** — [`hist::LatencyHist`], a log-linear histogram
//!   whose merge is element-wise addition: per-worker, per-shard, and
//!   per-epoch latency distributions fold exactly (commutative,
//!   associative), fixing the old max-of-p95 `absorb` bug. Quantiles
//!   are bucket-accurate ([`hist::QUANTILE_REL_ERROR`]); deltas
//!   subtract per bucket for the node→frontend stats push.
//! * **[`metrics`]** — renders a [`ServerStats`
//!   ](crate::serve::router::ServerStats) snapshot as Prometheus text
//!   exposition, served by the existing reactor as one more
//!   connection class (`--metrics-addr`) — a plain HTTP `GET
//!   /metrics` answered from the event loop, no extra threads.
//!
//! # Hot-path discipline
//!
//! Nothing here blocks and nothing here locks: the recorder is
//! atomics end to end (the `no-panic-paths` lint covers `obs/` like
//! the rest of the serve stack), histogram recording is an array
//! increment on state the caller already owns, and `/metrics`
//! rendering happens on the reactor thread from a cloned snapshot.

pub mod hist;
pub mod metrics;
pub mod trace;

pub use hist::LatencyHist;
pub use trace::{SpanKind, SpanRec, TraceCtx};
