//! Host tensor substrate: row-major f32 tensors + the ops the
//! coordinator needs (matmul for HO objectives, softmax/GELU mirrors of
//! the kernels, reductions, quant helpers live in [`crate::quant`]).

pub mod linalg;
pub mod stats;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Last-axis length.
    pub fn cols(&self) -> usize {
        *self.shape.last().expect("rank >= 1")
    }

    /// Product of all axes but the last.
    pub fn rows(&self) -> usize {
        self.len() / self.cols()
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    // ---- elementwise -----------------------------------------------------

    pub fn map<F: Fn(f32) -> f32>(&self, f: F) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip<F: Fn(f32, f32) -> f32>(&self, other: &Tensor, f: F) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    // ---- reductions --------------------------------------------------------

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        self.sum() / self.len() as f64
    }

    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Mean squared difference — the raw MSE calibration objective.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / self.len() as f64
    }

    // ---- linear algebra -----------------------------------------------------

    /// 2-D matmul: (m, k) x (k, n) → (m, n).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        matmul_into(&self.data, &other.data, &mut out, m, k, n);
        Tensor::new(vec![m, n], out)
    }

    /// Matmul where `self` is (..., k) flattened to rows: (R, k) x (k, n).
    pub fn matmul_flat(&self, w: &Tensor) -> Tensor {
        let k = self.cols();
        assert_eq!(w.rank(), 2);
        assert_eq!(w.shape[0], k);
        let r = self.rows();
        let n = w.shape[1];
        let mut out = vec![0.0f32; r * n];
        matmul_into(&self.data, &w.data, &mut out, r, k, n);
        let mut shape = self.shape.clone();
        *shape.last_mut().unwrap() = n;
        Tensor::new(shape, out)
    }

    /// 2-D transpose.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }

    /// Row softmax over the last axis.
    pub fn softmax_lastdim(&self) -> Tensor {
        let cols = self.cols();
        let mut out = self.data.clone();
        for row in out.chunks_mut(cols) {
            let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut s = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                s += *v;
            }
            for v in row.iter_mut() {
                *v /= s;
            }
        }
        Tensor { shape: self.shape.clone(), data: out }
    }

    /// tanh-approximated GELU (matches the pallas kernel / jnp oracle).
    pub fn gelu(&self) -> Tensor {
        self.map(gelu_scalar)
    }
}

/// Cache-friendly (ikj-order) matmul kernel shared by the tensor ops.
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

pub fn gelu_scalar(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let i = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_flat_keeps_leading_shape() {
        let x = Tensor::zeros(vec![2, 4, 3]);
        let w = Tensor::zeros(vec![3, 5]);
        assert_eq!(x.matmul_flat(&w).shape, vec![2, 4, 5]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.t().t(), a);
        assert_eq!(a.t().shape, vec![3, 2]);
        assert_eq!(a.t().data, vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::new(vec![2, 4], vec![0.1, 1.0, -2.0, 3.0, 0., 0., 0., 0.]);
        let s = x.softmax_lastdim();
        for row in s.data.chunks(4) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&p| p >= 0.0));
        }
        // uniform logits → uniform probs
        assert!((s.data[4] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let x = Tensor::new(vec![1, 3], vec![1000.0, 1000.0, 1000.0]);
        let s = x.softmax_lastdim();
        assert!(s.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn gelu_known_values() {
        assert!((gelu_scalar(0.0)).abs() < 1e-7);
        assert!((gelu_scalar(100.0) - 100.0).abs() < 1e-3);
        assert!(gelu_scalar(-100.0).abs() < 1e-3);
        // gelu(1) ≈ 0.8412
        assert!((gelu_scalar(1.0) - 0.8412).abs() < 1e-3);
        // minimum region is negative
        assert!(gelu_scalar(-0.5) < 0.0);
    }

    #[test]
    fn mse_and_reductions() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::new(vec![3], vec![1.0, 2.0, 5.0]);
        assert!((a.mse(&b) - 4.0 / 3.0).abs() < 1e-9);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 3.0);
        assert_eq!(b.abs_max(), 5.0);
        assert!((a.mean() - 2.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }
}
