//! Gaussian feature statistics (mean / covariance) + histogram helper.
//!
//! Feeds the FID/sFID metric ([`crate::metrics::fid`]) and the Fig. 2/3
//! distribution reproductions.

/// Online accumulator for mean and covariance of d-dim feature vectors.
#[derive(Clone, Debug)]
pub struct GaussStats {
    pub dim: usize,
    pub count: usize,
    sum: Vec<f64>,
    /// Upper-triangular-inclusive sum of outer products (full d×d kept).
    outer: Vec<f64>,
}

impl GaussStats {
    pub fn new(dim: usize) -> GaussStats {
        GaussStats { dim, count: 0, sum: vec![0.0; dim], outer: vec![0.0; dim * dim] }
    }

    /// Add one feature vector.
    pub fn push(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.dim);
        self.count += 1;
        for i in 0..self.dim {
            self.sum[i] += x[i] as f64;
        }
        for i in 0..self.dim {
            let xi = x[i] as f64;
            let row = &mut self.outer[i * self.dim..(i + 1) * self.dim];
            for (j, slot) in row.iter_mut().enumerate() {
                *slot += xi * x[j] as f64;
            }
        }
    }

    /// Add a batch laid out as (n, dim) row-major.
    pub fn push_batch(&mut self, data: &[f32]) {
        assert_eq!(data.len() % self.dim, 0);
        for row in data.chunks(self.dim) {
            self.push(row);
        }
    }

    pub fn mean(&self) -> Vec<f64> {
        assert!(self.count > 0);
        self.sum.iter().map(|s| s / self.count as f64).collect()
    }

    /// Sample covariance (n−1 denominator, matching `np.cov`).
    pub fn cov(&self) -> Vec<f64> {
        assert!(self.count > 1, "need ≥2 samples for covariance");
        let n = self.count as f64;
        let mu = self.mean();
        let d = self.dim;
        let mut cov = vec![0.0f64; d * d];
        for i in 0..d {
            for j in 0..d {
                let e_xy = self.outer[i * d + j] / n;
                cov[i * d + j] = (e_xy - mu[i] * mu[j]) * n / (n - 1.0);
            }
        }
        cov
    }
}

/// Fixed-range histogram (Fig. 2 reproduction).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub count: u64,
}

impl Histogram {
    pub fn new(lo: f32, hi: f32, nbins: usize) -> Histogram {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0, count: 0 }
    }

    pub fn push(&mut self, x: f32) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let f = (x - self.lo) / (self.hi - self.lo);
            let n = self.bins.len();
            let idx = ((f * n as f32) as usize).min(n - 1);
            self.bins[idx] += 1;
        }
    }

    pub fn push_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.push(x);
        }
    }

    /// Bin centers + normalized densities, as (center, density) rows.
    pub fn densities(&self) -> Vec<(f32, f64)> {
        let w = (self.hi - self.lo) / self.bins.len() as f32;
        let total = self.count.max(1) as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let center = self.lo + w * (i as f32 + 0.5);
                (center, c as f64 / total / w as f64)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_cov_known() {
        let mut s = GaussStats::new(2);
        // points: (0,0), (2,0), (0,2), (2,2) → mean (1,1), cov diag 4/3
        for p in [[0.0, 0.0], [2.0, 0.0], [0.0, 2.0], [2.0, 2.0]] {
            s.push(&p);
        }
        let mu = s.mean();
        assert!((mu[0] - 1.0).abs() < 1e-12 && (mu[1] - 1.0).abs() < 1e-12);
        let cov = s.cov();
        assert!((cov[0] - 4.0 / 3.0).abs() < 1e-9);
        assert!((cov[3] - 4.0 / 3.0).abs() < 1e-9);
        assert!(cov[1].abs() < 1e-9); // independent axes
    }

    #[test]
    fn push_batch_equals_push() {
        let mut a = GaussStats::new(3);
        let mut b = GaussStats::new(3);
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        a.push_batch(&data);
        b.push(&data[0..3]);
        b.push(&data[3..6]);
        assert_eq!(a.mean(), b.mean());
        assert_eq!(a.cov(), b.cov());
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.push_all(&[-0.5, 0.05, 0.15, 0.95, 1.5]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.bins[0], 1);
        assert_eq!(h.bins[1], 1);
        assert_eq!(h.bins[9], 1);
        assert_eq!(h.count, 5);
    }

    #[test]
    fn histogram_density_integrates_to_coverage() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push_all(&[0.1, 0.3, 0.6, 0.9]);
        let total: f64 = h
            .densities()
            .iter()
            .map(|(_, d)| d * 0.25)
            .sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
