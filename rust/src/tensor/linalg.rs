//! Dense symmetric linear algebra for the FID metric.
//!
//! FID needs `tr((Σ₁ Σ₂)^{1/2})`. We compute the principal square root of
//! the symmetrized product via a cyclic Jacobi eigendecomposition
//! (robust, dependency-free, and fast enough for the ≤ 192-dim feature
//! covariances this repo uses).

/// Column-major-agnostic dense symmetric matrix ops on row-major `Vec<f64>`.
pub struct SymEig {
    /// Eigenvalues, ascending order not guaranteed.
    pub values: Vec<f64>,
    /// Row-major eigenvector matrix; column j is the j-th eigenvector.
    pub vectors: Vec<f64>,
    pub n: usize,
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix (row-major n×n).
///
/// Runs sweeps until off-diagonal Frobenius mass < `tol` (relative) or
/// `max_sweeps` is hit. O(n³) per sweep; n ≤ a few hundred here.
pub fn jacobi_eigh(a: &[f64], n: usize, max_sweeps: usize, tol: f64) -> SymEig {
    assert_eq!(a.len(), n * n);
    let mut m = a.to_vec();
    // v = identity
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let norm: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        if (2.0 * off).sqrt() < tol * norm {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q of m
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let values = (0..n).map(|i| m[i * n + i]).collect();
    SymEig { values, vectors: v, n }
}

/// n×n row-major matmul (f64).
pub fn matmul_f64(a: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; n * n];
    for i in 0..n {
        for p in 0..n {
            let av = a[i * n + p];
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                out[i * n + j] += av * b[p * n + j];
            }
        }
    }
    out
}

/// Trace of the principal square root of a symmetric PSD matrix.
///
/// Negative eigenvalues (numerical noise) are clamped to zero.
pub fn trace_sqrt_sym(a: &[f64], n: usize) -> f64 {
    let eig = jacobi_eigh(a, n, 40, 1e-12);
    eig.values.iter().map(|&l| l.max(0.0).sqrt()).sum()
}

/// tr((Σ₁ Σ₂)^{1/2}) for symmetric PSD Σ₁, Σ₂ via the similarity trick:
/// eigenvalues of Σ₁Σ₂ equal those of the symmetric √Σ₁ Σ₂ √Σ₁.
pub fn trace_sqrt_product(sigma1: &[f64], sigma2: &[f64], n: usize) -> f64 {
    // s1 = √Σ₁ via eigendecomposition
    let eig = jacobi_eigh(sigma1, n, 40, 1e-12);
    let mut s1 = vec![0.0f64; n * n];
    // s1 = V diag(sqrt(λ)) Vᵀ
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0;
            for k in 0..n {
                let l = eig.values[k].max(0.0).sqrt();
                acc += eig.vectors[i * n + k] * l * eig.vectors[j * n + k];
            }
            s1[i * n + j] = acc;
        }
    }
    let inner = matmul_f64(&matmul_f64(&s1, sigma2, n), &s1, n);
    // symmetrize against numerical noise
    let mut sym = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            sym[i * n + j] = 0.5 * (inner[i * n + j] + inner[j * n + i]);
        }
    }
    trace_sqrt_sym(&sym, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn eig_diagonal() {
        let a = vec![3.0, 0.0, 0.0, 5.0];
        let e = jacobi_eigh(&a, 2, 30, 1e-14);
        let mut vals = e.values.clone();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(approx(vals[0], 3.0, 1e-12));
        assert!(approx(vals[1], 5.0, 1e-12));
    }

    #[test]
    fn eig_known_2x2() {
        // [[2,1],[1,2]] → eigenvalues 1, 3
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let e = jacobi_eigh(&a, 2, 30, 1e-14);
        let mut vals = e.values.clone();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(approx(vals[0], 1.0, 1e-12));
        assert!(approx(vals[1], 3.0, 1e-12));
    }

    #[test]
    fn eig_reconstructs_matrix() {
        // random symmetric 8x8 from a fixed pattern
        let n = 8;
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let v = ((i * 31 + j * 17) % 13) as f64 / 13.0;
                a[i * n + j] += v;
                a[j * n + i] += v;
            }
        }
        let e = jacobi_eigh(&a, n, 50, 1e-14);
        // A ≈ V diag(λ) Vᵀ
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += e.vectors[i * n + k] * e.values[k] * e.vectors[j * n + k];
                }
                assert!(approx(acc, a[i * n + j], 1e-9), "({i},{j})");
            }
        }
    }

    #[test]
    fn trace_sqrt_of_identity() {
        let n = 5;
        let mut i5 = vec![0.0; n * n];
        for i in 0..n {
            i5[i * n + i] = 1.0;
        }
        assert!(approx(trace_sqrt_sym(&i5, n), n as f64, 1e-12));
    }

    #[test]
    fn trace_sqrt_product_identity_pair() {
        let n = 4;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 4.0; // sqrt(4*4) per axis → ... tr = 4*4? no:
        }
        // Σ₁ = Σ₂ = 4I → (Σ₁Σ₂)^{1/2} = 4I → trace = 16
        assert!(approx(trace_sqrt_product(&a, &a, n), 16.0, 1e-10));
    }

    #[test]
    fn trace_sqrt_product_commutes() {
        // diagonal matrices commute: tr sqrt(D1 D2) = Σ sqrt(d1 d2)
        let n = 3;
        let d1 = vec![1.0, 0., 0., 0., 4.0, 0., 0., 0., 9.0];
        let d2 = vec![9.0, 0., 0., 0., 4.0, 0., 0., 0., 1.0];
        let expect = (9.0f64).sqrt() + 16.0f64.sqrt() + 9.0f64.sqrt();
        assert!(approx(trace_sqrt_product(&d1, &d2, n), expect, 1e-10));
    }
}
