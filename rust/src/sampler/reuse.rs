//! Time-grouped step reuse: the policy deciding, per sampler step,
//! whether to run the model or reuse the group's last ε̂.
//!
//! The paper's TGQ insight — activations vary smoothly *within* a time
//! group — is exploited at inference here: adjacent steps in a
//! low-drift group share one forward pass, and the skipped reverse
//! updates are applied with the scheduler's closed-form composition
//! ([`DdpmSchedule::fused_coeffs`]), so a run of reused steps costs one
//! host update and zero device dispatches.
//!
//! Everything in this module is pure and device-free:
//!
//! * [`drift_from_schedule`] computes the per-group ε-drift proxy the
//!   coordinator records alongside the calibrated `QuantConfig` — the
//!   mean change of the forward-process mixing coefficients
//!   (√ᾱ, √(1−ᾱ)) across adjacent visited steps of each group. It is
//!   the schedule-level upper-bound on how far ε̂ can wander between
//!   two steps the sampler actually takes in that group.
//! * [`ReusePolicy`] turns `drift < δ` (strict — δ=0 never reuses)
//!   into a per-step [`Decision`] plan. Groups further below the
//!   threshold refresh less often (stride 2/4/8), which is the
//!   "per-group step schedule": a group at stride k takes ⌈n/k⌉ full
//!   steps outright. The first visited step of every group is always
//!   `Full`, so a `Reuse` step always has a same-group ε̂ to reuse.
//! * [`simulate`] runs a full trajectory against a caller-supplied
//!   ε̂-closure with *exactly* the control flow, RNG draw order and
//!   fused math of `Sampler::sample` — the device-free reference the
//!   δ=0 byte-equality tests and the CI reuse bench are built on.

use crate::sched::{DdpmSchedule, TimeGroups};
use crate::util::rng::Rng;

use super::SampleStats;

/// Per-step verdict of the [`ReusePolicy`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Run the forward pass at this step.
    Full,
    /// Skip the forward pass; reuse the group's last ε̂ with the
    /// scheduler's closed-form rescaling.
    Reuse,
}

/// A maximal run of consecutive same-decision steps; `Reuse` runs never
/// cross a time-group boundary (the first visited step of every group
/// is `Full` by construction).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Run {
    /// First sampler-step index of the run.
    pub start: usize,
    /// Number of consecutive steps in the run.
    pub len: usize,
    /// `true` for a reuse run, `false` for a single full step.
    pub reuse: bool,
}

/// Step-reuse decision policy: a drift threshold δ applied to the
/// calibrated per-group drift statistics.
#[derive(Clone, Copy, Debug)]
pub struct ReusePolicy {
    /// Drift threshold; a group reuses only while `drift[g] < delta`
    /// (strict), so δ=0 reproduces the no-reuse trajectory exactly.
    pub delta: f64,
}

impl ReusePolicy {
    pub fn new(delta: f64) -> ReusePolicy {
        ReusePolicy { delta }
    }

    /// Refresh stride for one group: how many trajectory steps share a
    /// forward pass. Drift at or above δ never reuses (stride 1);
    /// below δ the stride doubles per halving of drift, capped at 8.
    pub fn stride(&self, drift: f32) -> usize {
        let d = drift as f64;
        if !d.is_finite() || d < 0.0 || !(d < self.delta) {
            1
        } else if d >= self.delta / 2.0 {
            2
        } else if d >= self.delta / 4.0 {
            4
        } else {
            8
        }
    }

    /// Per-step plan over a descending sampler step sequence. Groups
    /// missing a drift entry are treated as maximally drifting (never
    /// reused). Position 0 of every group's visit block is `Full`.
    pub fn plan(&self, steps: &[usize], groups: &TimeGroups,
                drift: &[f32]) -> Vec<Decision> {
        let mut visits = vec![0usize; groups.groups];
        steps
            .iter()
            .map(|&t| {
                let g = groups.group_of(t);
                let s = self.stride(drift.get(g).copied().unwrap_or(1.0));
                let pos = visits[g];
                visits[g] += 1;
                if pos % s == 0 {
                    Decision::Full
                } else {
                    Decision::Reuse
                }
            })
            .collect()
    }

    /// Collapse a plan into maximal runs: each `Full` step is its own
    /// unit-length run; consecutive `Reuse` steps merge (they share
    /// one ε̂ and one fused host update).
    pub fn runs(plan: &[Decision]) -> Vec<Run> {
        let mut out = Vec::new();
        let mut i = 0usize;
        while i < plan.len() {
            match plan[i] {
                Decision::Full => {
                    out.push(Run { start: i, len: 1, reuse: false });
                    i += 1;
                }
                Decision::Reuse => {
                    let mut k = 1usize;
                    while i + k < plan.len()
                        && plan[i + k] == Decision::Reuse
                    {
                        k += 1;
                    }
                    out.push(Run { start: i, len: k, reuse: true });
                    i += k;
                }
            }
        }
        out
    }
}

/// Per-group step schedule derived from a plan: which sampler-step
/// indices run full and which reuse, per time group. The union over
/// groups partitions `0..steps.len()` exactly (the conservation
/// property the tests pin).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GroupSchedule {
    /// Sampler-step indices this group runs the model at.
    pub full: Vec<usize>,
    /// Sampler-step indices this group reuses its last ε̂ at.
    pub reuse: Vec<usize>,
}

/// Split a plan into per-group schedules (index = group).
pub fn per_group_schedule(steps: &[usize], groups: &TimeGroups,
                          plan: &[Decision]) -> Vec<GroupSchedule> {
    let mut out = vec![GroupSchedule::default(); groups.groups];
    for (i, &t) in steps.iter().enumerate() {
        let g = groups.group_of(t);
        match plan.get(i).copied().unwrap_or(Decision::Full) {
            Decision::Full => out[g].full.push(i),
            Decision::Reuse => out[g].reuse.push(i),
        }
    }
    out
}

/// Schedule-derived per-group ε-drift proxy, recorded at calibration
/// time: the mean over adjacent *visited* step pairs (t, t') of
/// |√(1−ᾱ_t) − √(1−ᾱ_t')| + |√ᾱ_t − √ᾱ_t'| — how much the forward
/// process mixing changes between two steps the sampler actually takes
/// inside the group. Groups covering fewer than two visited steps get
/// the sentinel 1.0 (never reused: there is no adjacent pair to share
/// a forward pass across).
pub fn drift_from_schedule(sched: &DdpmSchedule, groups: &TimeGroups)
                           -> Vec<f32> {
    (0..groups.groups)
        .map(|g| {
            let (lo, hi) = groups.range_of(g);
            let visited: Vec<usize> = sched
                .steps
                .iter()
                .copied()
                .filter(|&t| t >= lo && t <= hi)
                .collect();
            if visited.len() < 2 {
                return 1.0;
            }
            let coeff = |t: usize| {
                let ab = sched.train_alpha_bars[t];
                (ab.sqrt(), (1.0 - ab).sqrt())
            };
            let sum: f64 = visited
                .windows(2)
                .map(|w| {
                    let (a0, e0) = coeff(w[0]);
                    let (a1, e1) = coeff(w[1]);
                    (a0 - a1).abs() + (e0 - e1).abs()
                })
                .sum();
            (sum / (visited.len() - 1) as f64) as f32
        })
        .collect()
}

/// Device-free reference trajectory: runs the reuse-aware sampling
/// loop against `eps_of(x, t, g)` in place of the model, with the same
/// decision plan, fused math, RNG draw order and final clamp as
/// `Sampler::sample`. With δ=0 this is byte-identical to the plain
/// per-step loop; the tests and the CI reuse bench both rest on it.
pub fn simulate<F>(sched: &DdpmSchedule, groups: &TimeGroups,
                   drift: &[f32], delta: f64, img_len: usize,
                   rng: &mut Rng, mut eps_of: F)
                   -> (Vec<f32>, SampleStats)
where
    F: FnMut(&[f32], usize, usize) -> Vec<f32>,
{
    let plan = ReusePolicy::new(delta).plan(&sched.steps, groups, drift);
    let runs = ReusePolicy::runs(&plan);
    let mut stats = SampleStats::default();
    let mut x = rng.normal_vec(img_len);
    let mut eps_hat: Vec<f32> = Vec::new();
    let mut eps_group = usize::MAX;
    let n = sched.len();
    for run in &runs {
        let g = groups.group_of(sched.steps[run.start]);
        if run.reuse && eps_group == g && !eps_hat.is_empty() {
            let (a, bc, s) = sched.fused_coeffs(run.start, run.len, 0.0);
            for j in 0..x.len() {
                x[j] = a * x[j] - bc * eps_hat[j];
            }
            if s > 0.0 {
                let z = rng.normal_vec(img_len);
                for j in 0..x.len() {
                    x[j] += s * z[j];
                }
            }
            stats.steps += 1;
            stats.reuse_hits += run.len;
            stats.steps_skipped += run.len;
            stats.uploads_saved += 2 * run.len;
            continue;
        }
        // full step(s); a degraded reuse run (no cached ε̂ — cannot
        // happen under plans from `ReusePolicy::plan`) falls through
        // here and stays exact
        for i in run.start..run.start + run.len {
            eps_hat = eps_of(&x, sched.steps[i], g);
            eps_group = g;
            let (c_x, c_eps, sigma) = sched.step_coeffs(i, 0.0);
            let noise = if i + 1 == n {
                None
            } else {
                Some(rng.normal_vec(img_len))
            };
            for j in 0..x.len() {
                x[j] = c_x * (x[j] - c_eps * eps_hat[j]);
            }
            if let Some(z) = &noise {
                for j in 0..x.len() {
                    x[j] += sigma * z[j];
                }
            }
            stats.steps += 1;
            stats.uploads_saved += 1;
        }
    }
    for v in x.iter_mut() {
        *v = v.clamp(-1.5, 1.5);
    }
    (x, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    fn sched(t_sample: usize) -> DdpmSchedule {
        DdpmSchedule::new(250, 1e-4, 0.02, t_sample)
    }

    #[test]
    fn delta_zero_plans_all_full() {
        check("delta0_all_full", 40, |g| {
            let t_sample = g.usize_in(1, 120);
            let groups = TimeGroups::new(250, g.usize_in(1, t_sample.min(10)));
            let s = sched(t_sample);
            let drift: Vec<f32> =
                (0..groups.groups).map(|_| g.f32_in(0.0, 0.5)).collect();
            let plan = ReusePolicy::new(0.0).plan(&s.steps, &groups, &drift);
            if plan.iter().all(|d| *d == Decision::Full) {
                Ok(())
            } else {
                Err(format!("δ=0 planned a reuse step: {plan:?}"))
            }
        });
    }

    #[test]
    fn schedule_conservation_partitions_every_step() {
        // per-group schedules cover every group's visited steps, no
        // step double-counted, and the union is exactly 0..n
        check("schedule_conservation", 40, |g| {
            let t_sample = g.usize_in(2, 120);
            let groups = TimeGroups::new(250, g.usize_in(1, 10));
            let s = sched(t_sample);
            let drift: Vec<f32> =
                (0..groups.groups).map(|_| g.f32_in(0.0, 0.1)).collect();
            let delta = g.f32_in(0.0, 0.1) as f64;
            let plan = ReusePolicy::new(delta).plan(&s.steps, &groups, &drift);
            let per = per_group_schedule(&s.steps, &groups, &plan);
            let mut seen = vec![0usize; s.len()];
            for gs in &per {
                for &i in gs.full.iter().chain(&gs.reuse) {
                    seen[i] += 1;
                }
            }
            if seen.iter().any(|&c| c != 1) {
                return Err(format!("steps not covered exactly once: {seen:?}"));
            }
            // each group that appears in the trajectory runs at least
            // one full step (the ε̂ producer)
            for (gi, gs) in per.iter().enumerate() {
                let visited = s.steps.iter()
                    .any(|&t| groups.group_of(t) == gi);
                if visited && gs.full.is_empty() {
                    return Err(format!("group {gi} has no full step"));
                }
                if !visited && !(gs.full.is_empty() && gs.reuse.is_empty()) {
                    return Err(format!("unvisited group {gi} got steps"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn first_visit_of_each_group_is_full() {
        let s = sched(100);
        let groups = TimeGroups::new(250, 10);
        let drift = vec![0.0f32; 10]; // maximally reusable
        let plan = ReusePolicy::new(0.5).plan(&s.steps, &groups, &drift);
        let mut seen = vec![false; 10];
        for (i, &t) in s.steps.iter().enumerate() {
            let g = groups.group_of(t);
            if !seen[g] {
                assert_eq!(plan[i], Decision::Full, "group {g} step {i}");
                seen[g] = true;
            }
        }
        // and with zero drift the stride cap bites: ≥ half the steps reuse
        let reused = plan.iter().filter(|d| **d == Decision::Reuse).count();
        assert!(reused * 2 >= s.len(), "{reused}/{}", s.len());
    }

    #[test]
    fn runs_merge_only_reuse_steps() {
        use Decision::{Full, Reuse};
        let plan = [Full, Reuse, Reuse, Full, Full, Reuse];
        let runs = ReusePolicy::runs(&plan);
        assert_eq!(runs, vec![
            Run { start: 0, len: 1, reuse: false },
            Run { start: 1, len: 2, reuse: true },
            Run { start: 3, len: 1, reuse: false },
            Run { start: 4, len: 1, reuse: false },
            Run { start: 5, len: 1, reuse: true },
        ]);
        // runs partition the plan
        let total: usize = runs.iter().map(|r| r.len).sum();
        assert_eq!(total, plan.len());
    }

    #[test]
    fn drift_proxy_orders_groups_and_flags_sparse_ones() {
        let s = sched(100);
        let groups = TimeGroups::new(250, 10);
        let drift = drift_from_schedule(&s, &groups);
        assert_eq!(drift.len(), 10);
        for &d in &drift {
            assert!(d.is_finite() && d >= 0.0);
            // adjacent respaced steps move the mixing coefficients by
            // far less than the 1.0 sentinel
            assert!(d < 0.5, "{d}");
        }
        // a 5-step trajectory cannot give 10 groups two visits each:
        // sparse groups get the sentinel
        let s5 = sched(5);
        let d5 = drift_from_schedule(&s5, &groups);
        assert!(d5.iter().filter(|&&d| d == 1.0).count() >= 5, "{d5:?}");
    }

    #[test]
    fn simulate_delta_zero_matches_plain_loop_exactly() {
        // the reuse-aware loop at δ=0 is byte-identical to the plain
        // per-step reverse loop (same RNG draws, same arithmetic)
        let s = sched(60);
        let groups = TimeGroups::new(250, 10);
        let drift = drift_from_schedule(&s, &groups);
        let il = 32usize;
        // deterministic stand-in for the model
        let eps_of = |x: &[f32], t: usize, _g: usize| -> Vec<f32> {
            x.iter()
                .map(|v| (v * 0.9 + t as f32 * 1e-3).sin())
                .collect()
        };
        let mut rng_a = Rng::new(42);
        let (got, stats) =
            simulate(&s, &groups, &drift, 0.0, il, &mut rng_a, eps_of);
        assert_eq!(stats.reuse_hits, 0);
        assert_eq!(stats.steps_skipped, 0);
        assert_eq!(stats.steps, s.len());

        let mut rng_b = Rng::new(42);
        let mut x = rng_b.normal_vec(il);
        for i in 0..s.len() {
            let eps = eps_of(&x, s.steps[i], 0);
            let noise = if i + 1 == s.len() {
                None
            } else {
                Some(rng_b.normal_vec(il))
            };
            s.reverse_step(i, &mut x, &eps, noise.as_deref());
        }
        for v in x.iter_mut() {
            *v = v.clamp(-1.5, 1.5);
        }
        assert_eq!(got, x, "δ=0 trajectory diverged from the plain loop");
    }

    #[test]
    fn simulate_with_reuse_skips_forwards_and_stays_finite() {
        let s = sched(60);
        let groups = TimeGroups::new(250, 10);
        let drift = drift_from_schedule(&s, &groups);
        let il = 32usize;
        let mut forwards = 0usize;
        let mut rng = Rng::new(7);
        let (x, stats) = simulate(
            &s, &groups, &drift, 0.25, il, &mut rng,
            |x, t, _g| {
                forwards += 1;
                x.iter()
                    .map(|v| (v * 0.9 + t as f32 * 1e-3).sin())
                    .collect()
            },
        );
        assert!(stats.reuse_hits > 0);
        assert_eq!(stats.steps_skipped, s.len() - forwards);
        assert_eq!(stats.reuse_hits, stats.steps_skipped);
        assert!(stats.steps < s.len()); // fused runs collapse updates
        assert!(x.iter().all(|v| v.is_finite()));
    }
}
