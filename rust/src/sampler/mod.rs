//! Ancestral DDPM sampling loop with per-time-group qparams switching.
//!
//! The sampler owns the request path: weights are fake-quantized once
//! (host-side, per the calibrated config), uploaded once as resident
//! device buffers, and each reverse step uploads only (x_t, t, y[, Δ]).
//! TGQ configs swap the packed qparams vector whenever the trajectory
//! crosses a time-group boundary (the vectors are precomputed).
//!
//! One sampler drives one *rung* of the manifest's batch ladder — the
//! batch dim its artifact was lowered with. [`Sampler::new`] builds the
//! largest rung (the classic full batch); [`Sampler::ladder`] builds
//! every lowered rung at once, sharing a single resident upload of the
//! quantized weights across the rungs so a multi-rung serve worker
//! costs no more device memory than a fixed-batch one.
//!
//! PTQD configs additionally apply the noise correction: the correlated
//! part of the quantization error is divided out of ε̂ and the residual
//! variance is removed from the ancestral σ².

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::coordinator::QuantConfig;
use crate::model::WeightStore;
use crate::runtime::Runtime;
use crate::sched::DdpmSchedule;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Per-trajectory observability (sampling-path §Perf numbers).
#[derive(Clone, Copy, Debug, Default)]
pub struct SampleStats {
    pub steps: usize,
    pub qp_swaps: usize,
    pub exec_s: f64,
    pub host_s: f64,
}

/// A compiled-and-resident sampling context for one [`QuantConfig`] at
/// one batch-ladder rung.
pub struct Sampler<'a> {
    rt: &'a Runtime,
    pub sched: DdpmSchedule,
    qc: QuantConfig,
    /// Weight buffers (fake-quantized) resident on device — shared
    /// across the rungs of a ladder.
    wbufs: Rc<Vec<xla::PjRtBuffer>>,
    /// Precomputed per-group qparams vectors (empty for the FP path).
    qvecs: Vec<Tensor>,
    /// Resolved artifact name for this rung's forward pass.
    artifact: String,
    img_len: usize,
    batch: usize,
}

impl<'a> Sampler<'a> {
    /// Build from a calibrated config at the *largest* lowered rung
    /// (the classic full artifact batch); `weights` are the FP weights
    /// (the sampler applies the config's weight fake-quantization
    /// itself). See [`Self::for_batch`] / [`Self::ladder`] for the
    /// smaller rungs.
    pub fn new(rt: &'a Runtime, weights: &WeightStore, qc: QuantConfig,
               timesteps: usize) -> Result<Sampler<'a>> {
        let rung = rt.manifest.batches.sample_max();
        Sampler::for_batch(rt, weights, qc, timesteps, rung)
    }

    /// Build for one specific ladder rung, quantizing + uploading the
    /// weights for this sampler alone.
    pub fn for_batch(rt: &'a Runtime, weights: &WeightStore,
                     qc: QuantConfig, timesteps: usize, batch: usize)
                     -> Result<Sampler<'a>> {
        let wbufs = Rc::new(Sampler::upload_weights(rt, weights, &qc)?);
        Sampler::with_shared(rt, wbufs, qc, timesteps, batch)
    }

    /// Build a sampler per lowered rung (ascending), sharing one
    /// resident upload of the quantized weights across all of them.
    /// `restrict` narrows serving to a subset of the lowered rungs; a
    /// requested rung the artifacts were never lowered at is a typed
    /// error naming the manifest ladder.
    pub fn ladder(rt: &'a Runtime, weights: &WeightStore,
                  qc: &QuantConfig, timesteps: usize,
                  restrict: Option<&[usize]>)
                  -> Result<Vec<Sampler<'a>>> {
        let lowered = &rt.manifest.batches.sample;
        let rungs: Vec<usize> = match restrict {
            None => lowered.clone(),
            Some(want) => {
                let mut v = want.to_vec();
                v.sort_unstable();
                v.dedup();
                if v.is_empty() {
                    bail!("batch ladder restriction is empty");
                }
                for r in &v {
                    if !lowered.contains(r) {
                        bail!(
                            "batch rung {r} was not lowered (manifest \
                             `batches.sample` ladder is {lowered:?})"
                        );
                    }
                }
                v
            }
        };
        let wbufs = Rc::new(Sampler::upload_weights(rt, weights, qc)?);
        rungs
            .into_iter()
            .map(|b| {
                Sampler::with_shared(rt, Rc::clone(&wbufs), qc.clone(),
                                     timesteps, b)
            })
            .collect()
    }

    /// Fake-quantize (non-FP) and upload the weights once.
    fn upload_weights(rt: &Runtime, weights: &WeightStore,
                      qc: &QuantConfig) -> Result<Vec<xla::PjRtBuffer>> {
        let ws = if qc.method == "fp" {
            weights.clone()
        } else {
            weights.fakequant(&qc.weights)
        };
        rt.upload_all(&ws.tensors)
    }

    /// Assemble a rung around already-resident weight buffers.
    fn with_shared(rt: &'a Runtime, wbufs: Rc<Vec<xla::PjRtBuffer>>,
                   qc: QuantConfig, timesteps: usize, batch: usize)
                   -> Result<Sampler<'a>> {
        let m = &rt.manifest;
        let d = &m.diffusion;
        let sched = DdpmSchedule::new(d.train_steps, d.beta_start, d.beta_end,
                                      timesteps);
        let fp = qc.method == "fp";
        let base = if fp { "dit_fp_sample" } else { "dit_quant" };
        let artifact = m.sample_artifact(base, batch)?;
        // compile this rung's executable now rather than on the first
        // dispatch: a serve worker pays compilation before it marks
        // itself ready, and a missing/corrupt rung artifact surfaces
        // here as a typed construction error instead of failing the
        // first client batch
        rt.executable_for_rung(base, batch)?;
        let qvecs: Vec<Tensor> = if fp {
            Vec::new()
        } else {
            qc.qparams_all_groups(m)
                .into_iter()
                .map(|v| Tensor::new(vec![m.qp_len], v))
                .collect()
        };
        Ok(Sampler {
            rt,
            sched,
            qc,
            wbufs,
            qvecs,
            artifact,
            img_len: m.model.img_size * m.model.img_size * m.model.channels,
            batch,
        })
    }

    /// Batch size this rung's artifact was lowered with.
    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn img_len(&self) -> usize {
        self.img_len
    }

    /// Generate one batch of images for the given class labels
    /// (`labels.len()` must equal [`Self::batch`]). Returns flat
    /// (B, H, W, C) pixels in ≈[-1, 1] and the step statistics.
    pub fn sample(&self, labels: &[i32], rng: &mut Rng)
                  -> Result<(Vec<f32>, SampleStats)> {
        let m = &self.rt.manifest;
        let b = self.batch;
        assert_eq!(labels.len(), b, "labels must match artifact batch");
        let il = self.img_len;
        let mut stats = SampleStats::default();

        let mut x = rng.normal_vec(b * il);
        let yb = self.rt.upload_i32(labels, &[b])?;
        let mut last_group = usize::MAX;
        let mut qpb: Option<xla::PjRtBuffer> = None;

        let t_total = std::time::Instant::now();
        for i in 0..self.sched.len() {
            let t = self.sched.steps[i];
            let tvec = vec![t as i32; b];

            // TGQ: swap the packed qparams when crossing a boundary
            if !self.qvecs.is_empty() {
                let g = self.qc.groups.group_of(t);
                if g != last_group {
                    qpb = Some(self.rt.upload(&self.qvecs[g])?);
                    last_group = g;
                    stats.qp_swaps += 1;
                }
            }

            let xt = Tensor::new(
                vec![b, m.model.img_size, m.model.img_size,
                     m.model.channels],
                x.clone(),
            );
            let xb = self.rt.upload(&xt)?;
            let tb = self.rt.upload_i32(&tvec, &[b])?;
            let t_exec = std::time::Instant::now();
            let mut inputs: Vec<&xla::PjRtBuffer> =
                self.wbufs.iter().collect();
            inputs.extend([&xb, &tb, &yb]);
            if let Some(q) = &qpb {
                inputs.push(q);
            }
            let outs = self.rt.run_buffers(&self.artifact, &inputs)?;
            stats.exec_s += t_exec.elapsed().as_secs_f64();
            let mut eps_hat = outs[0].data.clone();

            // PTQD correlated-noise correction (identity for others)
            let nc = self.qc.correction_for_t(t);
            if nc.rho != 1.0 || nc.bias != 0.0 {
                let inv = 1.0 / nc.rho;
                for e in eps_hat.iter_mut() {
                    *e = (*e - nc.bias) * inv;
                }
            }

            // ancestral update with (optionally) reduced variance
            let last = i + 1 == self.sched.len();
            let noise = if last {
                None
            } else {
                Some(rng.normal_vec(b * il))
            };
            self.reverse_step(i, &mut x, &eps_hat, noise.as_deref(),
                              nc.resid_var);
            stats.steps += 1;
        }
        stats.host_s = t_total.elapsed().as_secs_f64() - stats.exec_s;

        for v in x.iter_mut() {
            *v = v.clamp(-1.5, 1.5);
        }
        Ok((x, stats))
    }

    /// Reverse step with PTQD variance shrinkage: the residual
    /// (uncorrelated) quantization noise enters x with coefficient
    /// c_ε = β/√(1−ᾱ); its variance is removed from the posterior σ².
    fn reverse_step(&self, i: usize, x: &mut [f32], eps_hat: &[f32],
                    noise: Option<&[f32]>, resid_var: f32) {
        let s = &self.sched;
        let beta = s.betas[i];
        let ab = s.alpha_bars[i];
        let ab_prev = s.alpha_bars_prev[i];
        let alpha = 1.0 - beta;
        let c_eps = (beta / (1.0 - ab).sqrt()) as f32;
        let c_x = (1.0 / alpha.sqrt()) as f32;
        let var = beta * (1.0 - ab_prev) / (1.0 - ab);
        let var = (var - (c_eps as f64).powi(2) * resid_var as f64).max(0.0);
        let sigma = var.sqrt() as f32;
        for j in 0..x.len() {
            x[j] = c_x * (x[j] - c_eps * eps_hat[j]);
        }
        if let Some(z) = noise {
            for j in 0..x.len() {
                x[j] += sigma * z[j];
            }
        }
    }

    /// Generate `n` images round-robin over the classes, streaming each
    /// finished batch into `sink`. Returns aggregate stats.
    pub fn generate<F>(&self, n: usize, num_classes: usize, rng: &mut Rng,
                       mut sink: F) -> Result<SampleStats>
    where
        F: FnMut(&[f32], &[i32]) -> Result<()>,
    {
        let b = self.batch;
        let mut agg = SampleStats::default();
        let mut produced = 0usize;
        let mut next_class = 0usize;
        while produced < n {
            let labels: Vec<i32> = (0..b)
                .map(|i| ((next_class + i) % num_classes) as i32)
                .collect();
            next_class = (next_class + b) % num_classes;
            let (imgs, st) = self.sample(&labels, rng)?;
            let take = (n - produced).min(b);
            sink(&imgs[..take * self.img_len], &labels[..take])?;
            produced += take;
            agg.steps += st.steps;
            agg.qp_swaps += st.qp_swaps;
            agg.exec_s += st.exec_s;
            agg.host_s += st.host_s;
        }
        Ok(agg)
    }
}

#[cfg(test)]
mod tests {
    // Runtime-dependent behaviour is covered by the integration tests
    // (rust/tests/); here we pin the pure helpers.

    #[test]
    fn variance_shrinkage_floors_at_zero() {
        // the PTQD shrinkage never produces a negative variance: checked
        // by construction (max(0.0)) — assert the formula's pieces.
        let beta = 0.01f64;
        let ab = 0.5f64;
        let c_eps = beta / (1.0 - ab).sqrt();
        let var = beta * (1.0 - 0.51) / (1.0 - ab);
        let huge_resid = 1e9f32;
        let v = (var - c_eps.powi(2) * huge_resid as f64).max(0.0);
        assert_eq!(v, 0.0);
    }
}
