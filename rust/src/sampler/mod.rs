//! Ancestral DDPM sampling loop with per-time-group qparams switching
//! and timestep-aware compute reuse.
//!
//! The sampler owns the request path with a *device-resident
//! trajectory*: weights are fake-quantized once (host-side, per the
//! calibrated config) and uploaded once; the per-group packed qparams
//! vectors and the per-step `t` vectors are likewise uploaded at
//! construction, so each reverse step uploads only the evolving `x_t`
//! (straight from its host `Vec<f32>`, no per-step tensor clone). TGQ
//! configs switch between the resident qparams buffers whenever the
//! trajectory crosses a time-group boundary.
//!
//! On top of that sits the **step-reuse layer** ([`reuse`]): the
//! paper's TGQ insight — activations vary smoothly within a time group
//! — means adjacent steps in a low-drift group can share one forward
//! pass. A pure [`reuse::ReusePolicy`] turns the per-group drift
//! statistics the coordinator calibrates ([`QuantConfig::drift`]) and
//! the `--reuse-delta` threshold δ into a per-step `Full | Reuse`
//! plan; a run of `Reuse` steps skips the device dispatch entirely and
//! applies the scheduler's closed-form composition of the skipped
//! reverse updates to the group's last ε̂
//! ([`DdpmSchedule::fused_coeffs`]). δ=0 (the constructor default)
//! disables reuse and is byte-identical to the plain per-step loop;
//! [`SampleStats`] counts `reuse_hits` / `steps_skipped` /
//! `uploads_saved` so the serve stack can prove the cache hits.
//!
//! One sampler drives one *rung* of the manifest's batch ladder — the
//! batch dim its artifact was lowered with. [`Sampler::new`] builds the
//! largest rung (the classic full batch); [`Sampler::ladder`] builds
//! every lowered rung at once, sharing a single resident upload of the
//! quantized weights across the rungs so a multi-rung serve worker
//! costs no more device memory than a fixed-batch one.
//!
//! PTQD configs additionally apply the noise correction: the correlated
//! part of the quantization error is divided out of ε̂ and the residual
//! variance is removed from the ancestral σ².

pub mod reuse;

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::coordinator::QuantConfig;
use crate::model::WeightStore;
use crate::obs::trace::{self, SpanKind};
use crate::runtime::Runtime;
use crate::sched::DdpmSchedule;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use reuse::{Decision, ReusePolicy};

/// Per-trajectory observability (sampling-path §Perf numbers).
#[derive(Clone, Copy, Debug, Default)]
pub struct SampleStats {
    /// Host reverse updates applied (a fused reuse run counts once).
    pub steps: usize,
    pub qp_swaps: usize,
    /// Steps whose ε̂ came from the group's step cache.
    pub reuse_hits: usize,
    /// Forward passes avoided (`sched.len()` minus dispatches run).
    pub steps_skipped: usize,
    /// Per-trajectory host→device uploads avoided relative to the
    /// pre-resident protocol (which uploaded `x_t` *and* `t` every
    /// step plus the group qparams at each crossing).
    pub uploads_saved: usize,
    pub exec_s: f64,
    pub host_s: f64,
}

/// A compiled-and-resident sampling context for one [`QuantConfig`] at
/// one batch-ladder rung.
pub struct Sampler<'a> {
    rt: &'a Runtime,
    pub sched: DdpmSchedule,
    qc: QuantConfig,
    /// Weight buffers (fake-quantized) resident on device — shared
    /// across the rungs of a ladder.
    wbufs: Rc<Vec<xla::PjRtBuffer>>,
    /// Per-group packed qparams, uploaded once at construction (empty
    /// for the FP path); group crossings index instead of re-uploading.
    qbufs: Vec<xla::PjRtBuffer>,
    /// Per-step `t` vectors, uploaded once at construction.
    tbufs: Vec<xla::PjRtBuffer>,
    /// Step-reuse threshold δ (0 = disabled, byte-identical loop).
    reuse_delta: f64,
    /// Per-step `Full | Reuse` plan derived from δ and the config's
    /// calibrated per-group drift.
    plan: Vec<Decision>,
    /// Resolved artifact name for this rung's forward pass.
    artifact: String,
    img_len: usize,
    batch: usize,
}

impl<'a> Sampler<'a> {
    /// Build from a calibrated config at the *largest* lowered rung
    /// (the classic full artifact batch); `weights` are the FP weights
    /// (the sampler applies the config's weight fake-quantization
    /// itself). See [`Self::for_batch`] / [`Self::ladder`] for the
    /// smaller rungs.
    pub fn new(rt: &'a Runtime, weights: &WeightStore, qc: QuantConfig,
               timesteps: usize) -> Result<Sampler<'a>> {
        let rung = rt.manifest.batches.sample_max();
        Sampler::for_batch(rt, weights, qc, timesteps, rung)
    }

    /// Build for one specific ladder rung, quantizing + uploading the
    /// weights for this sampler alone.
    pub fn for_batch(rt: &'a Runtime, weights: &WeightStore,
                     qc: QuantConfig, timesteps: usize, batch: usize)
                     -> Result<Sampler<'a>> {
        let wbufs = Rc::new(Sampler::upload_weights(rt, weights, &qc)?);
        Sampler::with_shared(rt, wbufs, qc, timesteps, batch)
    }

    /// Build a sampler per lowered rung (ascending), sharing one
    /// resident upload of the quantized weights across all of them.
    /// `restrict` narrows serving to a subset of the lowered rungs; a
    /// requested rung the artifacts were never lowered at is a typed
    /// error naming the manifest ladder.
    pub fn ladder(rt: &'a Runtime, weights: &WeightStore,
                  qc: &QuantConfig, timesteps: usize,
                  restrict: Option<&[usize]>)
                  -> Result<Vec<Sampler<'a>>> {
        let lowered = &rt.manifest.batches.sample;
        let rungs: Vec<usize> = match restrict {
            None => lowered.clone(),
            Some(want) => {
                let mut v = want.to_vec();
                v.sort_unstable();
                v.dedup();
                if v.is_empty() {
                    bail!("batch ladder restriction is empty");
                }
                for r in &v {
                    if !lowered.contains(r) {
                        bail!(
                            "batch rung {r} was not lowered (manifest \
                             `batches.sample` ladder is {lowered:?})"
                        );
                    }
                }
                v
            }
        };
        let wbufs = Rc::new(Sampler::upload_weights(rt, weights, qc)?);
        rungs
            .into_iter()
            .map(|b| {
                Sampler::with_shared(rt, Rc::clone(&wbufs), qc.clone(),
                                     timesteps, b)
            })
            .collect()
    }

    /// Fake-quantize (non-FP) and upload the weights once.
    fn upload_weights(rt: &Runtime, weights: &WeightStore,
                      qc: &QuantConfig) -> Result<Vec<xla::PjRtBuffer>> {
        let ws = if qc.method == "fp" {
            weights.clone()
        } else {
            weights.fakequant(&qc.weights)
        };
        rt.upload_all(&ws.tensors)
    }

    /// Assemble a rung around already-resident weight buffers.
    fn with_shared(rt: &'a Runtime, wbufs: Rc<Vec<xla::PjRtBuffer>>,
                   qc: QuantConfig, timesteps: usize, batch: usize)
                   -> Result<Sampler<'a>> {
        let m = &rt.manifest;
        let d = &m.diffusion;
        let sched = DdpmSchedule::new(d.train_steps, d.beta_start, d.beta_end,
                                      timesteps);
        let fp = qc.method == "fp";
        let base = if fp { "dit_fp_sample" } else { "dit_quant" };
        let artifact = m.sample_artifact(base, batch)?;
        // compile this rung's executable now rather than on the first
        // dispatch: a serve worker pays compilation before it marks
        // itself ready, and a missing/corrupt rung artifact surfaces
        // here as a typed construction error instead of failing the
        // first client batch
        rt.executable_for_rung(base, batch)?;
        // device-resident trajectory: the per-group qparams and the
        // per-step t vectors never change within a sampler's lifetime,
        // so they are uploaded exactly once here instead of per
        // step/crossing on the hot path
        let qbufs: Vec<xla::PjRtBuffer> = if fp {
            Vec::new()
        } else {
            qc.qparams_all_groups(m)
                .into_iter()
                .map(|v| rt.upload(&Tensor::new(vec![m.qp_len], v)))
                .collect::<Result<_>>()?
        };
        let tbufs: Vec<xla::PjRtBuffer> = sched
            .steps
            .iter()
            .map(|&t| rt.upload_i32(&vec![t as i32; batch], &[batch]))
            .collect::<Result<_>>()?;
        let plan = vec![Decision::Full; sched.len()];
        Ok(Sampler {
            rt,
            sched,
            qc,
            wbufs,
            qbufs,
            tbufs,
            reuse_delta: 0.0,
            plan,
            artifact,
            img_len: m.model.img_size * m.model.img_size * m.model.channels,
            batch,
        })
    }

    /// Batch size this rung's artifact was lowered with.
    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn img_len(&self) -> usize {
        self.img_len
    }

    /// Set the step-reuse threshold δ and recompute the per-step plan
    /// from the config's calibrated per-group drift. δ=0 (the
    /// construction default) plans every step `Full` — byte-identical
    /// to the pre-reuse sampler; larger δ lets low-drift time groups
    /// share forward passes at stride 2/4/8.
    pub fn set_reuse_delta(&mut self, delta: f64) {
        self.reuse_delta = if delta.is_finite() { delta.max(0.0) } else { 0.0 };
        self.plan = ReusePolicy::new(self.reuse_delta)
            .plan(&self.sched.steps, &self.qc.groups, &self.qc.drift);
    }

    /// Current step-reuse threshold δ.
    pub fn reuse_delta(&self) -> f64 {
        self.reuse_delta
    }

    /// Generate one batch of images for the given class labels
    /// (`labels.len()` must equal [`Self::batch`]). Returns flat
    /// (B, H, W, C) pixels in ≈[-1, 1] and the step statistics.
    ///
    /// The loop iterates the reuse plan's runs: a `Full` step uploads
    /// the host trajectory (`x_t` only — `t` and the group qparams are
    /// already resident), dispatches the model and applies one reverse
    /// update; a `Reuse` run applies the fused closed-form composition
    /// of its skipped steps to the group's cached ε̂ with zero device
    /// work. At δ=0 every step is `Full` and the trajectory is
    /// byte-identical to the pre-reuse sampler.
    pub fn sample(&self, labels: &[i32], rng: &mut Rng)
                  -> Result<(Vec<f32>, SampleStats)> {
        let m = &self.rt.manifest;
        let b = self.batch;
        if labels.len() != b {
            bail!(
                "label count {} does not match artifact batch {b} \
                 (rung `{}`)",
                labels.len(), self.artifact
            );
        }
        let il = self.img_len;
        let shape = [b, m.model.img_size, m.model.img_size,
                     m.model.channels];
        let n = self.sched.len();
        let mut stats = SampleStats::default();

        let mut x = rng.normal_vec(b * il);
        let yb = self.rt.upload_i32(labels, &[b])?;
        let mut last_group = usize::MAX;
        // group-local ε̂ cache for the reuse fast path
        let mut eps_hat: Vec<f32> = Vec::new();
        let mut eps_group = usize::MAX;

        // per-run step spans parent under the router's Generate span
        // (installed on this thread for the duration of the call);
        // NONE outside a traced batch, making every record a no-op
        let tctx = trace::current();
        let t_total = std::time::Instant::now();
        for run in ReusePolicy::runs(&self.plan) {
            let g = self.qc.groups.group_of(self.sched.steps[run.start]);
            let nc = self.qc.correction_for_t(self.sched.steps[run.start]);
            let run_start =
                if tctx.is_active() { trace::now_ns() } else { 0 };

            if run.reuse && eps_group == g && !eps_hat.is_empty() {
                // fused reuse run: one host update, zero dispatches,
                // zero uploads — ε̂ rescales through the closed form
                let (a, bc, s) =
                    self.sched.fused_coeffs(run.start, run.len,
                                            nc.resid_var);
                for j in 0..x.len() {
                    x[j] = a * x[j] - bc * eps_hat[j];
                }
                if s > 0.0 {
                    let z = rng.normal_vec(b * il);
                    for j in 0..x.len() {
                        x[j] += s * z[j];
                    }
                }
                stats.steps += 1;
                stats.reuse_hits += run.len;
                stats.steps_skipped += run.len;
                stats.uploads_saved += 2 * run.len; // x_t and t
                if tctx.is_active() {
                    // span args = the half-open step-index range this
                    // run covered; the kind already says it was reused
                    trace::record_span(tctx, SpanKind::StepsReuse,
                                       run_start, trace::now_ns(),
                                       run.start as u64,
                                       (run.start + run.len) as u64);
                }
                continue;
            }

            // full step(s); a reuse run without a cached same-group ε̂
            // (impossible under `ReusePolicy::plan`, which opens every
            // group with a Full step) degrades to full steps here
            for i in run.start..run.start + run.len {
                // TGQ: switch the resident qparams buffer on crossing
                if !self.qbufs.is_empty() && g != last_group {
                    last_group = g;
                    stats.qp_swaps += 1;
                    stats.uploads_saved += 1; // resident since init
                }

                let xb = self.rt.upload_f32(&x, &shape)?;
                let t_exec = std::time::Instant::now();
                let mut inputs: Vec<&xla::PjRtBuffer> =
                    self.wbufs.iter().collect();
                inputs.extend([&xb, &self.tbufs[i], &yb]);
                if let Some(q) = self.qbufs.get(g) {
                    inputs.push(q);
                }
                let mut outs =
                    self.rt.run_buffers(&self.artifact, &inputs)?;
                stats.exec_s += t_exec.elapsed().as_secs_f64();
                if outs.is_empty() {
                    bail!("artifact `{}` returned no outputs",
                          self.artifact);
                }
                eps_hat = outs.swap_remove(0).data;
                eps_group = g;

                // PTQD correlated-noise correction (identity for others)
                if nc.rho != 1.0 || nc.bias != 0.0 {
                    let inv = 1.0 / nc.rho;
                    for e in eps_hat.iter_mut() {
                        *e = (*e - nc.bias) * inv;
                    }
                }

                // ancestral update with (optionally) reduced variance
                let noise = if i + 1 == n {
                    None
                } else {
                    Some(rng.normal_vec(b * il))
                };
                let (c_x, c_eps, sigma) =
                    self.sched.step_coeffs(i, nc.resid_var);
                for j in 0..x.len() {
                    x[j] = c_x * (x[j] - c_eps * eps_hat[j]);
                }
                if let Some(z) = &noise {
                    for j in 0..x.len() {
                        x[j] += sigma * z[j];
                    }
                }
                stats.steps += 1;
                stats.uploads_saved += 1; // t resident since init
            }
            if tctx.is_active() {
                trace::record_span(tctx, SpanKind::StepsFull,
                                   run_start, trace::now_ns(),
                                   run.start as u64,
                                   (run.start + run.len) as u64);
            }
        }
        stats.host_s = t_total.elapsed().as_secs_f64() - stats.exec_s;

        for v in x.iter_mut() {
            *v = v.clamp(-1.5, 1.5);
        }
        Ok((x, stats))
    }

    /// Generate `n` images round-robin over the classes, streaming each
    /// finished batch into `sink`. Returns aggregate stats.
    pub fn generate<F>(&self, n: usize, num_classes: usize, rng: &mut Rng,
                       mut sink: F) -> Result<SampleStats>
    where
        F: FnMut(&[f32], &[i32]) -> Result<()>,
    {
        let b = self.batch;
        let mut agg = SampleStats::default();
        let mut produced = 0usize;
        let mut next_class = 0usize;
        while produced < n {
            let labels: Vec<i32> = (0..b)
                .map(|i| ((next_class + i) % num_classes) as i32)
                .collect();
            next_class = (next_class + b) % num_classes;
            let (imgs, st) = self.sample(&labels, rng)?;
            let take = (n - produced).min(b);
            sink(&imgs[..take * self.img_len], &labels[..take])?;
            produced += take;
            agg.steps += st.steps;
            agg.qp_swaps += st.qp_swaps;
            agg.reuse_hits += st.reuse_hits;
            agg.steps_skipped += st.steps_skipped;
            agg.uploads_saved += st.uploads_saved;
            agg.exec_s += st.exec_s;
            agg.host_s += st.host_s;
        }
        Ok(agg)
    }
}

#[cfg(test)]
mod tests {
    // Runtime-dependent behaviour is covered by the integration tests
    // (rust/tests/); here we pin the pure helpers.

    #[test]
    fn variance_shrinkage_floors_at_zero() {
        // the PTQD shrinkage never produces a negative variance: checked
        // by construction (max(0.0)) — assert the formula's pieces.
        let beta = 0.01f64;
        let ab = 0.5f64;
        let c_eps = beta / (1.0 - ab).sqrt();
        let var = beta * (1.0 - 0.51) / (1.0 - ab);
        let huge_resid = 1e9f32;
        let v = (var - c_eps.powi(2) * huge_resid as f64).max(0.0);
        assert_eq!(v, 0.0);
    }
}
