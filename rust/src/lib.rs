//! # TQ-DiT — Efficient Time-Aware Quantization for Diffusion Transformers
//!
//! Rust coordinator (L3) of the three-layer reproduction described in
//! `DESIGN.md`. Python/JAX/Pallas exist only at build time (`make
//! artifacts`); this crate loads the AOT-lowered HLO-text artifacts via
//! the PJRT C API and owns everything on the request path: calibration
//! (Algorithm 1), quantization-parameter search (HO / MRQ / TGQ),
//! baselines, DDPM sampling with per-time-group parameter switching, a
//! batched generation service, and the FID/sFID/IS evaluation harness.
//!
//! Module map (bottom-up):
//!
//! * [`util`] — from-scratch substrates (no crates offline): PRNG,
//!   JSON parsing, CLI, config files, thread pool, bench harness,
//!   mini property-testing framework, RSS probes.
//! * [`tensor`] — host tensors + linear algebra (Jacobi eigendecomposition
//!   → matrix square root for FID).
//! * [`quant`] — the paper's quantization math: uniform asymmetric
//!   quant (eq. 5), multi-region quant (§III-C), Hessian-guided
//!   objective (eq. 14–17), candidate search.
//! * [`sched`] — DDPM schedules, respacing, time-grouping (eq. 9).
//! * [`runtime`] — PJRT client wrapper, artifact manifest, executables.
//! * [`model`] — weight store + host-side weight fake-quantization.
//! * [`coordinator`] — Algorithm 1 phases 1–3, baselines, pipelines.
//! * [`sampler`] — ancestral DDPM sampling loop (TGQ-aware).
//! * [`serve`] — sharded generation service: dynamic batcher + a
//!   deadline-aware batch-ladder policy + a multi-worker router with
//!   typed error propagation, extended across hosts by `serve::net`
//!   (wire/proto/node/cluster with health checks and re-queue on
//!   node loss).
//! * [`obs`] — serve-stack observability: request-scoped tracing
//!   (span ring + Chrome trace export), mergeable log-linear latency
//!   histograms, and the Prometheus-style `/metrics` exposition the
//!   reactor serves at `--metrics-addr`.
//! * [`metrics`] — FID / sFID / Inception Score, image writers.
//! * [`data`] — synthetic dataset (mirror of `python/compile/data.py`).
//! * [`analysis`] — static analysis over this repo's own sources
//!   (`tq-dit lint`): concurrency-invariant rules — lock-across-
//!   blocking, lock order, panic-free serve paths, protocol match
//!   exhaustiveness, reactor discipline — gated in CI.

pub mod analysis;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod sampler;
pub mod sched;
pub mod serve;
pub mod tensor;
pub mod util;
