//! Clean counterpart to `protocol_exhaustiveness_bad.rs`: the
//! catch-all arm binds and logs, so an unexpected variant leaves a
//! trace. Not compiled.

fn handle(msg: Msg) {
    match msg {
        Msg::Ping { seq } => pong(seq),
        Msg::Submit { id, n } => enqueue(id, n),
        other => log_ignored(&other),
    }
}
