//! Violating fixture for `protocol-exhaustiveness`: the silent `_`
//! wildcard swallows any `Msg` variant a newer peer sends — no log, no
//! error, just a protocol feature that mysteriously no-ops. Not
//! compiled.

fn handle(msg: Msg) {
    match msg {
        Msg::Ping { seq } => pong(seq),
        Msg::Submit { id, n } => enqueue(id, n),
        _ => {} // finding: silent wildcard over a protocol enum
    }
}
