//! Clean counterpart to `lock_across_blocking_bad.rs`: state is
//! updated under the guard, the socket write happens after the guard's
//! block ends. Not compiled — linted by the fixture tests.

fn push_update(shared: &Shared, payload: &[u8]) -> std::io::Result<()> {
    let seq = {
        let mut st = crate::util::lock(&shared.state);
        st.seq += 1;
        st.seq
    };
    let mut sock = shared.socket_for(seq);
    sock.write_all(payload)?;
    sock.flush()
}
