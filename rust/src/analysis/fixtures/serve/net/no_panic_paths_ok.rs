//! Clean counterpart to `no_panic_paths_bad.rs`: total reads via
//! `.get(..)` with typed errors, and one provably-infallible unwrap
//! carrying the mandatory pragma + reason. Not compiled.

fn decode_ack(bytes: &[u8]) -> Result<Ack, WireError> {
    let kind = bytes.first().copied().unwrap_or(0);
    if kind == 0xff {
        return Err(WireError::Protocol("bad ack kind".into()));
    }
    let id = parse_id(bytes)?;
    Ok(Ack { id })
}

fn newest_rung(ladder: &Ladder) -> usize {
    // tq-lint: allow(no-panic-paths): Ladder::new rejects empty ladders
    *ladder.rungs.last().unwrap()
}
