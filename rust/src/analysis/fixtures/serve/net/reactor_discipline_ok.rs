//! Clean counterpart to `reactor_discipline_bad.rs`: the callback only
//! drains already-buffered frames and hands real work to the pool —
//! blocking calls live inside the offloaded closure, off the loop.
//! Not compiled.

fn on_readable(&mut self, ctl: &mut Ctl<'_>) {
    while let Some(frame) = self.frames.next_ready() {
        let tx = self.tx.clone();
        self.pool.execute(move || {
            let resp = handle_frame(frame);
            tx.send_message(resp)
        });
    }
    ctl.rearm();
}
