//! Clean counterpart to `non_poisoning_lock_bad.rs`: the shared
//! non-poisoning helper is the one blessed way to take a mutex. Not
//! compiled.

fn bump(counter: &Mutex<u64>) -> u64 {
    let mut g = crate::util::lock(counter);
    *g += 1;
    *g
}
