//! The repaired `stats_plumbing_bad.rs`: every `ServerStats` field is
//! mentioned in all four required fns — string-literal serde keys
//! count as mentions, as do plain idents. The
//! `stats_plumbing_catches_a_dropped_absorb_mention` test deletes the
//! `reuse_hits` absorb line from this source and asserts the rule
//! fires, which is the acceptance contract for the rule itself. Not
//! compiled.

struct ServerStats {
    requests: u64,
    reuse_hits: u64,
}

impl ServerStats {
    fn absorb(&mut self, o: &ServerStats) {
        self.requests += o.requests;
        self.reuse_hits += o.reuse_hits;
    }
}

fn stats_to_json(s: &ServerStats) -> Json {
    obj(&[("requests", s.requests), ("reuse_hits", s.reuse_hits)])
}

fn stats_from_json(j: &Json) -> ServerStats {
    ServerStats {
        requests: num(j, "requests"),
        reuse_hits: num(j, "reuse_hits"),
    }
}

fn stats_fold(acc: &ServerStats, d: &ServerStats) -> ServerStats {
    ServerStats {
        requests: acc.requests + d.requests,
        reuse_hits: acc.reuse_hits + d.reuse_hits,
    }
}
