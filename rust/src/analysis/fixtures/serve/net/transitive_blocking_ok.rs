//! The repaired shapes for `transitive_blocking_bad.rs` — same
//! helpers, no finding. Two distinct repairs are shown: dropping the
//! guard and offloading the blocking helper to the pool, and cutting
//! inference at a mode-dispatch shim with a declared
//! `allow(transitive-blocking)` pragma (its hot path hands the frame
//! to a non-blocking queue). Not compiled.

fn write_frame_to(conn: &mut Conn) -> std::io::Result<()> {
    conn.sock.write_all(&conn.buf)
}

fn flush_shard(conn: &mut Conn) {
    let _ = write_frame_to(conn);
}

fn push_state(shared: &Shared, pool: &ThreadPool, conn: Conn) {
    let mut st = crate::util::lock(&shared.state);
    st.dirty = false;
    drop(st);
    let mut conn = conn;
    pool.execute(move || {
        flush_shard(&mut conn);
    });
}

// tq-lint: allow(transitive-blocking): queue_frame hands the bytes to the reactor handle without blocking; only the threaded fallback path may block, and its callers are dedicated writer threads
fn send_any(conn: &mut Conn, reactor: bool) {
    if reactor {
        queue_frame(conn);
    } else {
        flush_shard(conn);
    }
}

fn notify(shared: &Shared, conn: &mut Conn) {
    let _st = crate::util::lock(&shared.state);
    send_any(conn, true);
}
