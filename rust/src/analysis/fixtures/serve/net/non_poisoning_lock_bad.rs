//! Violating fixture for `non-poisoning-lock`: `.lock().unwrap()`
//! turns one panicking holder into a permanent `PoisonError` for every
//! later accessor. Not compiled.

fn bump(counter: &Mutex<u64>) -> u64 {
    let mut g = counter.lock().unwrap(); // finding: poisons on panic
    *g += 1;
    *g
}
