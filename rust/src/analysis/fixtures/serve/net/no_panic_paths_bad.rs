//! Violating fixture for `no-panic-paths`: a decode path that indexes
//! peer-controlled bytes, unwraps, and panics — a malformed frame from
//! one peer takes the whole node down. Not compiled.

fn decode_ack(bytes: &[u8]) -> Ack {
    let kind = bytes[0]; // finding: indexing peer bytes
    let id = parse_id(bytes).unwrap(); // finding: unwrap on a decode path
    if kind == 0xff {
        panic!("bad ack kind"); // finding: panic in production code
    }
    Ack { id }
}
