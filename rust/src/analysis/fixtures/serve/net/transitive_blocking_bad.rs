//! Violates `lock-across-blocking` only *through* helpers: the guard
//! is held across a call the call graph infers as blocking, two hops
//! away from the actual `write_all`. The finding must print the chain
//! (`push_state -> flush_shard -> write_frame_to [blocking: write_all]`).
//! Not compiled — linted via include_str! in analysis::tests.

fn write_frame_to(conn: &mut Conn) -> std::io::Result<()> {
    conn.sock.write_all(&conn.buf)
}

fn flush_shard(conn: &mut Conn) {
    let _ = write_frame_to(conn);
}

fn push_state(shared: &Shared, conn: &mut Conn) {
    let st = crate::util::lock(&shared.state);
    flush_shard(conn);
    drop(st);
}
