//! Violating fixture for `reactor-discipline`: a reactor callback does
//! blocking frame I/O inline — every connection on the loop stalls
//! behind this one peer. Not compiled.

fn on_readable(&mut self, ctl: &mut Ctl<'_>) {
    let frame = read_frame(&mut self.sock); // finding: blocks the loop
    self.dispatch(frame);
    ctl.rearm();
}
