//! Violates `stats-plumbing`: `ServerStats.reuse_hits` is plumbed
//! through the serde encode/decode and `stats_fold`, but missing from
//! `absorb` — a new counter that silently vanishes when worker deltas
//! are folded in. The finding anchors at the field definition. This
//! file carries its own miniature plumbing set; required fns that are
//! absent from the file's index are skipped, so the fixture stays
//! self-contained. Not compiled.

struct ServerStats {
    requests: u64,
    reuse_hits: u64,
}

impl ServerStats {
    fn absorb(&mut self, o: &ServerStats) {
        self.requests += o.requests;
    }
}

fn stats_to_json(s: &ServerStats) -> Json {
    obj(&[("requests", s.requests), ("reuse_hits", s.reuse_hits)])
}

fn stats_from_json(j: &Json) -> ServerStats {
    ServerStats {
        requests: num(j, "requests"),
        reuse_hits: num(j, "reuse_hits"),
    }
}

fn stats_fold(acc: &ServerStats, d: &ServerStats) -> ServerStats {
    ServerStats {
        requests: acc.requests + d.requests,
        reuse_hits: acc.reuse_hits + d.reuse_hits,
    }
}
