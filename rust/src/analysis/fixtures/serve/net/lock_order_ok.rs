//! Clean counterpart to `lock_order_bad.rs`: acquisitions strictly
//! ascend the registry (`state` rank 0, then `data` rank 3), and the
//! low-rank guard is dropped before any further work. Not compiled.

fn rehome(conn: &Conn) {
    let moved = {
        let mut st = crate::util::lock(&conn.state);
        st.take_moved()
    };
    let mut data = crate::util::lock(&conn.data);
    data.push_pending(moved);
    drop(data);
}
