//! Violating fixture for `lock-order`: `data` (rank 3) is taken first,
//! then `state` (rank 0) — the inverse of the declared registry, which
//! deadlocks against any thread locking in the blessed order. Also
//! acquires an unregistered mutex while a guard is held. Not compiled.

fn rehome(conn: &Conn) {
    let mut data = crate::util::lock(&conn.data);
    let mut st = crate::util::lock(&conn.state); // finding: rank inversion
    st.moved += data.take_pending();
    let scratch = crate::util::lock(&conn.scratch); // finding: unregistered
    drop(scratch);
}
