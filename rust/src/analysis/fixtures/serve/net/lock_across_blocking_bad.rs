//! Violating fixture for `lock-across-blocking`: a frame write happens
//! while the shared-state guard is live, so every sibling submitter
//! stalls behind one peer's socket. Not compiled — linted by the
//! fixture tests in `analysis/mod.rs` and by CI expecting exit != 0.

fn push_update(shared: &Shared, payload: &[u8]) -> std::io::Result<()> {
    let mut st = crate::util::lock(&shared.state);
    st.seq += 1;
    st.sock.write_all(payload)?; // finding: blocking under the guard
    st.sock.flush()?; // finding: and again
    Ok(())
}
