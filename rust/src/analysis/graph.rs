//! Whole-program call graph and transitive-blocking inference.
//!
//! Built from every file's [`FileIndex`], the graph records one node
//! per function and resolves call sites with *typed* resolution: a
//! method call `x.f(…)` produces an edge only when the receiver's
//! outer type is known — `self` (the enclosing `impl` type), a
//! `self.field` whose struct declares the field's type, or a typed
//! local/parameter. Unknown receivers produce **no** edge: one junk
//! edge into a blocking fn would poison whole subtrees of the graph,
//! so precision wins over recall. Path calls `Seg::f(…)` resolve via
//! the assoc-fn table when `Seg` is a type (uppercase) and via the
//! free-fn table when it is a module segment; bare calls resolve via
//! the free-fn table.
//!
//! Blocking inference is a fixpoint: seeds are non-offloaded calls to
//! the [`BLOCKING`] names (plus `wait`/`wait_timeout`; `join` only
//! when zero-arg, so `Path::join`/`slice::join` don't count), and a
//! fn becomes blocking when any non-offloaded resolved callee is
//! blocking. Two things cut propagation: pool-offload ranges
//! (`execute`/`spawn` argument bodies, from
//! [`scope::offload_ranges`](crate::analysis::scope::offload_ranges))
//! and fns whose definition line carries a
//! `tq-lint: allow(transitive-blocking)` pragma — a *declared* cut
//! for mode-dispatch shims whose hot path is non-blocking. Each
//! blocking fn remembers why, so findings print the full chain:
//! `on_readable -> flush_shard -> write_frame [blocking: write_all]`.

use crate::analysis::index::{EnumItem, FileIndex, FnItem, StructItem};
use crate::analysis::lexer::{Tok, TokKind};
use crate::analysis::rules::BLOCKING;
use crate::analysis::scope::{in_ranges, offload_ranges};
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};

/// One file's contribution to the graph.
pub struct GraphInput<'a> {
    pub path: &'a str,
    pub toks: &'a [Tok],
    pub index: &'a FileIndex,
    /// Fn-definition lines covered by a `transitive-blocking` pragma.
    pub cuts: &'a BTreeSet<usize>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CallKind {
    /// `recv.name(…)` — resolves only through a typed receiver.
    Method,
    /// `Seg::name(…)`.
    Path,
    /// `name(…)`.
    Free,
}

/// One syntactic call site inside a fn body.
#[derive(Clone, Debug)]
pub struct CallSite {
    pub name: String,
    pub kind: CallKind,
    /// Method receiver ident (`x` in `x.f()`), if it is an ident.
    pub recv: Option<String>,
    /// Ident before the receiver in a `base.recv.f()` chain.
    pub base: Option<String>,
    /// Path segment before `::` for `CallKind::Path`.
    pub qual: Option<String>,
    /// Token index of the callee name.
    pub idx: usize,
    pub line: usize,
    /// Inside a pool `execute(…)`/`spawn(…)` argument list.
    pub offloaded: bool,
    /// `name()` with no arguments (the `join` seed refinement).
    pub zero_arg: bool,
}

/// Why a fn is blocking: a direct seed call, or a resolved edge into
/// another blocking fn.
#[derive(Clone, Debug)]
pub enum Why {
    Seed { call: String },
    Via { call: String, callee: usize },
}

struct Node {
    file: String,
    item: FnItem,
    sites: Vec<CallSite>,
    mentions: BTreeSet<String>,
    cut: bool,
}

/// The program: fn nodes, resolution tables, item lists, and the
/// inferred blocking set.
pub struct Graph {
    nodes: Vec<Node>,
    free: BTreeMap<String, Vec<usize>>,
    assoc: BTreeMap<(String, String), Vec<usize>>,
    fieldtypes: BTreeMap<String, BTreeMap<String, String>>,
    by_body: BTreeMap<(String, usize), usize>,
    structs: Vec<(String, StructItem)>,
    enums: Vec<(String, EnumItem)>,
    blocking: BTreeMap<usize, Why>,
    seeds: usize,
}

fn ident_words_of_str(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for c in text.chars() {
        if c.is_ascii_alphanumeric() || c == '_' {
            cur.push(c);
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Identifier-ish words a fn body mentions: idents plus words inside
/// string literals (serde keys count as plumbing a field).
fn fn_mentions(toks: &[Tok], f: &FnItem) -> BTreeSet<String> {
    let mut words = BTreeSet::new();
    let be = f.body_end.min(toks.len().saturating_sub(1));
    for t in toks.iter().take(be + 1).skip(f.body_start) {
        match t.kind {
            TokKind::Ident => {
                words.insert(t.text.clone());
            }
            TokKind::Str => {
                words.extend(ident_words_of_str(&t.text));
            }
            _ => {}
        }
    }
    words
}

fn call_sites(toks: &[Tok], f: &FnItem) -> Vec<CallSite> {
    let mut sites = Vec::new();
    let off = offload_ranges(toks, f.body_start, f.body_end);
    let be = f.body_end.min(toks.len().saturating_sub(1));
    let mut i = f.body_start + 1;
    while i < be {
        let t = &toks[i];
        let is_call = t.kind == TokKind::Ident && toks[i + 1].text == "(";
        let is_def = i >= 1 && toks[i - 1].kind == TokKind::Ident && toks[i - 1].text == "fn";
        if !is_call || is_def {
            i += 1;
            continue;
        }
        let zero_arg = toks.get(i + 2).is_some_and(|t| t.text == ")");
        let offloaded = in_ranges(i, &off);
        let site = if i >= 1 && toks[i - 1].text == "." {
            let recv = (i >= 2 && toks[i - 2].kind == TokKind::Ident)
                .then(|| toks[i - 2].text.clone());
            let base = (recv.is_some()
                && i >= 4
                && toks[i - 3].text == "."
                && toks[i - 4].kind == TokKind::Ident)
                .then(|| toks[i - 4].text.clone());
            CallSite { name: t.text.clone(), kind: CallKind::Method, recv, base,
                       qual: None, idx: i, line: t.line, offloaded, zero_arg }
        } else if i >= 3
            && toks[i - 1].text == ":"
            && toks[i - 2].text == ":"
            && toks[i - 3].kind == TokKind::Ident
        {
            CallSite { name: t.text.clone(), kind: CallKind::Path, recv: None,
                       base: None, qual: Some(toks[i - 3].text.clone()), idx: i,
                       line: t.line, offloaded, zero_arg }
        } else {
            CallSite { name: t.text.clone(), kind: CallKind::Free, recv: None,
                       base: None, qual: None, idx: i, line: t.line, offloaded,
                       zero_arg }
        };
        sites.push(site);
        i += 1;
    }
    sites
}

impl Graph {
    /// Build the graph over a set of indexed files and run the
    /// blocking fixpoint.
    pub fn build(inputs: &[GraphInput]) -> Graph {
        let mut g = Graph {
            nodes: Vec::new(),
            free: BTreeMap::new(),
            assoc: BTreeMap::new(),
            fieldtypes: BTreeMap::new(),
            by_body: BTreeMap::new(),
            structs: Vec::new(),
            enums: Vec::new(),
            blocking: BTreeMap::new(),
            seeds: 0,
        };
        for inp in inputs {
            for f in &inp.index.fns {
                let id = g.nodes.len();
                match &f.impl_type {
                    Some(t) => g
                        .assoc
                        .entry((t.clone(), f.name.clone()))
                        .or_default()
                        .push(id),
                    None => g.free.entry(f.name.clone()).or_default().push(id),
                }
                g.by_body.insert((inp.path.to_string(), f.body_start), id);
                g.nodes.push(Node {
                    file: inp.path.to_string(),
                    item: f.clone(),
                    sites: call_sites(inp.toks, f),
                    mentions: fn_mentions(inp.toks, f),
                    cut: inp.cuts.contains(&f.line),
                });
            }
            for s in &inp.index.structs {
                let m = g.fieldtypes.entry(s.name.clone()).or_default();
                for fl in &s.fields {
                    if let Some(ty) = &fl.ty {
                        m.insert(fl.name.clone(), ty.clone());
                    }
                }
                g.structs.push((inp.path.to_string(), s.clone()));
            }
            for e in &inp.index.enums {
                g.enums.push((inp.path.to_string(), e.clone()));
            }
        }
        g.seed();
        g.propagate();
        g
    }

    fn seed(&mut self) {
        let mut seeded = Vec::new();
        for (id, node) in self.nodes.iter().enumerate() {
            if node.cut {
                continue;
            }
            for s in &node.sites {
                if s.offloaded {
                    continue;
                }
                let nm = s.name.as_str();
                let seedy =
                    BLOCKING.contains(&nm) || nm == "wait" || nm == "wait_timeout";
                if !seedy || (nm == "join" && !s.zero_arg) {
                    continue;
                }
                seeded.push((id, nm.to_string()));
                break;
            }
        }
        self.seeds = seeded.len();
        for (id, call) in seeded {
            self.blocking.insert(id, Why::Seed { call });
        }
    }

    fn propagate(&mut self) {
        let mut changed = true;
        while changed {
            changed = false;
            for id in 0..self.nodes.len() {
                if self.blocking.contains_key(&id) || self.nodes[id].cut {
                    continue;
                }
                let mut found = None;
                'sites: for s in &self.nodes[id].sites {
                    if s.offloaded {
                        continue;
                    }
                    for tgt in self.resolve(s, id) {
                        if tgt != id && self.blocking.contains_key(&tgt) {
                            found = Some((s.name.clone(), tgt));
                            break 'sites;
                        }
                    }
                }
                if let Some((call, callee)) = found {
                    self.blocking.insert(id, Why::Via { call, callee });
                    changed = true;
                }
            }
        }
    }

    /// Callee candidates for one site; empty when the receiver type is
    /// unknown.
    fn resolve(&self, site: &CallSite, caller: usize) -> Vec<usize> {
        let c = &self.nodes[caller].item;
        let assoc = |ty: &str| -> Vec<usize> {
            self.assoc
                .get(&(ty.to_string(), site.name.clone()))
                .cloned()
                .unwrap_or_default()
        };
        let free = || self.free.get(&site.name).cloned().unwrap_or_default();
        match site.kind {
            CallKind::Method => {
                let recv = match &site.recv {
                    Some(r) => r.as_str(),
                    None => return Vec::new(),
                };
                if recv == "self" {
                    return assoc(c.impl_type.as_deref().unwrap_or("?"));
                }
                let rtype = if site.base.as_deref() == Some("self") {
                    c.impl_type
                        .as_ref()
                        .and_then(|t| self.fieldtypes.get(t))
                        .and_then(|m| m.get(recv))
                } else if site.base.is_none() {
                    c.locals.get(recv).or_else(|| c.params.get(recv))
                } else {
                    None
                };
                match rtype {
                    Some(t) => assoc(t),
                    None => Vec::new(),
                }
            }
            CallKind::Path => {
                let q = site.qual.as_deref().unwrap_or("");
                let q = if q == "Self" || q == "self" {
                    c.impl_type.as_deref().unwrap_or("?")
                } else {
                    q
                };
                if q.chars().next().is_some_and(|ch| ch.is_ascii_uppercase()) {
                    assoc(q)
                } else {
                    free()
                }
            }
            CallKind::Free => free(),
        }
    }

    /// Graph node for the fn whose body opens at `(file, body_start)` —
    /// the join key with [`scope::functions`](crate::analysis::scope::functions).
    pub fn fn_id(&self, file: &str, body_start: usize) -> Option<usize> {
        self.by_body.get(&(file.to_string(), body_start)).copied()
    }

    pub fn is_blocking(&self, id: usize) -> bool {
        self.blocking.contains_key(&id)
    }

    /// If the call at token `tok_idx` inside fn `caller` resolves to an
    /// inferred-blocking fn, the blocking chain starting at the callee.
    pub fn blocking_chain(&self, caller: usize, tok_idx: usize) -> Option<String> {
        let node = self.nodes.get(caller)?;
        let site = node.sites.iter().find(|s| s.idx == tok_idx)?;
        if site.offloaded {
            return None;
        }
        let callee = self
            .resolve(site, caller)
            .into_iter()
            .find(|t| self.blocking.contains_key(t))?;
        Some(self.chain(callee))
    }

    /// Render `qual -> qual -> … [blocking: seed]` for a blocking fn.
    pub fn chain(&self, mut id: usize) -> String {
        let mut parts = vec![self.nodes[id].item.qual()];
        for _ in 0..8 {
            match self.blocking.get(&id) {
                None => {
                    parts.push("?".to_string());
                    break;
                }
                Some(Why::Seed { call }) => {
                    parts.push(format!("[blocking: {call}]"));
                    break;
                }
                Some(Why::Via { callee, .. }) => {
                    parts.push(self.nodes[*callee].item.qual());
                    id = *callee;
                }
            }
        }
        parts.join(" -> ")
    }

    /// Fn ids matching a registry spec: `Type::name` via the assoc
    /// table, bare `name` via the free table.
    pub fn resolve_spec(&self, spec: &str) -> Vec<usize> {
        match spec.split_once("::") {
            Some((ty, nm)) => self
                .assoc
                .get(&(ty.to_string(), nm.to_string()))
                .cloned()
                .unwrap_or_default(),
            None => self.free.get(spec).cloned().unwrap_or_default(),
        }
    }

    pub fn mentions(&self, id: usize) -> &BTreeSet<String> {
        &self.nodes[id].mentions
    }

    pub fn structs(&self) -> &[(String, StructItem)] {
        &self.structs
    }

    pub fn enums(&self) -> &[(String, EnumItem)] {
        &self.enums
    }

    pub fn fn_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn blocking_count(&self) -> usize {
        self.blocking.len()
    }

    /// Serialize nodes + resolved edges for `tq-dit lint --graph-json`.
    pub fn to_json(&self) -> Json {
        let mut nodes = Vec::new();
        let mut edges = Vec::new();
        for (id, node) in self.nodes.iter().enumerate() {
            let mut o = BTreeMap::new();
            o.insert("id".to_string(), Json::Num(id as f64));
            o.insert("fn".to_string(), Json::Str(node.item.qual()));
            o.insert("file".to_string(), Json::Str(node.file.clone()));
            o.insert("line".to_string(), Json::Num(node.item.line as f64));
            o.insert("method".to_string(), Json::Bool(node.item.has_self));
            o.insert("blocking".to_string(), Json::Bool(self.is_blocking(id)));
            if node.cut {
                o.insert("cut".to_string(), Json::Bool(true));
            }
            if self.is_blocking(id) {
                o.insert("chain".to_string(), Json::Str(self.chain(id)));
            }
            nodes.push(Json::Obj(o));
            for s in &node.sites {
                for tgt in self.resolve(s, id) {
                    let mut e = BTreeMap::new();
                    e.insert("from".to_string(), Json::Num(id as f64));
                    e.insert("to".to_string(), Json::Num(tgt as f64));
                    e.insert("call".to_string(), Json::Str(s.name.clone()));
                    e.insert("line".to_string(), Json::Num(s.line as f64));
                    if s.offloaded {
                        e.insert("offloaded".to_string(), Json::Bool(true));
                    }
                    edges.push(Json::Obj(e));
                }
            }
        }
        let mut counts = BTreeMap::new();
        counts.insert("fns".to_string(), Json::Num(self.nodes.len() as f64));
        counts.insert("edges".to_string(), Json::Num(edges.len() as f64));
        counts.insert("seeds".to_string(), Json::Num(self.seeds as f64));
        counts.insert(
            "blocking".to_string(),
            Json::Num(self.blocking.len() as f64),
        );
        let mut top = BTreeMap::new();
        top.insert("nodes".to_string(), Json::Arr(nodes));
        top.insert("edges".to_string(), Json::Arr(edges));
        top.insert("counts".to_string(), Json::Obj(counts));
        Json::Obj(top)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::index::index_file;
    use crate::analysis::lexer::lex;
    use crate::analysis::scope::code_tokens;

    fn graph_of(src: &str) -> (Graph, Vec<Tok>, FileIndex) {
        let toks = code_tokens(&lex(src));
        let index = index_file(&toks);
        let cuts = BTreeSet::new();
        let g = Graph::build(&[GraphInput { path: "t.rs", toks: &toks, index: &index, cuts: &cuts }]);
        (g, toks, index)
    }

    fn id_of(g: &Graph, ix: &FileIndex, name: &str) -> usize {
        let f = ix.fns.iter().find(|f| f.qual() == name).unwrap();
        g.fn_id("t.rs", f.body_start).unwrap()
    }

    #[test]
    fn cycle_terminates_and_both_sides_block() {
        let src = "
            fn ping(sock: &mut Conn) { pong(sock); }
            fn pong(sock: &mut Conn) { ping(sock); leak(sock); }
            fn leak(sock: &mut Conn) { sock_write(); }
            fn sock_write() { write_all(); }
        ";
        let (g, _t, ix) = graph_of(src);
        for f in ["ping", "pong", "leak", "sock_write"] {
            assert!(g.is_blocking(id_of(&g, &ix, f)), "{f} should block");
        }
        let chain = g.chain(id_of(&g, &ix, "leak"));
        assert_eq!(chain, "leak -> sock_write -> [blocking: write_all]");
    }

    #[test]
    fn offload_ranges_cut_propagation() {
        let src = "
            fn hot(pool: &ThreadPool) { pool.execute(move || { cold(); }); }
            fn cold() { flush(); }
        ";
        let (g, _t, ix) = graph_of(src);
        assert!(g.is_blocking(id_of(&g, &ix, "cold")));
        assert!(!g.is_blocking(id_of(&g, &ix, "hot")), "offloaded call must not propagate");
    }

    #[test]
    fn method_and_free_fn_with_same_name_resolve_separately() {
        let src = "
            struct Quiet { n: u32 }
            impl Quiet { fn poke(&self) { self.n; } }
            fn poke() { write_all(); }
            fn uses_method(q: &Quiet) { q.poke(); }
            fn uses_free() { poke(); }
            fn unknown_receiver(q: &Mystery) { q.poke(); }
        ";
        let (g, _t, ix) = graph_of(src);
        assert!(!g.is_blocking(id_of(&g, &ix, "uses_method")), "typed receiver picks Quiet::poke");
        assert!(g.is_blocking(id_of(&g, &ix, "uses_free")), "free call picks the blocking free fn");
        // Mystery has no struct def: receiver type unknown -> no edge
        assert!(!g.is_blocking(id_of(&g, &ix, "unknown_receiver")));
    }

    #[test]
    fn join_seed_requires_zero_args() {
        let src = "
            fn thread_join(h: Handle) { h.join(); }
            fn path_join(p: &Path) { p.join(\"x\"); }
        ";
        let (g, _t, ix) = graph_of(src);
        assert!(g.is_blocking(id_of(&g, &ix, "thread_join")));
        assert!(!g.is_blocking(id_of(&g, &ix, "path_join")));
    }

    #[test]
    fn declared_cut_stops_propagation() {
        let src = "
            fn dispatch(conn: &mut Conn) { slow_path(conn); }
            fn slow_path(conn: &mut Conn) { write_all(); }
            fn caller(conn: &mut Conn) { dispatch(conn); }
        ";
        let toks = code_tokens(&lex(src));
        let index = index_file(&toks);
        let cut_line = index.fns.iter().find(|f| f.name == "dispatch").unwrap().line;
        let cuts: BTreeSet<usize> = [cut_line].into_iter().collect();
        let g = Graph::build(&[GraphInput { path: "t.rs", toks: &toks, index: &index, cuts: &cuts }]);
        let id = |name: &str| {
            let f = index.fns.iter().find(|f| f.qual() == name).unwrap();
            g.fn_id("t.rs", f.body_start).unwrap()
        };
        assert!(g.is_blocking(id("slow_path")));
        assert!(!g.is_blocking(id("dispatch")), "cut fn is never marked blocking");
        assert!(!g.is_blocking(id("caller")), "cut stops the chain to callers");
    }

    #[test]
    fn self_field_receiver_uses_struct_field_type() {
        let src = "
            struct Writer { n: u32 }
            impl Writer { fn put(&self) { write_all(); } }
            struct Front { out: Writer }
            impl Front { fn push(&self) { self.out.put(); } }
        ";
        let (g, _t, ix) = graph_of(src);
        assert!(g.is_blocking(id_of(&g, &ix, "Front::push")));
        let chain = g.chain(id_of(&g, &ix, "Front::push"));
        assert_eq!(chain, "Front::push -> Writer::put -> [blocking: write_all]");
    }
}
