//! Per-file item extraction — the symbol layer under the call graph.
//!
//! [`index_file`] walks one file's comment-free token stream (the same
//! stream the rules see) and records every function definition (with
//! its enclosing `impl` type, receiver-ness, parameter types and
//! typed local bindings), struct (with per-field declared outer
//! types), and enum (with its variants). Test regions are skipped the
//! same way [`crate::analysis::scope::functions`] skips them, so the
//! fn list here lines up one-to-one with the rule engine's
//! [`FnBody`](crate::analysis::scope::FnBody) list — the graph keys
//! fns by `(file, body_start)` on the strength of that alignment.
//!
//! Types are recorded as *outer* names only (`Vec<WorkerStats>` →
//! `Vec`, `&mut ShardConn` → `ShardConn`, `Arc<Mutex<T>>` → `Arc`):
//! the call graph resolves a method call only when the receiver's
//! outer type names an `impl` block in this crate, and an outer std
//! wrapper simply resolves to nothing — precision over recall.

use crate::analysis::lexer::{Tok, TokKind};
use crate::analysis::scope::{in_regions, match_brace, test_regions};
use std::collections::BTreeMap;

fn is_punct(t: &Tok, p: &str) -> bool {
    t.kind == TokKind::Punct && t.text == p
}

/// One function definition.
#[derive(Clone, Debug)]
pub struct FnItem {
    pub name: String,
    /// Enclosing `impl` block's type name (`impl Foo {` /
    /// `impl Trait for Foo {` → `Foo`); `None` for free fns and trait
    /// declaration bodies.
    pub impl_type: Option<String>,
    /// Whether the parameter list contains `self`.
    pub has_self: bool,
    /// Line of the name token.
    pub line: usize,
    /// Inclusive token range of the `{ … }` body.
    pub body_start: usize,
    pub body_end: usize,
    /// Parameter name → declared outer type.
    pub params: BTreeMap<String, String>,
    /// `let`-bound local → outer type, from explicit `let x: T`
    /// annotations and `let x = Type::ctor(..)` / `let x = Type { .. }`
    /// initializers.
    pub locals: BTreeMap<String, String>,
}

impl FnItem {
    /// `Type::name` for methods/assoc fns, bare `name` otherwise.
    pub fn qual(&self) -> String {
        match &self.impl_type {
            Some(t) => format!("{t}::{}", self.name),
            None => self.name.clone(),
        }
    }
}

/// One struct field (or, reused, one enum variant — `ty` then `None`).
#[derive(Clone, Debug)]
pub struct Field {
    pub name: String,
    pub line: usize,
    pub ty: Option<String>,
}

#[derive(Clone, Debug)]
pub struct StructItem {
    pub name: String,
    pub line: usize,
    pub fields: Vec<Field>,
}

#[derive(Clone, Debug)]
pub struct EnumItem {
    pub name: String,
    pub line: usize,
    pub variants: Vec<Field>,
}

/// Everything extracted from one file.
#[derive(Clone, Debug, Default)]
pub struct FileIndex {
    pub fns: Vec<FnItem>,
    pub structs: Vec<StructItem>,
    pub enums: Vec<EnumItem>,
}

/// Index past a generic parameter list: `toks[i]` may be `<`; returns
/// the index just past the matching `>` (a `->` never closes —
/// `impl<F: Fn() -> T>` stays balanced).
fn skip_generics(toks: &[Tok], i: usize) -> usize {
    let n = toks.len();
    if i >= n || toks[i].text != "<" {
        return i;
    }
    let mut depth = 0i32;
    let mut j = i;
    while j < n {
        let t = &toks[j];
        if t.text == "<" {
            depth += 1;
        } else if t.text == ">" && !(j >= 1 && toks[j - 1].text == "-") {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    n
}

/// Outermost type name of the type starting at `toks[i]` (after the
/// `:` of a field/param/let): skip `&`, lifetimes, `mut`, `dyn`; then
/// the first ident path's last segment. `[u8; 4]`, `(A, B)` and
/// `impl Trait` yield `None`.
pub fn outer_type(toks: &[Tok], i: usize, end: usize) -> Option<String> {
    let end = end.min(toks.len());
    let mut j = i;
    while j < end {
        let t = &toks[j];
        let skip = t.kind == TokKind::Lifetime
            || (t.kind == TokKind::Ident && (t.text == "mut" || t.text == "dyn"))
            || t.text == "&";
        if !skip {
            break;
        }
        j += 1;
    }
    if j >= end || toks[j].kind != TokKind::Ident || toks[j].text == "impl" {
        return None;
    }
    let mut last = toks[j].text.clone();
    j += 1;
    while j + 1 < end && toks[j].text == ":" && toks[j + 1].text == ":" {
        j += 2;
        match toks.get(j) {
            Some(t) if t.kind == TokKind::Ident => {
                last = t.text.clone();
                j += 1;
            }
            _ => break,
        }
    }
    Some(last)
}

/// `impl` blocks: `(type name, open brace idx, close brace idx)`.
fn impl_ranges(toks: &[Tok], skip: &[(usize, usize)]) -> Vec<(String, usize, usize)> {
    let n = toks.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        if in_regions(i, skip) || !(t.kind == TokKind::Ident && t.text == "impl") {
            i += 1;
            continue;
        }
        let mut j = skip_generics(toks, i + 1);
        // header path idents to the `{` at angle depth 0; `for` starts
        // the implemented-on type (`impl Trait for Foo`)
        let mut depth = 0i32;
        let mut segs: Vec<String> = Vec::new();
        let mut after_for: Option<Vec<String>> = None;
        while j < n {
            let tj = &toks[j];
            if tj.text == "<" {
                depth += 1;
            } else if tj.text == ">" && !(j >= 1 && toks[j - 1].text == "-") {
                depth -= 1;
            } else if depth == 0 && tj.text == "{" {
                break;
            } else if depth == 0 && tj.kind == TokKind::Ident {
                if tj.text == "for" {
                    after_for = Some(Vec::new());
                } else if tj.text != "where" {
                    match &mut after_for {
                        Some(v) => v.push(tj.text.clone()),
                        None => segs.push(tj.text.clone()),
                    }
                }
            }
            j += 1;
        }
        if j >= n {
            break;
        }
        let path = match &after_for {
            Some(v) if !v.is_empty() => v,
            _ => &segs,
        };
        let ty = path.last().cloned().unwrap_or_else(|| "?".to_string());
        out.push((ty, j, match_brace(toks, j)));
        i = j + 1;
    }
    out
}

/// Innermost impl type containing token index `idx`.
fn impl_type_at(ranges: &[(String, usize, usize)], idx: usize) -> Option<String> {
    let mut best: Option<(&str, usize)> = None;
    for (ty, a, b) in ranges {
        if *a <= idx && idx <= *b && best.map_or(true, |(_, ba)| *a > ba) {
            best = Some((ty, *a));
        }
    }
    best.map(|(t, _)| t.to_string())
}

fn index_fns(toks: &[Tok], skip: &[(usize, usize)],
             impls: &[(String, usize, usize)]) -> Vec<FnItem> {
    let n = toks.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < n {
        if in_regions(i, skip) {
            i += 1;
            continue;
        }
        let t = &toks[i];
        let named = t.kind == TokKind::Ident
            && t.text == "fn"
            && i + 1 < n
            && toks[i + 1].kind == TokKind::Ident;
        if !named {
            i += 1;
            continue;
        }
        let name = toks[i + 1].text.clone();
        let mut k = i + 2;
        while k < n && !(is_punct(&toks[k], "{") || is_punct(&toks[k], ";")) {
            k += 1;
        }
        if !(k < n && toks[k].text == "{") {
            // trait declaration without a body
            i = k;
            continue;
        }
        // parameters: the first `( … )` after the name
        let mut has_self = false;
        let mut params = BTreeMap::new();
        let mut p = i + 2;
        while p < k && toks[p].text != "(" {
            p += 1;
        }
        if p < k {
            let mut d = 0i32;
            let mut q = p;
            while q < k {
                let tq = &toks[q];
                if tq.text == "(" {
                    d += 1;
                } else if tq.text == ")" {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                } else if d == 1 && tq.kind == TokKind::Ident {
                    if tq.text == "self" {
                        has_self = true;
                    } else if toks.get(q + 1).is_some_and(|t| t.text == ":")
                        && toks.get(q + 2).map_or(true, |t| t.text != ":")
                    {
                        if let Some(ty) = outer_type(toks, q + 2, k) {
                            params.insert(tq.text.clone(), ty);
                        }
                    }
                }
                q += 1;
            }
        }
        let body_start = k;
        let body_end = match_brace(toks, k);
        // typed locals inside the body
        let mut locals = BTreeMap::new();
        let mut q = body_start;
        while q < body_end.min(n) {
            if toks[q].kind == TokKind::Ident && toks[q].text == "let" {
                let mut gi = q + 1;
                if toks.get(gi).is_some_and(|t| t.text == "mut") {
                    gi += 1;
                }
                if toks.get(gi).is_some_and(|t| t.kind == TokKind::Ident) {
                    let vname = toks[gi].text.clone();
                    let mut ty = None;
                    if toks.get(gi + 1).is_some_and(|t| t.text == ":")
                        && toks.get(gi + 2).map_or(true, |t| t.text != ":")
                    {
                        ty = outer_type(toks, gi + 2, body_end.min(n));
                    } else if toks.get(gi + 1).is_some_and(|t| t.text == "=") {
                        // `let x = Type { .. }` / `let x = Type::ctor(..)`
                        let ctor = toks.get(gi + 2).is_some_and(|t| {
                            t.kind == TokKind::Ident
                                && t.text.chars().next().is_some_and(|c| c.is_ascii_uppercase())
                        });
                        let shaped = toks.get(gi + 3).is_some_and(|t| t.text == "{")
                            || (toks.get(gi + 3).is_some_and(|t| t.text == ":")
                                && toks.get(gi + 4).is_some_and(|t| t.text == ":"));
                        if ctor && shaped {
                            ty = Some(toks[gi + 2].text.clone());
                        }
                    }
                    if let Some(ty) = ty {
                        locals.insert(vname, ty);
                    }
                }
            }
            q += 1;
        }
        out.push(FnItem {
            name,
            impl_type: impl_type_at(impls, i),
            has_self,
            line: toks[i + 1].line,
            body_start,
            body_end,
            params,
            locals,
        });
        i += 2;
    }
    out
}

fn skip_attr(toks: &[Tok], mut k: usize, close: usize) -> usize {
    // `toks[k]` is `#`; returns the index past the matching `]`
    let mut d = 0i32;
    while k < close {
        if toks[k].text == "[" {
            d += 1;
        } else if toks[k].text == "]" {
            d -= 1;
            if d == 0 {
                return k + 1;
            }
        }
        k += 1;
    }
    k
}

fn index_structs(toks: &[Tok], skip: &[(usize, usize)]) -> Vec<StructItem> {
    let n = toks.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        let named = t.kind == TokKind::Ident
            && t.text == "struct"
            && i + 1 < n
            && toks[i + 1].kind == TokKind::Ident;
        if in_regions(i, skip) || !named {
            i += 1;
            continue;
        }
        let name = toks[i + 1].text.clone();
        let line = toks[i + 1].line;
        let mut j = skip_generics(toks, i + 2);
        // run (past a possible where clause) to `{`, `;` or tuple `(`
        let mut d = 0i32;
        while j < n {
            let tj = &toks[j];
            if tj.text == "<" {
                d += 1;
            } else if tj.text == ">" && !(j >= 1 && toks[j - 1].text == "-") {
                d -= 1;
            } else if d == 0 && (tj.text == "{" || tj.text == ";" || tj.text == "(") {
                break;
            }
            j += 1;
        }
        if !(j < n && toks[j].text == "{") {
            // unit/tuple struct: no named fields to track
            i = if j > i { j } else { i + 1 };
            continue;
        }
        let close = match_brace(toks, j);
        let mut fields = Vec::new();
        let mut k = j + 1;
        while k < close {
            if toks[k].text == "#" && toks.get(k + 1).is_some_and(|t| t.text == "[") {
                k = skip_attr(toks, k, close);
                continue;
            }
            if toks[k].kind == TokKind::Ident && toks[k].text == "pub" {
                k += 1;
                if toks.get(k).is_some_and(|t| t.text == "(") {
                    // pub(crate) & friends
                    let mut d2 = 0i32;
                    while k < close {
                        if toks[k].text == "(" {
                            d2 += 1;
                        } else if toks[k].text == ")" {
                            d2 -= 1;
                            if d2 == 0 {
                                break;
                            }
                        }
                        k += 1;
                    }
                    k += 1;
                }
                continue;
            }
            if toks[k].kind == TokKind::Ident
                && toks.get(k + 1).is_some_and(|t| t.text == ":")
            {
                fields.push(Field {
                    name: toks[k].text.clone(),
                    line: toks[k].line,
                    ty: outer_type(toks, k + 2, close),
                });
                // skip the field's type to the `,` at depth 0
                let mut d2 = 0i32;
                let mut ang = 0i32;
                k += 2;
                while k < close {
                    let tk = &toks[k];
                    if tk.text == "(" || tk.text == "[" || tk.text == "{" {
                        d2 += 1;
                    } else if tk.text == ")" || tk.text == "]" || tk.text == "}" {
                        d2 -= 1;
                    } else if tk.text == "<" {
                        ang += 1;
                    } else if tk.text == ">" && !(k >= 1 && toks[k - 1].text == "-") {
                        ang -= 1;
                    } else if tk.text == "," && d2 == 0 && ang <= 0 {
                        break;
                    }
                    k += 1;
                }
                k += 1;
                continue;
            }
            k += 1;
        }
        out.push(StructItem { name, line, fields });
        i = close + 1;
    }
    out
}

fn index_enums(toks: &[Tok], skip: &[(usize, usize)]) -> Vec<EnumItem> {
    let n = toks.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        let named = t.kind == TokKind::Ident
            && t.text == "enum"
            && i + 1 < n
            && toks[i + 1].kind == TokKind::Ident;
        if in_regions(i, skip) || !named {
            i += 1;
            continue;
        }
        let name = toks[i + 1].text.clone();
        let line = toks[i + 1].line;
        let mut j = skip_generics(toks, i + 2);
        while j < n && toks[j].text != "{" {
            j += 1;
        }
        if j >= n {
            break;
        }
        let close = match_brace(toks, j);
        let mut variants = Vec::new();
        let mut expect = true;
        let mut d = 0i32;
        let mut k = j + 1;
        while k < close {
            let tk = &toks[k];
            if expect && d == 0 && tk.text == "#"
                && toks.get(k + 1).is_some_and(|t| t.text == "[")
            {
                k = skip_attr(toks, k, close);
                continue;
            }
            if tk.text == "(" || tk.text == "[" || tk.text == "{" {
                d += 1;
            } else if tk.text == ")" || tk.text == "]" || tk.text == "}" {
                d -= 1;
            } else if d == 0 && tk.text == "," {
                expect = true;
                k += 1;
                continue;
            }
            if expect && d == 0 && tk.kind == TokKind::Ident {
                variants.push(Field { name: tk.text.clone(), line: tk.line, ty: None });
                expect = false;
            }
            k += 1;
        }
        out.push(EnumItem { name, line, variants });
        i = close + 1;
    }
    out
}

/// Index one file's comment-free token stream.
pub fn index_file(toks: &[Tok]) -> FileIndex {
    let skip = test_regions(toks);
    let impls = impl_ranges(toks, &skip);
    FileIndex {
        fns: index_fns(toks, &skip, &impls),
        structs: index_structs(toks, &skip),
        enums: index_enums(toks, &skip),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;
    use crate::analysis::scope::code_tokens;

    fn idx(src: &str) -> FileIndex {
        index_file(&code_tokens(&lex(src)))
    }

    #[test]
    fn fns_get_impl_context_and_param_types() {
        let src = "
            fn free_one(n: usize, conn: &mut ShardConn) {}
            struct Foo { cache: CalibCache, items: Vec<WorkerStats> }
            impl Foo {
                fn method(&self, x: u32) { let c = NetClient::connect(); }
            }
            impl std::fmt::Display for Foo {
                fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                    Ok(())
                }
            }
            trait T { fn decl(&self); }
        ";
        let ix = idx(src);
        let names: Vec<String> = ix.fns.iter().map(|f| f.qual()).collect();
        assert_eq!(names, ["free_one", "Foo::method", "Foo::fmt"]);
        assert_eq!(ix.fns[0].params.get("conn").map(String::as_str), Some("ShardConn"));
        assert!(ix.fns[1].has_self);
        assert_eq!(ix.fns[1].locals.get("c").map(String::as_str), Some("NetClient"));
        let foo = &ix.structs[0];
        assert_eq!(foo.fields[0].ty.as_deref(), Some("CalibCache"));
        // outer type only: Vec<WorkerStats> must NOT type the field as
        // WorkerStats (a `.push()` on it is a Vec method)
        assert_eq!(foo.fields[1].ty.as_deref(), Some("Vec"));
    }

    #[test]
    fn struct_fields_and_enum_variants_with_attrs() {
        let src = "
            pub struct Stats {
                pub requests: u64,
                #[allow(dead_code)]
                latency: Hist,
                pub(crate) map: BTreeMap<String, u64>,
            }
            enum Msg {
                Hello { peer: String },
                #[allow(dead_code)]
                Ping(u64),
                Stop,
            }
        ";
        let ix = idx(src);
        let fields: Vec<&str> =
            ix.structs[0].fields.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(fields, ["requests", "latency", "map"]);
        let variants: Vec<&str> =
            ix.enums[0].variants.iter().map(|v| v.name.as_str()).collect();
        assert_eq!(variants, ["Hello", "Ping", "Stop"]);
    }

    #[test]
    fn test_regions_are_not_indexed() {
        let src = "
            fn prod() {}
            #[cfg(test)]
            mod tests {
                fn helper() {}
                struct Fake { x: u32 }
            }
        ";
        let ix = idx(src);
        assert_eq!(ix.fns.len(), 1);
        assert!(ix.structs.is_empty());
    }

    #[test]
    fn outer_type_strips_refs_and_paths() {
        let toks = code_tokens(&lex("&'a mut crate::serve::net::NetClient"));
        assert_eq!(outer_type(&toks, 0, toks.len()).as_deref(), Some("NetClient"));
        let toks = code_tokens(&lex("[u8; 4]"));
        assert_eq!(outer_type(&toks, 0, toks.len()), None);
    }
}
