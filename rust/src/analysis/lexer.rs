//! Hand-rolled Rust lexer for the lint pass — just enough token
//! structure for the rules in [`super::rules`], none of the grammar.
//!
//! The hard parts a line-based grep gets wrong, handled here:
//!
//! * strings (`"unwrap()"` inside a string is *text*, not a call),
//!   including escapes and raw strings `r#"…"#` with any hash depth,
//!   and byte-string variants `b"…"` / `br"…"`;
//! * nested block comments (`/* /* */ */` — Rust nests them, C does
//!   not);
//! * `'a` lifetimes vs `'x'` char literals vs `'\n'` escaped chars;
//! * line comments, which the pragma parser reads *as tokens* (the
//!   rules themselves only ever see the comment-free stream).
//!
//! Numbers, idents and single-char punctuation are enough structure
//! for brace matching and call-shape checks; multi-char operators stay
//! as individual punct tokens (`=>` is `=` then `>`), which the rules
//! account for.

/// Token class. `LineComment`/`BlockComment` only survive into the raw
/// stream handed to the pragma parser; rules run on
/// [`code_tokens`](super::scope::code_tokens) output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
    LineComment,
    BlockComment,
}

/// One lexed token: kind, verbatim text, 1-based line of its first
/// character.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_ascii_alphabetic()
}

fn is_ident_cont(c: char) -> bool {
    c == '_' || c.is_ascii_alphanumeric()
}

/// Lex `src` into a flat token stream. Total: any input produces some
/// token stream — unterminated strings/comments run to end of input
/// rather than erroring, which is the right behavior for a linter that
/// must never take the build down with it.
pub fn lex(src: &str) -> Vec<Tok> {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let text = |a: usize, b: usize| -> String { s[a..b.min(n)].iter().collect() };
    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && i + 1 < n && s[i + 1] == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::LineComment, text: text(i, j), line });
            i = j;
            continue;
        }
        // block comment, nesting-aware
        if c == '/' && i + 1 < n && s[i + 1] == '*' {
            let (start, l0) = (i, line);
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if s[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if s[j] == '/' && j + 1 < n && s[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if s[j] == '*' && j + 1 < n && s[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            toks.push(Tok { kind: TokKind::BlockComment, text: text(start, j), line: l0 });
            i = j;
            continue;
        }
        // raw / byte string prefixes: r"…", r#"…"#, br"…", b"…", b'x'
        if c == 'r' || c == 'b' {
            let mut j = i;
            let isb = s[j] == 'b';
            if isb {
                j += 1;
            }
            if j < n && s[j] == 'r' {
                j += 1;
                let mut hashes = 0usize;
                while j < n && s[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && s[j] == '"' {
                    // raw string: runs to `"` followed by `hashes` #s
                    j += 1;
                    let l0 = line;
                    let end = loop {
                        if j >= n {
                            break n;
                        }
                        if s[j] == '"' && s[j + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes
                            && j + 1 + hashes <= n
                        {
                            break j;
                        }
                        if s[j] == '\n' {
                            line += 1;
                        }
                        j += 1;
                    };
                    let stop = (end + 1 + hashes).min(n);
                    toks.push(Tok { kind: TokKind::Str, text: text(i, stop), line: l0 });
                    i = stop;
                    continue;
                }
                // `r` / `br` not followed by a string: re-lex as ident
                // below (fall through with i unchanged)
            } else if isb && j < n && (s[j] == '"' || s[j] == '\'') {
                // cooked byte string / byte char with escapes
                let q = s[j];
                let l0 = line;
                let mut k = j + 1;
                while k < n {
                    if s[k] == '\\' {
                        k += 2;
                        continue;
                    }
                    if s[k] == '\n' {
                        line += 1;
                    }
                    if s[k] == q {
                        k += 1;
                        break;
                    }
                    k += 1;
                }
                let kind = if q == '"' { TokKind::Str } else { TokKind::Char };
                toks.push(Tok { kind, text: text(i, k), line: l0 });
                i = k;
                continue;
            }
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(s[j]) {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, text: text(i, j), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (is_ident_cont(s[j]) || s[j] == '.') {
                // stop before `..` so ranges like `2..10` stay punct
                if s[j] == '.' && j + 1 < n && s[j + 1] == '.' {
                    break;
                }
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Num, text: text(i, j), line });
            i = j;
            continue;
        }
        if c == '"' {
            let l0 = line;
            let mut j = i + 1;
            while j < n {
                if s[j] == '\\' {
                    j += 2;
                    continue;
                }
                if s[j] == '\n' {
                    line += 1;
                }
                if s[j] == '"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Str, text: text(i, j), line: l0 });
            i = j;
            continue;
        }
        if c == '\'' {
            // escaped char `'\n'`
            if i + 1 < n && s[i + 1] == '\\' {
                let mut j = i + 2;
                if j < n {
                    j += 1; // the escaped character itself
                }
                while j < n && s[j] != '\'' {
                    j += 1;
                }
                j = (j + 1).min(n);
                toks.push(Tok { kind: TokKind::Char, text: text(i, j), line });
                i = j;
                continue;
            }
            // `'x'` — any single non-quote char then a closing quote
            if i + 2 < n && s[i + 1] != '\'' && s[i + 2] == '\'' {
                toks.push(Tok { kind: TokKind::Char, text: text(i, i + 3), line });
                i += 3;
                continue;
            }
            // otherwise a lifetime: `'` + ident chars
            let mut j = i + 1;
            while j < n && is_ident_cont(s[j]) {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Lifetime, text: text(i, j), line });
            i = j;
            continue;
        }
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    toks
}
