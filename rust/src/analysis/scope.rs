//! Structure recovery over the token stream: brace matching, test
//! region discovery, per-function body ranges, statement boundaries.
//!
//! Everything here works on *indices into the comment-free token
//! list* — a (start, end) pair is an inclusive token range, not a byte
//! range. The rules never re-scan source text.

use crate::analysis::lexer::{Tok, TokKind};

/// Drop comment tokens; rules operate on this stream (pragmas are read
/// from the raw stream separately).
pub fn code_tokens(toks: &[Tok]) -> Vec<Tok> {
    toks.iter()
        .filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
        .cloned()
        .collect()
}

fn is_punct(t: &Tok, p: &str) -> bool {
    t.kind == TokKind::Punct && t.text == p
}

/// `toks[open_idx]` is `{`; index of the matching `}` (or `toks.len()`
/// when unbalanced — callers clamp).
pub fn match_brace(toks: &[Tok], open_idx: usize) -> usize {
    let mut depth = 0i32;
    for (k, t) in toks.iter().enumerate().skip(open_idx) {
        if is_punct(t, "{") {
            depth += 1;
        } else if is_punct(t, "}") {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
    }
    toks.len()
}

/// Inclusive token-index ranges that are `#[cfg(test)]` mod bodies or
/// `#[test]`/`#[bench]` fn bodies — every rule skips these; tests may
/// unwrap freely.
pub fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if !(is_punct(&toks[i], "#") && i + 1 < n && toks[i + 1].text == "[") {
            i += 1;
            continue;
        }
        // collect attribute tokens to the matching `]`
        let mut depth = 0i32;
        let mut j = i + 1;
        let mut attr: Vec<&str> = Vec::new();
        while j < n {
            let tj = &toks[j];
            if tj.text == "[" {
                depth += 1;
            } else if tj.text == "]" {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else {
                attr.push(&tj.text);
            }
            j += 1;
        }
        let is_cfg_test = attr.contains(&"cfg") && attr.contains(&"test");
        let is_test_attr = attr == ["test"] || attr == ["bench"];
        if is_cfg_test || is_test_attr {
            // hop over any further attributes, then find the item body
            let mut k = j + 1;
            while k < n && toks[k].text == "#" && k + 1 < n && toks[k + 1].text == "[" {
                let mut d2 = 0i32;
                while k < n {
                    if toks[k].text == "[" {
                        d2 += 1;
                    } else if toks[k].text == "]" {
                        d2 -= 1;
                        if d2 == 0 {
                            break;
                        }
                    }
                    k += 1;
                }
                k += 1;
            }
            while k < n && !(is_punct(&toks[k], "{") || is_punct(&toks[k], ";")) {
                k += 1;
            }
            if k < n && toks[k].text == "{" {
                let end = match_brace(toks, k);
                regions.push((i, end));
                i = end + 1;
                continue;
            }
        }
        i = j + 1;
    }
    regions
}

/// Whether token index `idx` falls in any of the inclusive `regions`.
pub fn in_regions(idx: usize, regions: &[(usize, usize)]) -> bool {
    regions.iter().any(|&(a, b)| a <= idx && idx <= b)
}

/// A function body found in the stream: name plus the inclusive token
/// range of its `{ … }` body.
#[derive(Clone, Debug)]
pub struct FnBody {
    pub name: String,
    pub body_start: usize,
    pub body_end: usize,
}

/// All non-test function bodies. Nested fns are yielded separately;
/// their tokens also sit inside the parent's range, which the rules
/// tolerate (a finding is deduplicated by token index where it
/// matters).
pub fn functions(toks: &[Tok], skip: &[(usize, usize)]) -> Vec<FnBody> {
    let mut out = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if in_regions(i, skip) {
            i += 1;
            continue;
        }
        let t = &toks[i];
        if t.kind == TokKind::Ident
            && t.text == "fn"
            && i + 1 < n
            && toks[i + 1].kind == TokKind::Ident
        {
            let name = toks[i + 1].text.clone();
            let mut k = i + 2;
            while k < n && !(is_punct(&toks[k], "{") || is_punct(&toks[k], ";")) {
                k += 1;
            }
            if k < n && toks[k].text == "{" {
                let end = match_brace(toks, k);
                out.push(FnBody { name, body_start: k, body_end: end });
                i += 2;
                continue;
            }
            i = k;
            continue;
        }
        i += 1;
    }
    out
}

/// First token index of the statement containing `i` (the token after
/// the nearest `;`, `{` or `}` at or before it).
pub fn stmt_start(toks: &[Tok], i: usize, body_start: usize) -> usize {
    let mut j = i.saturating_sub(1);
    while j > body_start {
        let t = &toks[j];
        if t.kind == TokKind::Punct && (t.text == ";" || t.text == "{" || t.text == "}") {
            return j + 1;
        }
        j -= 1;
    }
    body_start + 1
}

/// Token ranges inside `pool.execute(..)` / `thread::spawn(..)` call
/// arguments within `[start, end]`: closure bodies that run off the
/// current thread, exempt from on-thread blocking rules.
pub fn offload_ranges(toks: &[Tok], start: usize, end: usize) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let end = end.min(toks.len().saturating_sub(1));
    let mut k = start;
    while k <= end {
        let t = &toks[k];
        if t.kind == TokKind::Ident
            && (t.text == "execute" || t.text == "spawn")
            && k + 1 <= end
            && toks[k + 1].text == "("
        {
            let mut depth = 0i32;
            let mut j = k + 1;
            while j <= end {
                if toks[j].text == "(" {
                    depth += 1;
                } else if toks[j].text == ")" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                j += 1;
            }
            ranges.push((k, j));
            k = j + 1;
            continue;
        }
        k += 1;
    }
    ranges
}

/// Whether token index `i` falls in any offload range.
pub fn in_ranges(i: usize, ranges: &[(usize, usize)]) -> bool {
    ranges.iter().any(|&(a, b)| a <= i && i <= b)
}
