//! Static analysis over this repo's own Rust sources — `tq-dit lint`.
//!
//! A dependency-free lint pass purpose-built for the concurrency
//! invariants the serve stack depends on but `rustc`/clippy cannot
//! see: which mutex may be held across which calls, in what order
//! locks nest, and which code paths must never panic. It runs in CI
//! against the whole tree (and in a unit test below, so `cargo test`
//! alone catches regressions).
//!
//! ## Pipeline
//!
//! 1. [`lexer`] — a hand-rolled token lexer: strings (escaped, raw
//!    `r#"…"#`, byte), nested block comments, `'a` lifetimes vs `'x'`
//!    char literals. Rules never see raw text, so `"unwrap()"` inside
//!    a string literal can't false-positive.
//! 2. [`scope`] — brace-matched structure recovery: `#[cfg(test)]` /
//!    `#[test]` regions (exempt from every rule), per-function body
//!    ranges, statement boundaries, `pool.execute(..)`/`spawn(..)`
//!    offload ranges.
//! 3. [`rules`] — the rule engine; each rule is a pure function from
//!    tokens to [`Finding`]s:
//!
//!    | rule | guards against |
//!    |------|----------------|
//!    | `lock-across-blocking` | holding a mutex guard across socket/frame I/O, channel `recv`, `sleep`, `join` — and re-acquiring a held mutex (self-deadlock) |
//!    | `lock-order` | acquisitions that invert the declared rank registry (`state` → `readers` → `bulk` → `data`/`ctrl`/`stream`/`half` → `record`), or touch an unregistered mutex while one is held |
//!    | `no-panic-paths` | `.unwrap()` / `.expect()` / `panic!`-family in production `serve/`, `runtime/` and `sampler/` code; slice-indexing peer bytes on `serve/net` decode paths |
//!    | `protocol-exhaustiveness` | silent `_ => {}` arms over protocol enums (`Msg`, `WireError`, `ShardState`, `Role`, `Health`) in `serve/net` |
//!    | `reactor-discipline` | blocking calls inside reactor callbacks (`on_*` fns, fns taking `Ctl`) outside `reactor.rs` |
//!    | `non-poisoning-lock` | `.lock().unwrap()` — call sites belong on [`crate::util::lock`] |
//!
//! ## Suppressions
//!
//! `// tq-lint: allow(rule): reason` exempts the next code line (and
//! the pragma's own line); `// tq-lint: allow-file(rule): reason`
//! exempts the file. A reason is mandatory and the rule name must be
//! real — anything else is a `bad-pragma` finding, so suppressions
//! never rot silently.
//!
//! ## Fixtures
//!
//! `fixtures/serve/net/` holds one violating and one clean file per
//! rule (the directory name puts them in scope of the path-gated
//! rules). They are not compiled — the tree walker skips `fixtures`
//! directories, and the tests below lint them via `include_str!`,
//! asserting each `_bad` file trips exactly its rule and each `_ok`
//! file is clean. CI additionally runs `tq-dit lint` on each `_bad`
//! fixture expecting a nonzero exit.

pub mod lexer;
pub mod rules;
pub mod scope;

use std::path::{Path, PathBuf};

pub use rules::{Finding, KNOWN_RULES};

use crate::util::json::Json;

/// Lint one source text. `path` is used both for reporting and for the
/// path-gated rules (`serve/`, `runtime/`, `sampler/`, `serve/net`), so pass a
/// repo-relative or absolute path with `/` separators.
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let raw = lexer::lex(src);
    let mut findings = Vec::new();
    let pragmas = rules::parse_pragmas(&raw, path, &mut findings);
    let toks = scope::code_tokens(&raw);
    let skip = scope::test_regions(&toks);
    let fns = scope::functions(&toks, &skip);
    rules::rule_locks(path, &toks, &fns, &mut findings);
    rules::rule_no_panic(path, &toks, &fns, &mut findings);
    rules::rule_protocol(path, &toks, &skip, &mut findings);
    rules::rule_reactor(path, &toks, &fns, &mut findings);
    rules::rule_lock_helper(path, &toks, &skip, &mut findings);
    findings
        .into_iter()
        .filter(|f| !pragmas.suppresses(&f.rule, f.line))
        .collect()
}

fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(root)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            // fixtures are deliberate violations; the tests lint them
            // explicitly, the tree walk must not
            if p.file_name().is_some_and(|n| n == "fixtures") {
                continue;
            }
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under the given roots (files are linted
/// directly; directories are walked, skipping `fixtures`). Findings
/// come back sorted by file, line, rule.
pub fn lint_paths(roots: &[PathBuf]) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for root in roots {
        collect_rs(root, &mut files)?;
    }
    files.sort();
    let mut findings = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let rel = f.to_string_lossy().replace('\\', "/");
        findings.extend(lint_source(&rel, &src));
    }
    findings.sort();
    Ok(findings)
}

/// Canonical JSON report: `{"findings": [...], "counts": {...}}` via
/// the crate's own serializer, for the CI artifact.
pub fn report_json(findings: &[Finding]) -> Json {
    use std::collections::BTreeMap;
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            let mut o = BTreeMap::new();
            o.insert("file".to_string(), Json::Str(f.file.clone()));
            o.insert("line".to_string(), Json::Num(f.line as f64));
            o.insert("rule".to_string(), Json::Str(f.rule.clone()));
            o.insert("message".to_string(), Json::Str(f.message.clone()));
            Json::Obj(o)
        })
        .collect();
    let mut counts: BTreeMap<String, Json> = BTreeMap::new();
    for f in findings {
        let e = counts.entry(f.rule.clone()).or_insert(Json::Num(0.0));
        if let Json::Num(n) = e {
            *n += 1.0;
        }
    }
    let mut top = BTreeMap::new();
    top.insert("findings".to_string(), Json::Arr(items));
    top.insert("counts".to_string(), Json::Obj(counts));
    Json::Obj(top)
}

#[cfg(test)]
mod tests {
    use super::lexer::{lex, TokKind};
    use super::*;

    fn rules_hit(path: &str, src: &str) -> Vec<String> {
        let mut rs: Vec<String> =
            lint_source(path, src).into_iter().map(|f| f.rule).collect();
        rs.sort();
        rs.dedup();
        rs
    }

    // ------------------------------------------------------- lexer

    #[test]
    fn lexer_strings_hide_their_contents() {
        // "unwrap()" inside string literals must lex as one Str token,
        // never as idents the rules could match
        let src = r##"
            fn serve_msg() {
                let a = "x.unwrap() inside";
                let b = r#"raw "quoted" .unwrap() body"#;
                let c = b"byte unwrap()";
            }
        "##;
        let toks = lex(src);
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Str).count(), 3);
        assert!(!toks
            .iter()
            .any(|t| t.kind == TokKind::Ident && t.text == "unwrap"));
        // and the whole file lints clean even under a serve/ path
        assert!(lint_source("serve/net/x.rs", src).is_empty());
    }

    #[test]
    fn lexer_raw_string_hash_depths() {
        let src = r####"let s = r###"one "# two "## three"###;"####;
        let toks = lex(src);
        let strs: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert!(strs[0].text.starts_with("r###\""));
        assert!(strs[0].text.ends_with("\"###"));
    }

    #[test]
    fn lexer_lifetimes_vs_char_literals() {
        let src = "fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; \
                   let brace = '{'; let q = '\\''; }";
        let toks = lex(src);
        let lifetimes: Vec<_> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, ["'a", "'a"]);
        let chars: Vec<_> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Char)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(chars, ["'x'", "'\\n'", "'{'", "'\\''"]);
        // the '{' char literal must not unbalance brace matching
        let code = scope::code_tokens(&toks);
        let open = code.iter().position(|t| t.text == "{").unwrap();
        let close = scope::match_brace(&code, open);
        assert_eq!(code[close].text, "}");
        assert_eq!(close, code.len() - 1);
    }

    #[test]
    fn lexer_nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ fn f() {}";
        let toks = lex(src);
        assert_eq!(toks[0].kind, TokKind::BlockComment);
        assert!(toks[0].text.ends_with("still comment */"));
        assert_eq!(toks[1].text, "fn");
    }

    #[test]
    fn lexer_line_numbers_survive_multiline_tokens() {
        let src = "let a = \"one\ntwo\";\nlet b = 1; /* x\ny */ let c = 2;";
        let toks = lex(src);
        let b = toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 3); // the string spanned lines 1-2
        let c = toks.iter().find(|t| t.text == "c").unwrap();
        assert_eq!(c.line, 4); // the block comment spanned 3-4
    }

    // ----------------------------------------------------- pragmas

    #[test]
    fn pragma_suppresses_next_code_line_only() {
        let src = "fn f(v: &Vec<u32>) -> u32 {\n\
                   // tq-lint: allow(no-panic-paths): checked non-empty\n\
                   *v.last().unwrap()\n\
                   }\n\
                   fn g(v: &Vec<u32>) -> u32 { *v.last().unwrap() }\n";
        let fs = lint_source("serve/x.rs", src);
        // f's unwrap is suppressed; g's is not
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, "no-panic-paths");
        assert_eq!(fs[0].line, 5);
    }

    #[test]
    fn pragma_allow_file_is_filewide() {
        let src = "// tq-lint: allow-file(no-panic-paths): generated\n\
                   fn f(v: &Vec<u32>) -> u32 { v.first().unwrap() + 1 }\n\
                   fn g(v: &Vec<u32>) -> u32 { *v.last().unwrap() }\n";
        assert!(lint_source("serve/x.rs", src).is_empty());
    }

    #[test]
    fn pragma_errors_are_findings() {
        let cases = [
            ("// tq-lint: allow(no-panic-paths", "missing `)`"),
            ("// tq-lint: allow(not-a-rule): x", "unknown rule"),
            ("// tq-lint: allow(no-panic-paths)", "needs a `: reason`"),
            ("// tq-lint: allow(no-panic-paths):   ", "needs a `: reason`"),
            ("// tq-lint: frobnicate", "unrecognized"),
        ];
        for (src, want) in cases {
            let fs = lint_source("serve/x.rs", src);
            assert_eq!(fs.len(), 1, "{src}");
            assert_eq!(fs[0].rule, "bad-pragma", "{src}");
            assert!(fs[0].message.contains(want), "{src}: {}", fs[0].message);
        }
    }

    #[test]
    fn bad_pragma_cannot_be_suppressed_by_itself() {
        // an allow() of a bogus rule is a finding even on its own line
        let src = "// tq-lint: allow(made-up-rule): because\nfn f() {}\n";
        let fs = lint_source("serve/x.rs", src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "bad-pragma");
    }

    // ---------------------------------------------------- fixtures

    const FIXTURES: [(&str, &str, &str); 12] = [
        (
            "lock-across-blocking",
            "fixtures/serve/net/lock_across_blocking_bad.rs",
            include_str!("fixtures/serve/net/lock_across_blocking_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/lock_across_blocking_ok.rs",
            include_str!("fixtures/serve/net/lock_across_blocking_ok.rs"),
        ),
        (
            "lock-order",
            "fixtures/serve/net/lock_order_bad.rs",
            include_str!("fixtures/serve/net/lock_order_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/lock_order_ok.rs",
            include_str!("fixtures/serve/net/lock_order_ok.rs"),
        ),
        (
            "no-panic-paths",
            "fixtures/serve/net/no_panic_paths_bad.rs",
            include_str!("fixtures/serve/net/no_panic_paths_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/no_panic_paths_ok.rs",
            include_str!("fixtures/serve/net/no_panic_paths_ok.rs"),
        ),
        (
            "protocol-exhaustiveness",
            "fixtures/serve/net/protocol_exhaustiveness_bad.rs",
            include_str!("fixtures/serve/net/protocol_exhaustiveness_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/protocol_exhaustiveness_ok.rs",
            include_str!("fixtures/serve/net/protocol_exhaustiveness_ok.rs"),
        ),
        (
            "reactor-discipline",
            "fixtures/serve/net/reactor_discipline_bad.rs",
            include_str!("fixtures/serve/net/reactor_discipline_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/reactor_discipline_ok.rs",
            include_str!("fixtures/serve/net/reactor_discipline_ok.rs"),
        ),
        (
            "non-poisoning-lock",
            "fixtures/serve/net/non_poisoning_lock_bad.rs",
            include_str!("fixtures/serve/net/non_poisoning_lock_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/non_poisoning_lock_ok.rs",
            include_str!("fixtures/serve/net/non_poisoning_lock_ok.rs"),
        ),
    ];

    #[test]
    fn violating_fixtures_trip_their_rule() {
        for (rule, path, src) in FIXTURES {
            if rule.is_empty() {
                continue;
            }
            let hit = rules_hit(path, src);
            assert!(
                hit.iter().any(|r| r == rule),
                "{path}: expected a `{rule}` finding, got {hit:?}"
            );
        }
    }

    #[test]
    fn clean_fixtures_stay_clean() {
        for (rule, path, src) in FIXTURES {
            if !rule.is_empty() {
                continue;
            }
            let fs = lint_source(path, src);
            assert!(fs.is_empty(), "{path}: unexpected findings {fs:?}");
        }
    }

    #[test]
    fn self_deadlock_is_flagged() {
        let src = "fn f(s: &Shared) {\n\
                   let a = s.state.lock().unwrap_or_else(|p| p.into_inner());\n\
                   let b = s.state.lock().unwrap_or_else(|p| p.into_inner());\n\
                   }\n";
        let fs = lint_source("serve/net/x.rs", src);
        assert!(
            fs.iter().any(|f| f.rule == "lock-across-blocking"
                && f.message.contains("self-deadlock")),
            "{fs:?}"
        );
    }

    #[test]
    fn condvar_wait_consumes_the_guard() {
        // wait() hands the guard back to the condvar — the blocking
        // call itself must NOT count as blocking-under-lock
        let src = "fn f(s: &Shared) {\n\
                   let mut st = crate::util::lock(&s.state);\n\
                   st = s.cv.wait(st).unwrap_or_else(|p| p.into_inner());\n\
                   st.n += 1;\n\
                   }\n";
        let fs = lint_source("serve/net/x.rs", src);
        assert!(
            fs.iter().all(|f| f.rule != "lock-across-blocking"),
            "{fs:?}"
        );
    }

    #[test]
    fn test_regions_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n\
                   fn helper(v: &Vec<u32>) -> u32 { v.first().unwrap() + 1 }\n\
                   }\n";
        assert!(lint_source("serve/x.rs", src).is_empty());
        let src2 = "#[test]\nfn t() { Vec::<u32>::new().first().unwrap(); }\n";
        assert!(lint_source("serve/x.rs", src2).is_empty());
    }

    // ----------------------------------------------------- dogfood

    #[test]
    fn dogfood_whole_tree_is_clean() {
        // the manifest may sit at the repo root (src under rust/src) or
        // alongside the sources — handle both
        let base = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let root = if base.join("rust/src").is_dir() {
            base.join("rust/src")
        } else {
            base.join("src")
        };
        let findings = lint_paths(&[root]).expect("walk src");
        assert!(
            findings.is_empty(),
            "lint findings in the tree:\n{}",
            findings
                .iter()
                .map(|f| f.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    #[test]
    fn json_report_shape() {
        let fs = vec![Finding {
            file: "a.rs".into(),
            line: 3,
            rule: "lock-order".into(),
            message: "m".into(),
        }];
        let j = report_json(&fs).dump();
        assert!(j.contains("\"findings\""));
        assert!(j.contains("\"lock-order\""));
        assert!(j.contains("\"line\":3"));
    }
}
