//! Static analysis over this repo's own Rust sources — `tq-dit lint`.
//!
//! A dependency-free lint pass purpose-built for the concurrency
//! invariants the serve stack depends on but `rustc`/clippy cannot
//! see: which mutex may be held across which calls, in what order
//! locks nest, which code paths must never panic, and whether a new
//! stats field is plumbed end-to-end. It runs in CI against the whole
//! tree (and in a unit test below, so `cargo test` alone catches
//! regressions).
//!
//! ## Pipeline
//!
//! 1. [`lexer`] — a hand-rolled token lexer: strings (escaped, raw
//!    `r#"…"#`, byte), nested block comments, `'a` lifetimes vs `'x'`
//!    char literals. Rules never see raw text, so `"unwrap()"` inside
//!    a string literal can't false-positive.
//! 2. [`scope`] — brace-matched structure recovery: `#[cfg(test)]` /
//!    `#[test]` regions (exempt from every rule), per-function body
//!    ranges, statement boundaries, `pool.execute(..)`/`spawn(..)`
//!    offload ranges.
//! 3. [`index`] — per-file item extraction on the same token stream:
//!    fn definitions (with enclosing `impl` type, parameter types and
//!    typed locals), struct fields with declared outer types, enum
//!    variants.
//! 4. [`graph`] — the whole-program call graph over every indexed
//!    file, with *typed* call resolution (a method call resolves only
//!    when the receiver's outer type is known — `self`, a declared
//!    `self.field` type, a typed param or local; unknown receivers
//!    produce no edge) and the transitive-blocking fixpoint: a fn
//!    that calls anything in `BLOCKING`, or anything inferred
//!    blocking, is itself blocking. Offload ranges and
//!    `allow(transitive-blocking)` pragma cuts stop the propagation.
//! 5. [`rules`] — the rule engine; each rule is a pure function from
//!    tokens (and, for the interprocedural ones, the graph) to
//!    [`Finding`]s:
//!
//!    | rule | guards against |
//!    |------|----------------|
//!    | `lock-across-blocking` | holding a mutex guard across socket/frame I/O, channel `recv`, `sleep`, `join` — directly, or through any call chain the graph infers as blocking — and re-acquiring a held mutex (self-deadlock) |
//!    | `lock-order` | acquisitions that invert the declared rank registry (`state` → `readers` → `bulk` → `data`/`ctrl`/`stream`/`half` → `record`), or touch an unregistered mutex while one is held |
//!    | `no-panic-paths` | `.unwrap()` / `.expect()` / `panic!`-family in production `serve/`, `runtime/` and `sampler/` code; slice-indexing peer bytes on `serve/net` decode paths |
//!    | `protocol-exhaustiveness` | silent `_ => {}` arms over protocol enums (`Msg`, `WireError`, `ShardState`, `Role`, `Health`) in `serve/net` |
//!    | `reactor-discipline` | blocking calls — direct or through an inferred-blocking chain — inside reactor callbacks (`on_*` fns, fns taking `Ctl`) outside `reactor.rs` |
//!    | `non-poisoning-lock` | `.lock().unwrap()` — call sites belong on [`crate::util::lock`] |
//!    | `stats-plumbing` | a `ServerStats`/`WorkerStats`/`RungStats`/`SampleStats` field or `Msg` variant missing from its serde encode/decode, `absorb`, or `stats_fold` (registry + declared exemptions in [`rules::STATS_PLUMBING`] / [`rules::STATS_EXEMPT`]) |
//!
//!    Interprocedural findings print the blocking *chain*, e.g.
//!    `on_readable -> flush_shard -> write_frame [blocking: write_all]`,
//!    so the repair site is visible without re-deriving the graph by
//!    hand.
//!
//! ## Blocking inference semantics
//!
//! Seeds are non-offloaded calls to the 14 `BLOCKING` names plus
//! `wait`/`wait_timeout` (`join` only when zero-arg, so `Path::join`
//! and `slice::join` don't seed). Propagation follows resolved call
//! edges only — typed resolution means precision over recall: a
//! receiver the index can't type contributes *no* edge rather than an
//! edge to every same-named method in the tree. Two cuts stop
//! propagation: work inside `pool.execute(..)`/`spawn(..)` argument
//! ranges runs elsewhere, and a fn whose definition line carries
//! `// tq-lint: allow(transitive-blocking): reason` is *declared*
//! non-blocking for inference (a mode-dispatch shim whose hot path is
//! non-blocking; the direct rules still check its body). The graph is
//! serialized by `tq-dit lint --graph-json` for offline inspection.
//!
//! ## Suppressions
//!
//! `// tq-lint: allow(rule): reason` exempts the next code line (and
//! the pragma's own line); `// tq-lint: allow-file(rule): reason`
//! exempts the file. A reason is mandatory and the rule name must be
//! real — anything else is a `bad-pragma` finding, so suppressions
//! never rot silently. `tq-dit lint --pragmas` reports every pragma
//! with its reason, and CI ratchets the production pragma count
//! against `rust/lint_pragmas.baseline` so the number can shrink but
//! not grow.
//!
//! ## Parallelism and determinism
//!
//! [`lint_tree`] parses and indexes files in parallel on
//! [`crate::util::threadpool::par_map`], builds the graph once, then
//! runs the per-file rules in parallel again. Findings are merged and
//! sorted by `(file, line, rule)`, so the output order is
//! deterministic regardless of scheduling; per-rule wall time is
//! aggregated into [`LintRun::timings`].
//!
//! ## Fixtures
//!
//! `fixtures/serve/net/` holds one violating and one clean file per
//! rule (the directory name puts them in scope of the path-gated
//! rules). They are not compiled — the tree walker skips `fixtures`
//! directories, and the tests below lint them via `include_str!`,
//! asserting each `_bad` file trips exactly its rule and each `_ok`
//! file is clean. CI additionally runs `tq-dit lint` on each `_bad`
//! fixture expecting a nonzero exit.

pub mod graph;
pub mod index;
pub mod lexer;
pub mod rules;
pub mod scope;

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::time::Instant;

pub use rules::{Finding, PragmaRec, KNOWN_RULES};

use crate::util::json::Json;
use crate::util::threadpool::par_map;

/// Display labels for the per-file rule passes, in [`run_rules`]
/// order. The first walk serves two rules at once.
const RULE_LABELS: [&str; 5] = [
    "lock-across-blocking+lock-order",
    "no-panic-paths",
    "protocol-exhaustiveness",
    "reactor-discipline",
    "non-poisoning-lock",
];

/// Everything derived from one file before graph construction.
struct FileUnit {
    path: String,
    toks: Vec<lexer::Tok>,
    skip: Vec<(usize, usize)>,
    fns: Vec<scope::FnBody>,
    index: index::FileIndex,
    pragmas: rules::Pragmas,
    pragma_findings: Vec<Finding>,
    /// Fn-definition lines declared as blocking-propagation cuts.
    cuts: BTreeSet<usize>,
}

fn parse_unit(path: &str, src: &str) -> FileUnit {
    let raw = lexer::lex(src);
    let mut pragma_findings = Vec::new();
    let pragmas = rules::parse_pragmas(&raw, path, &mut pragma_findings);
    let toks = scope::code_tokens(&raw);
    let skip = scope::test_regions(&toks);
    let fns = scope::functions(&toks, &skip);
    let index = index::index_file(&toks);
    let cuts = index
        .fns
        .iter()
        .filter(|f| pragmas.suppresses("transitive-blocking", f.line))
        .map(|f| f.line)
        .collect();
    FileUnit { path: path.to_string(), toks, skip, fns, index, pragmas, pragma_findings, cuts }
}

/// The per-file rule passes; returns unfiltered findings plus one
/// nanosecond timing per [`RULE_LABELS`] entry.
fn run_rules(unit: &FileUnit, g: &graph::Graph) -> (Vec<Finding>, [u128; 5]) {
    let mut findings = unit.pragma_findings.clone();
    let mut ns = [0u128; 5];
    let t = Instant::now();
    rules::rule_locks(&unit.path, &unit.toks, &unit.fns, g, &mut findings);
    ns[0] = t.elapsed().as_nanos();
    let t = Instant::now();
    rules::rule_no_panic(&unit.path, &unit.toks, &unit.fns, &mut findings);
    ns[1] = t.elapsed().as_nanos();
    let t = Instant::now();
    rules::rule_protocol(&unit.path, &unit.toks, &unit.skip, &mut findings);
    ns[2] = t.elapsed().as_nanos();
    let t = Instant::now();
    rules::rule_reactor(&unit.path, &unit.toks, &unit.fns, g, &mut findings);
    ns[3] = t.elapsed().as_nanos();
    let t = Instant::now();
    rules::rule_lock_helper(&unit.path, &unit.toks, &unit.skip, &mut findings);
    ns[4] = t.elapsed().as_nanos();
    (findings, ns)
}

/// Lint one source text. `path` is used both for reporting and for the
/// path-gated rules (`serve/`, `runtime/`, `sampler/`, `serve/net`), so pass a
/// repo-relative or absolute path with `/` separators. The call graph
/// spans just this file, so interprocedural findings cover
/// same-file helpers — fixtures stay self-contained.
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let unit = parse_unit(path, src);
    let input = graph::GraphInput {
        path: &unit.path,
        toks: &unit.toks,
        index: &unit.index,
        cuts: &unit.cuts,
    };
    let g = graph::Graph::build(std::slice::from_ref(&input));
    let (mut findings, _ns) = run_rules(&unit, &g);
    rules::rule_stats_plumbing(&g, &mut findings);
    let mut out: Vec<Finding> = findings
        .into_iter()
        .filter(|f| !unit.pragmas.suppresses(&f.rule, f.line))
        .collect();
    out.sort();
    out
}

fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(root)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            // fixtures are deliberate violations; the tests lint them
            // explicitly, the tree walk must not
            if p.file_name().is_some_and(|n| n == "fixtures") {
                continue;
            }
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// One whole-program lint run: filtered findings plus everything the
/// CLI reports around them.
pub struct LintRun {
    /// Sorted by `(file, line, rule)` — deterministic across runs.
    pub findings: Vec<Finding>,
    pub files: usize,
    /// Every well-formed pragma in the linted files: `(file, record)`.
    pub pragmas: Vec<(String, PragmaRec)>,
    /// `(label, nanoseconds)` per phase/rule: parse+index, graph, the
    /// five per-file passes, stats-plumbing.
    pub timings: Vec<(&'static str, u128)>,
    pub wall_ns: u128,
    pub graph: graph::Graph,
}

/// Lint every `.rs` file under the given roots (files are linted
/// directly; directories are walked, skipping `fixtures`) as one
/// program: parse/index in parallel, build the call graph, run the
/// rules in parallel, merge deterministically.
pub fn lint_tree(roots: &[PathBuf]) -> std::io::Result<LintRun> {
    let t_all = Instant::now();
    let mut files = Vec::new();
    for root in roots {
        collect_rs(root, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut sources = Vec::with_capacity(files.len());
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let rel = f.to_string_lossy().replace('\\', "/");
        sources.push((rel, src));
    }
    let t = Instant::now();
    let units: Vec<FileUnit> = par_map(&sources, |(rel, src)| parse_unit(rel, src));
    let parse_ns = t.elapsed().as_nanos();

    let t = Instant::now();
    let inputs: Vec<graph::GraphInput> = units
        .iter()
        .map(|u| graph::GraphInput {
            path: &u.path,
            toks: &u.toks,
            index: &u.index,
            cuts: &u.cuts,
        })
        .collect();
    let g = graph::Graph::build(&inputs);
    drop(inputs);
    let graph_ns = t.elapsed().as_nanos();

    let per_file: Vec<(Vec<Finding>, [u128; 5])> =
        par_map(&units, |u| run_rules(u, &g));
    let mut rule_ns = [0u128; 5];
    let mut findings = Vec::new();
    for (u, (fs, ns)) in units.iter().zip(per_file) {
        for (acc, n) in rule_ns.iter_mut().zip(ns) {
            *acc += n;
        }
        findings.extend(
            fs.into_iter().filter(|f| !u.pragmas.suppresses(&f.rule, f.line)),
        );
    }

    let t = Instant::now();
    let mut stats = Vec::new();
    rules::rule_stats_plumbing(&g, &mut stats);
    let by_path: BTreeMap<&str, &rules::Pragmas> =
        units.iter().map(|u| (u.path.as_str(), &u.pragmas)).collect();
    findings.extend(stats.into_iter().filter(|f| {
        by_path
            .get(f.file.as_str())
            .map_or(true, |p| !p.suppresses(&f.rule, f.line))
    }));
    let stats_ns = t.elapsed().as_nanos();
    findings.sort();

    let pragmas = units
        .iter()
        .flat_map(|u| {
            u.pragmas.records().iter().map(|r| (u.path.clone(), r.clone()))
        })
        .collect();
    let mut timings = vec![("parse+index", parse_ns), ("graph", graph_ns)];
    for (label, n) in RULE_LABELS.into_iter().zip(rule_ns) {
        timings.push((label, n));
    }
    timings.push(("stats-plumbing", stats_ns));
    Ok(LintRun {
        findings,
        files: files.len(),
        pragmas,
        timings,
        wall_ns: t_all.elapsed().as_nanos(),
        graph: g,
    })
}

/// Findings only — the original entry point, now a thin wrapper over
/// [`lint_tree`].
pub fn lint_paths(roots: &[PathBuf]) -> std::io::Result<Vec<Finding>> {
    lint_tree(roots).map(|r| r.findings)
}

/// Parse `lint_pragmas.baseline`: `#` comment lines and blanks around
/// a single integer.
pub fn parse_ratchet(text: &str) -> Option<usize> {
    text.lines()
        .map(str::trim)
        .find(|l| !l.is_empty() && !l.starts_with('#'))?
        .parse()
        .ok()
}

/// Canonical JSON report: `{"findings": [...], "counts": {...}}` via
/// the crate's own serializer, for the CI artifact.
pub fn report_json(findings: &[Finding]) -> Json {
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            let mut o = BTreeMap::new();
            o.insert("file".to_string(), Json::Str(f.file.clone()));
            o.insert("line".to_string(), Json::Num(f.line as f64));
            o.insert("rule".to_string(), Json::Str(f.rule.clone()));
            o.insert("message".to_string(), Json::Str(f.message.clone()));
            Json::Obj(o)
        })
        .collect();
    let mut counts: BTreeMap<String, Json> = BTreeMap::new();
    for f in findings {
        let e = counts.entry(f.rule.clone()).or_insert(Json::Num(0.0));
        if let Json::Num(n) = e {
            *n += 1.0;
        }
    }
    let mut top = BTreeMap::new();
    top.insert("findings".to_string(), Json::Arr(items));
    top.insert("counts".to_string(), Json::Obj(counts));
    Json::Obj(top)
}

#[cfg(test)]
mod tests {
    use super::lexer::{lex, TokKind};
    use super::*;

    fn rules_hit(path: &str, src: &str) -> Vec<String> {
        let mut rs: Vec<String> =
            lint_source(path, src).into_iter().map(|f| f.rule).collect();
        rs.sort();
        rs.dedup();
        rs
    }

    /// The tree the binary lints in CI — the manifest may sit at the
    /// repo root (src under rust/src) or alongside the sources.
    fn tree_root() -> PathBuf {
        let base = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        if base.join("rust/src").is_dir() {
            base.join("rust/src")
        } else {
            base.join("src")
        }
    }

    // ------------------------------------------------------- lexer

    #[test]
    fn lexer_strings_hide_their_contents() {
        // "unwrap()" inside string literals must lex as one Str token,
        // never as idents the rules could match
        let src = r##"
            fn serve_msg() {
                let a = "x.unwrap() inside";
                let b = r#"raw "quoted" .unwrap() body"#;
                let c = b"byte unwrap()";
            }
        "##;
        let toks = lex(src);
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Str).count(), 3);
        assert!(!toks
            .iter()
            .any(|t| t.kind == TokKind::Ident && t.text == "unwrap"));
        // and the whole file lints clean even under a serve/ path
        assert!(lint_source("serve/net/x.rs", src).is_empty());
    }

    #[test]
    fn lexer_raw_string_hash_depths() {
        let src = r####"let s = r###"one "# two "## three"###;"####;
        let toks = lex(src);
        let strs: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert!(strs[0].text.starts_with("r###\""));
        assert!(strs[0].text.ends_with("\"###"));
    }

    #[test]
    fn lexer_lifetimes_vs_char_literals() {
        let src = "fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; \
                   let brace = '{'; let q = '\\''; }";
        let toks = lex(src);
        let lifetimes: Vec<_> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, ["'a", "'a"]);
        let chars: Vec<_> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Char)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(chars, ["'x'", "'\\n'", "'{'", "'\\''"]);
        // the '{' char literal must not unbalance brace matching
        let code = scope::code_tokens(&toks);
        let open = code.iter().position(|t| t.text == "{").unwrap();
        let close = scope::match_brace(&code, open);
        assert_eq!(code[close].text, "}");
        assert_eq!(close, code.len() - 1);
    }

    #[test]
    fn lexer_nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ fn f() {}";
        let toks = lex(src);
        assert_eq!(toks[0].kind, TokKind::BlockComment);
        assert!(toks[0].text.ends_with("still comment */"));
        assert_eq!(toks[1].text, "fn");
    }

    #[test]
    fn lexer_line_numbers_survive_multiline_tokens() {
        let src = "let a = \"one\ntwo\";\nlet b = 1; /* x\ny */ let c = 2;";
        let toks = lex(src);
        let b = toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 3); // the string spanned lines 1-2
        let c = toks.iter().find(|t| t.text == "c").unwrap();
        assert_eq!(c.line, 4); // the block comment spanned 3-4
    }

    // ----------------------------------------------------- pragmas

    #[test]
    fn pragma_suppresses_next_code_line_only() {
        let src = "fn f(v: &Vec<u32>) -> u32 {\n\
                   // tq-lint: allow(no-panic-paths): checked non-empty\n\
                   *v.last().unwrap()\n\
                   }\n\
                   fn g(v: &Vec<u32>) -> u32 { *v.last().unwrap() }\n";
        let fs = lint_source("serve/x.rs", src);
        // f's unwrap is suppressed; g's is not
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, "no-panic-paths");
        assert_eq!(fs[0].line, 5);
    }

    #[test]
    fn pragma_allow_file_is_filewide() {
        let src = "// tq-lint: allow-file(no-panic-paths): generated\n\
                   fn f(v: &Vec<u32>) -> u32 { v.first().unwrap() + 1 }\n\
                   fn g(v: &Vec<u32>) -> u32 { *v.last().unwrap() }\n";
        assert!(lint_source("serve/x.rs", src).is_empty());
    }

    #[test]
    fn pragma_errors_are_findings() {
        let cases = [
            ("// tq-lint: allow(no-panic-paths", "missing `)`"),
            ("// tq-lint: allow(not-a-rule): x", "unknown rule"),
            ("// tq-lint: allow(no-panic-paths)", "needs a `: reason`"),
            ("// tq-lint: allow(no-panic-paths):   ", "needs a `: reason`"),
            ("// tq-lint: frobnicate", "unrecognized"),
        ];
        for (src, want) in cases {
            let fs = lint_source("serve/x.rs", src);
            assert_eq!(fs.len(), 1, "{src}");
            assert_eq!(fs[0].rule, "bad-pragma", "{src}");
            assert!(fs[0].message.contains(want), "{src}: {}", fs[0].message);
        }
    }

    #[test]
    fn bad_pragma_cannot_be_suppressed_by_itself() {
        // an allow() of a bogus rule is a finding even on its own line
        let src = "// tq-lint: allow(made-up-rule): because\nfn f() {}\n";
        let fs = lint_source("serve/x.rs", src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "bad-pragma");
    }

    // ---------------------------------------------------- fixtures

    const FIXTURES: [(&str, &str, &str); 16] = [
        (
            "lock-across-blocking",
            "fixtures/serve/net/lock_across_blocking_bad.rs",
            include_str!("fixtures/serve/net/lock_across_blocking_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/lock_across_blocking_ok.rs",
            include_str!("fixtures/serve/net/lock_across_blocking_ok.rs"),
        ),
        (
            "lock-order",
            "fixtures/serve/net/lock_order_bad.rs",
            include_str!("fixtures/serve/net/lock_order_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/lock_order_ok.rs",
            include_str!("fixtures/serve/net/lock_order_ok.rs"),
        ),
        (
            "no-panic-paths",
            "fixtures/serve/net/no_panic_paths_bad.rs",
            include_str!("fixtures/serve/net/no_panic_paths_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/no_panic_paths_ok.rs",
            include_str!("fixtures/serve/net/no_panic_paths_ok.rs"),
        ),
        (
            "protocol-exhaustiveness",
            "fixtures/serve/net/protocol_exhaustiveness_bad.rs",
            include_str!("fixtures/serve/net/protocol_exhaustiveness_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/protocol_exhaustiveness_ok.rs",
            include_str!("fixtures/serve/net/protocol_exhaustiveness_ok.rs"),
        ),
        (
            "reactor-discipline",
            "fixtures/serve/net/reactor_discipline_bad.rs",
            include_str!("fixtures/serve/net/reactor_discipline_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/reactor_discipline_ok.rs",
            include_str!("fixtures/serve/net/reactor_discipline_ok.rs"),
        ),
        (
            "non-poisoning-lock",
            "fixtures/serve/net/non_poisoning_lock_bad.rs",
            include_str!("fixtures/serve/net/non_poisoning_lock_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/non_poisoning_lock_ok.rs",
            include_str!("fixtures/serve/net/non_poisoning_lock_ok.rs"),
        ),
        (
            "lock-across-blocking",
            "fixtures/serve/net/transitive_blocking_bad.rs",
            include_str!("fixtures/serve/net/transitive_blocking_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/transitive_blocking_ok.rs",
            include_str!("fixtures/serve/net/transitive_blocking_ok.rs"),
        ),
        (
            "stats-plumbing",
            "fixtures/serve/net/stats_plumbing_bad.rs",
            include_str!("fixtures/serve/net/stats_plumbing_bad.rs"),
        ),
        (
            "",
            "fixtures/serve/net/stats_plumbing_ok.rs",
            include_str!("fixtures/serve/net/stats_plumbing_ok.rs"),
        ),
    ];

    #[test]
    fn violating_fixtures_trip_their_rule() {
        for (rule, path, src) in FIXTURES {
            if rule.is_empty() {
                continue;
            }
            let hit = rules_hit(path, src);
            assert!(
                hit.iter().any(|r| r == rule),
                "{path}: expected a `{rule}` finding, got {hit:?}"
            );
        }
    }

    #[test]
    fn clean_fixtures_stay_clean() {
        for (rule, path, src) in FIXTURES {
            if !rule.is_empty() {
                continue;
            }
            let fs = lint_source(path, src);
            assert!(fs.is_empty(), "{path}: unexpected findings {fs:?}");
        }
    }

    #[test]
    fn self_deadlock_is_flagged() {
        let src = "fn f(s: &Shared) {\n\
                   let a = s.state.lock().unwrap_or_else(|p| p.into_inner());\n\
                   let b = s.state.lock().unwrap_or_else(|p| p.into_inner());\n\
                   }\n";
        let fs = lint_source("serve/net/x.rs", src);
        assert!(
            fs.iter().any(|f| f.rule == "lock-across-blocking"
                && f.message.contains("self-deadlock")),
            "{fs:?}"
        );
    }

    #[test]
    fn condvar_wait_consumes_the_guard() {
        // wait() hands the guard back to the condvar — the blocking
        // call itself must NOT count as blocking-under-lock
        let src = "fn f(s: &Shared) {\n\
                   let mut st = crate::util::lock(&s.state);\n\
                   st = s.cv.wait(st).unwrap_or_else(|p| p.into_inner());\n\
                   st.n += 1;\n\
                   }\n";
        let fs = lint_source("serve/net/x.rs", src);
        assert!(
            fs.iter().all(|f| f.rule != "lock-across-blocking"),
            "{fs:?}"
        );
    }

    #[test]
    fn test_regions_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n\
                   fn helper(v: &Vec<u32>) -> u32 { v.first().unwrap() + 1 }\n\
                   }\n";
        assert!(lint_source("serve/x.rs", src).is_empty());
        let src2 = "#[test]\nfn t() { Vec::<u32>::new().first().unwrap(); }\n";
        assert!(lint_source("serve/x.rs", src2).is_empty());
    }

    // ------------------------------------- interprocedural findings

    #[test]
    fn transitive_finding_prints_the_chain() {
        let (_rule, path, src) = FIXTURES
            .iter()
            .find(|(_, p, _)| p.ends_with("transitive_blocking_bad.rs"))
            .unwrap();
        let fs = lint_source(path, src);
        let f = fs
            .iter()
            .find(|f| f.rule == "lock-across-blocking")
            .expect("transitive fixture must trip lock-across-blocking");
        assert!(
            f.message.contains("call chain") && f.message.contains("->")
                && f.message.contains("[blocking:"),
            "chain missing from message: {}",
            f.message
        );
    }

    #[test]
    fn stats_plumbing_catches_a_dropped_absorb_mention() {
        // the acceptance contract: deleting any single field mention
        // from absorb turns the clean fixture into a failing one
        let (_r, path, src) = FIXTURES
            .iter()
            .find(|(_, p, _)| p.ends_with("stats_plumbing_ok.rs"))
            .unwrap();
        assert!(lint_source(path, src).is_empty());
        let broken = src.replacen("self.reuse_hits += o.reuse_hits;", "", 1);
        assert_ne!(&broken, src, "fixture must contain the absorb mention");
        let fs = lint_source(path, &broken);
        assert!(
            fs.iter().any(|f| f.rule == "stats-plumbing"
                && f.message.contains("reuse_hits")
                && f.message.contains("absorb")),
            "expected a stats-plumbing finding for reuse_hits, got {fs:?}"
        );
    }

    #[test]
    fn stats_plumbing_string_keys_count_as_mentions() {
        // serde fns usually mention fields as "key" literals — words
        // inside strings must count, and only as exact words
        let src = r#"
            struct ServerStats { requests: u64, failed_requests: u64 }
            impl ServerStats {
                fn absorb(&mut self, o: &ServerStats) {
                    self.requests += o.requests;
                    self.failed_requests += o.failed_requests;
                }
            }
            fn stats_to_json(s: &ServerStats) -> u64 {
                let _k = "requests failed_requests";
                s.requests
            }
            fn stats_from_json(n: u64) -> u64 { let _ = "requests"; n }
            fn stats_fold(a: u64) -> u64 { let _ = "requests failed_requests"; a }
        "#;
        let fs = lint_source("serve/stats.rs", src);
        // `failed_requests` appears in from_json only as a substring
        // of nothing — it is genuinely missing there
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].message.contains("failed_requests"));
        assert!(fs[0].message.contains("stats_from_json"));
    }

    // ----------------------------------------------------- dogfood

    #[test]
    fn dogfood_whole_tree_is_clean() {
        let run = lint_tree(&[tree_root()]).expect("walk src");
        assert!(
            run.findings.is_empty(),
            "lint findings in the tree:\n{}",
            run.findings
                .iter()
                .map(|f| f.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
        // the whole-program pass really saw the program
        assert!(run.files > 30, "only {} files walked", run.files);
        assert!(run.graph.fn_count() > 300, "index too small: {} fns", run.graph.fn_count());
        assert!(run.graph.blocking_count() > 10, "blocking inference found nothing");
        assert_eq!(run.timings.len(), 2 + RULE_LABELS.len() + 1);
    }

    #[test]
    fn pragma_count_matches_checked_in_baseline() {
        // the ratchet: pragmas may disappear (update the baseline),
        // never appear (CI fails before a new one lands silently)
        let baseline = parse_ratchet(include_str!("../../lint_pragmas.baseline"))
            .expect("baseline file must contain a count");
        let run = lint_tree(&[tree_root()]).expect("walk src");
        let listing = run
            .pragmas
            .iter()
            .map(|(f, r)| format!("{f}:{}: allow({}) — {}", r.line, r.rule, r.reason))
            .collect::<Vec<_>>()
            .join("\n");
        assert_eq!(
            run.pragmas.len(),
            baseline,
            "pragma count drifted from rust/lint_pragmas.baseline; \
             current pragmas:\n{listing}"
        );
    }

    #[test]
    fn json_report_shape() {
        let fs = vec![Finding {
            file: "a.rs".into(),
            line: 3,
            rule: "lock-order".into(),
            message: "m".into(),
        }];
        let j = report_json(&fs).dump();
        assert!(j.contains("\"findings\""));
        assert!(j.contains("\"lock-order\""));
        assert!(j.contains("\"line\":3"));
    }
}
