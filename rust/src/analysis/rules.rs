//! The rule engine: pragma parsing plus the concurrency/robustness
//! rules, each a pure function over the token stream emitting
//! [`Finding`]s. The two concurrency rules additionally consult the
//! whole-program [`Graph`] so one helper fn of indirection no longer
//! hides a blocking call. See the module doc on [`crate::analysis`]
//! for what each rule enforces and why.

use crate::analysis::graph::Graph;
use crate::analysis::lexer::{Tok, TokKind};
use crate::analysis::scope::{
    in_ranges, in_regions, match_brace, offload_ranges, stmt_start, FnBody,
};
use std::collections::{BTreeMap, BTreeSet};

/// One diagnostic: `file:line: rule: message`.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// Every rule a pragma may name. A pragma naming anything else is
/// itself a finding (`bad-pragma`), so suppressions can't rot silently.
///
/// `transitive-blocking` is special: it never emits findings under
/// that name. A `tq-lint: allow(transitive-blocking): reason` pragma
/// on a fn *definition* declares the fn non-blocking for call-graph
/// inference — a cut point for mode-dispatch shims whose hot path is
/// non-blocking (the direct rules still check the fn's own body).
pub const KNOWN_RULES: [&str; 8] = [
    "lock-across-blocking",
    "lock-order",
    "no-panic-paths",
    "protocol-exhaustiveness",
    "reactor-discipline",
    "non-poisoning-lock",
    "stats-plumbing",
    "transitive-blocking",
];

/// Calls that park the calling thread: socket and frame I/O, channel
/// receives, sleeps and joins. Holding a mutex across any of these
/// serializes every sibling on one peer's network behavior. These are
/// also the call-graph blocking *seeds* (`join` only when zero-arg —
/// `Path::join`/`slice::join` take arguments).
pub const BLOCKING: [&str; 14] = [
    "write_all", "flush", "read_exact", "write_encoded", "write_frame",
    "read_frame", "read_message", "send_message", "connect", "accept",
    "sleep", "join", "recv", "recv_timeout",
];

/// The declared lock-order registry: a mutex's *field name* maps to a
/// rank; acquisitions must strictly ascend. Unregistered names acquired
/// under a held guard are findings too — the registry is the contract.
const LOCK_RANKS: [(&str, i32); 10] = [
    ("state", 0), ("self", 0), ("shared", 0),
    ("readers", 1),
    ("bulk", 2),
    ("data", 3), ("ctrl", 3), ("stream", 3), ("half", 3),
    ("record", 4),
];

/// The stats-plumbing contract: every field of these structs (and
/// every variant of the `Msg` enum) must be *mentioned* — as an
/// identifier or a serde key inside a string literal — in each of the
/// listed fns, or the `stats-plumbing` rule fires at the field's
/// definition. `Type::name` specs resolve through the impl table,
/// bare names through the free-fn table; a listed fn that is absent
/// from the current run's index skips that requirement (so a
/// single-file fixture can carry its own miniature plumbing). The
/// path gate keys on the *defining* file, which keeps same-named
/// private types elsewhere (e.g. `util::threadpool`'s `Msg`) out of
/// the contract.
pub const STATS_PLUMBING: [(&str, &str, &[&str]); 5] = [
    ("ServerStats", "serve/", &[
        "stats_to_json", "stats_from_json", "ServerStats::absorb", "stats_fold",
    ]),
    ("WorkerStats", "serve/", &[
        "worker_to_json", "worker_from_json", "ServerStats::absorb",
    ]),
    ("RungStats", "serve/", &[
        "rung_to_json", "rung_from_json", "ServerStats::absorb",
    ]),
    ("SampleStats", "sampler/", &["Sampler::generate"]),
    ("Msg", "serve/net", &["Msg::kind", "Msg::to_json", "Msg::from_json"]),
];

/// Declared holes in the stats-plumbing contract:
/// `(type, field, required fn, reason)`. An intentional local-only
/// field is declared here, not silent — the reason is part of the
/// registry so the exemption survives review the same way a pragma
/// does. `stats_fold` starts from the latest delta (`d.clone()`), so
/// gauges and breakdowns that aren't additive counters ride along
/// without a mention.
pub const STATS_EXEMPT: [(&str, &str, &str, &str); 10] = [
    ("ServerStats", "batch_fill", "stats_fold",
     "fill-ratio gauge; latest delta wins via d.clone(), quantities fold"),
    ("ServerStats", "wall_s", "stats_fold",
     "per-snapshot wall clock; latest delta wins via d.clone()"),
    ("ServerStats", "queue_depth_avg", "stats_fold",
     "queue gauge sampled at snapshot time; latest delta wins"),
    ("ServerStats", "queue_depth_max", "stats_fold",
     "queue gauge sampled at snapshot time; latest delta wins"),
    ("ServerStats", "calib_cold_start_ms", "stats_fold",
     "one-shot startup measurement; latest delta wins"),
    ("ServerStats", "pending", "stats_fold",
     "instantaneous queue length, not an additive counter"),
    ("ServerStats", "rungs", "stats_fold",
     "per-rung breakdown carried whole from the latest delta"),
    ("ServerStats", "workers", "stats_fold",
     "per-worker breakdown carried whole from the latest delta"),
    ("WorkerStats", "ready", "ServerStats::absorb",
     "per-worker liveness flag; absorb aggregates cluster totals"),
    ("WorkerStats", "failed", "ServerStats::absorb",
     "per-worker liveness flag; absorb aggregates cluster totals"),
];

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

/// Enum paths that mark a `match` as protocol-shaped: a silent `_`
/// wildcard over one of these swallows future wire/state variants.
const PROTO_ENUMS: [&str; 5] = ["Msg", "WireError", "ShardState", "Role", "Health"];

fn lock_rank(name: &str) -> Option<i32> {
    LOCK_RANKS.iter().find(|(n, _)| *n == name).map(|&(_, r)| r)
}

/// One well-formed pragma as written, for the `--pragmas` report and
/// the CI ratchet.
#[derive(Clone, Debug)]
pub struct PragmaRec {
    pub line: usize,
    pub rule: String,
    pub reason: String,
    pub filewide: bool,
}

/// Per-file pragma state: line-scoped allows per rule, plus file-wide
/// allows, plus the raw records.
pub struct Pragmas {
    allow: BTreeMap<String, BTreeSet<usize>>,
    allow_file: BTreeSet<String>,
    records: Vec<PragmaRec>,
}

impl Pragmas {
    pub fn suppresses(&self, rule: &str, line: usize) -> bool {
        self.allow_file.contains(rule)
            || self.allow.get(rule).is_some_and(|ls| ls.contains(&line))
    }

    /// Every well-formed pragma in the file, in source order.
    pub fn records(&self) -> &[PragmaRec] {
        &self.records
    }
}

/// Parse `// tq-lint: allow(rule): reason` (line-scoped: the pragma's
/// own line and the first code line after it) and
/// `// tq-lint: allow-file(rule): reason` (file-wide) out of the *raw*
/// token stream. Malformed pragmas, unknown rules and missing reasons
/// are `bad-pragma` findings — a suppression must always say why.
pub fn parse_pragmas(raw: &[Tok], path: &str, findings: &mut Vec<Finding>) -> Pragmas {
    let mut out = Pragmas {
        allow: BTreeMap::new(),
        allow_file: BTreeSet::new(),
        records: Vec::new(),
    };
    for (idx, t) in raw.iter().enumerate() {
        if t.kind != TokKind::LineComment {
            continue;
        }
        let body = t.text.trim_start_matches('/').trim();
        let Some(rest) = body.strip_prefix("tq-lint:") else {
            continue;
        };
        let rest = rest.trim();
        let mut matched = false;
        for (kw, filewide) in [("allow-file(", true), ("allow(", false)] {
            let Some(inner) = rest.strip_prefix(kw) else {
                continue;
            };
            matched = true;
            let Some(close) = inner.find(')') else {
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "bad-pragma".to_string(),
                    message: "malformed tq-lint pragma (missing `)`)".to_string(),
                });
                break;
            };
            let rule = inner[..close].trim().to_string();
            let reason = inner[close + 1..].trim();
            if !KNOWN_RULES.contains(&rule.as_str()) {
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "bad-pragma".to_string(),
                    message: format!("unknown rule `{rule}` in pragma"),
                });
                break;
            }
            let reason_ok = reason
                .strip_prefix(':')
                .is_some_and(|r| !r.trim().is_empty());
            if !reason_ok {
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "bad-pragma".to_string(),
                    message: "pragma needs a `: reason`".to_string(),
                });
                break;
            }
            out.records.push(PragmaRec {
                line: t.line,
                rule: rule.clone(),
                reason: reason
                    .strip_prefix(':')
                    .map(|r| r.trim().to_string())
                    .unwrap_or_default(),
                filewide,
            });
            if filewide {
                out.allow_file.insert(rule);
            } else {
                let lines = out.allow.entry(rule).or_default();
                lines.insert(t.line);
                // the first code token after the comment: the pragma
                // covers that line too (the usual comment-above shape)
                for u in &raw[idx + 1..] {
                    if matches!(u.kind, TokKind::LineComment | TokKind::BlockComment) {
                        continue;
                    }
                    if u.line > t.line {
                        lines.insert(u.line);
                    }
                    break;
                }
            }
            break;
        }
        if !matched {
            findings.push(Finding {
                file: path.to_string(),
                line: t.line,
                rule: "bad-pragma".to_string(),
                message: "unrecognized tq-lint pragma".to_string(),
            });
        }
    }
    out
}

/// Name the mutex behind a `lock(` call site: the receiver ident for
/// method calls (`x.lock()`), the last ident inside the parens for the
/// free-fn helper (`lock(&self.state)` → `state`).
fn lock_receiver(toks: &[Tok], i: usize) -> Option<String> {
    if i >= 2 && toks[i - 1].text == "." && toks[i - 2].kind == TokKind::Ident {
        return Some(toks[i - 2].text.clone());
    }
    let mut depth = 0i32;
    let mut last_ident = None;
    let mut j = i + 1;
    while j < toks.len() {
        let t = &toks[j];
        if t.text == "(" {
            depth += 1;
        } else if t.text == ")" {
            depth -= 1;
            if depth == 0 {
                break;
            }
        } else if t.kind == TokKind::Ident {
            last_ident = Some(t.text.clone());
        }
        j += 1;
    }
    last_ident
}

struct Guard {
    /// Binding name (`<temp>` for an unbound guard expression).
    name: String,
    /// The mutex field it came from (registry key).
    src: String,
    /// Brace depth at acquisition — block exit releases it.
    depth: i32,
    line: usize,
    rank: Option<i32>,
    /// Temporary guards die at their statement's `;`.
    temp: bool,
    die_at: usize,
}

/// Rules 1+2 — `lock-across-blocking` and `lock-order` — share one
/// guard-tracking walk per function: let-bound guards live until
/// `drop()`, condvar-`wait()` consumption or block exit; temporaries
/// die at their statement. Blocking calls and same-mutex re-acquisition
/// while any guard is held are rule-1 findings; rank inversions and
/// unregistered acquisitions are rule-2. With the call graph, a call
/// that *resolves* to an inferred-blocking fn under a held guard is a
/// rule-1 finding too, and the message prints the blocking chain.
pub fn rule_locks(
    path: &str,
    toks: &[Tok],
    fns: &[FnBody],
    graph: &Graph,
    findings: &mut Vec<Finding>,
) {
    for f in fns {
        let (bs, be) = (f.body_start, f.body_end.min(toks.len().saturating_sub(1)));
        let fid = graph.fn_id(path, f.body_start);
        let mut guards: Vec<Guard> = Vec::new();
        let mut depth = 0i32;
        let offload = offload_ranges(toks, bs, be);
        let mut i = bs;
        while i <= be {
            let t = &toks[i];
            if in_ranges(i, &offload) {
                i += 1;
                continue;
            }
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        guards.retain(|g| g.depth <= depth);
                    }
                    ";" => guards.retain(|g| !(g.temp && i >= g.die_at)),
                    _ => {}
                }
                i += 1;
                continue;
            }
            if t.kind != TokKind::Ident {
                i += 1;
                continue;
            }
            let is_call = toks.get(i + 1).is_some_and(|nt| nt.text == "(") && i + 1 <= be;
            if t.text == "drop" && is_call {
                if let Some(d) = toks.get(i + 2).filter(|d| d.kind == TokKind::Ident) {
                    guards.retain(|g| g.name != d.text);
                }
                i += 1;
                continue;
            }
            if (t.text == "wait" || t.text == "wait_timeout") && is_call {
                // a condvar wait atomically releases (consumes) the
                // guard passed as its first argument
                if let Some(w) = toks.get(i + 2).filter(|w| w.kind == TokKind::Ident) {
                    guards.retain(|g| g.name != w.text);
                }
                i += 1;
                continue;
            }
            if t.text == "lock" && is_call {
                let recv = lock_receiver(toks, i).unwrap_or_else(|| "?".to_string());
                let rank = lock_rank(&recv);
                let mut reacquired = false;
                for g in &guards {
                    if g.name == recv
                        || (g.rank.is_some() && rank.is_some() && g.rank == rank && g.src == recv)
                    {
                        findings.push(Finding {
                            file: path.to_string(),
                            line: t.line,
                            rule: "lock-across-blocking".to_string(),
                            message: format!(
                                "re-acquiring `{recv}` while its guard from line {} \
                                 is still held (self-deadlock)",
                                g.line
                            ),
                        });
                        reacquired = true;
                        break;
                    }
                }
                if !reacquired {
                    if let (None, Some(g)) = (rank, guards.last()) {
                        findings.push(Finding {
                            file: path.to_string(),
                            line: t.line,
                            rule: "lock-order".to_string(),
                            message: format!(
                                "`{recv}` is not in the lock-order registry but is \
                                 acquired while `{}` (line {}) is held",
                                g.src, g.line
                            ),
                        });
                    } else if let Some(r) = rank {
                        for g in &guards {
                            if let Some(gr) = g.rank {
                                if gr >= r {
                                    findings.push(Finding {
                                        file: path.to_string(),
                                        line: t.line,
                                        rule: "lock-order".to_string(),
                                        message: format!(
                                            "acquiring `{recv}` (rank {r}) while holding \
                                             `{}` (rank {gr}, line {}) inverts the \
                                             declared order",
                                            g.src, g.line
                                        ),
                                    });
                                    break;
                                }
                            }
                        }
                    }
                }
                // guard lifetime: let-binding vs temporary
                let ss = stmt_start(toks, i, bs);
                let is_let = toks[ss].kind == TokKind::Ident && toks[ss].text == "let";
                if is_let {
                    let mut gi = ss + 1;
                    if toks.get(gi).is_some_and(|t| t.text == "mut") {
                        gi += 1;
                    }
                    let gname = match toks.get(gi) {
                        // `let (g, _) = …` destructuring
                        Some(t) if t.text == "(" => toks
                            .get(gi + 1)
                            .map(|t| t.text.clone())
                            .unwrap_or_else(|| "?".to_string()),
                        Some(t) if t.kind == TokKind::Ident => t.text.clone(),
                        _ => "?".to_string(),
                    };
                    guards.push(Guard {
                        name: gname,
                        src: recv,
                        depth,
                        line: t.line,
                        rank,
                        temp: false,
                        die_at: usize::MAX,
                    });
                } else {
                    // temporary guard: lives to the statement's `;`
                    let mut d2 = 0i32;
                    let mut j = i;
                    while j <= be {
                        let tj = &toks[j];
                        if tj.text == "(" || tj.text == "[" {
                            d2 += 1;
                        } else if tj.text == ")" || tj.text == "]" {
                            d2 -= 1;
                        } else if tj.text == ";" && d2 <= 0 {
                            break;
                        }
                        j += 1;
                    }
                    guards.push(Guard {
                        name: "<temp>".to_string(),
                        src: recv,
                        depth,
                        line: t.line,
                        rank,
                        temp: true,
                        die_at: j,
                    });
                }
                i += 1;
                continue;
            }
            if is_call && BLOCKING.contains(&t.text.as_str()) && !guards.is_empty() {
                let g = &guards[guards.len() - 1];
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "lock-across-blocking".to_string(),
                    message: format!(
                        "`{}` may block while the `{}` guard from line {} is held",
                        t.text, g.src, g.line
                    ),
                });
                i += 1;
                continue;
            }
            if is_call && !guards.is_empty() {
                // transitive: does this call resolve to a fn the graph
                // inferred as blocking?
                if let Some(chain) = fid.and_then(|id| graph.blocking_chain(id, i)) {
                    let g = &guards[guards.len() - 1];
                    findings.push(Finding {
                        file: path.to_string(),
                        line: t.line,
                        rule: "lock-across-blocking".to_string(),
                        message: format!(
                            "call chain `{} -> {chain}` may block while the `{}` \
                             guard from line {} is held",
                            f.name, g.src, g.line
                        ),
                    });
                    i += 1;
                    continue;
                }
            }
            if !guards.is_empty()
                && (t.text == "read" || t.text == "write")
                && is_call
                && i >= 1
                && toks[i - 1].text == "."
            {
                let g = &guards[guards.len() - 1];
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "lock-across-blocking".to_string(),
                    message: format!(
                        "socket `{}` under the `{}` guard from line {}",
                        t.text, g.src, g.line
                    ),
                });
            }
            guards.retain(|g| !(g.temp && i > g.die_at));
            i += 1;
        }
    }
}

/// Rule 3 — `no-panic-paths`: `.unwrap()`, `.expect()` and panic
/// macros are banned in production `serve/`, `runtime/`, `sampler/`
/// and `obs/` code (the sampler runs on serve worker threads, so a
/// panic there strands a whole batch; obs rides every hot path — a
/// panic in a histogram bucket must not take a request down with it);
/// on `serve/net` decode paths, so is direct
/// slice indexing of peer bytes (use `.get(..)` and a typed error —
/// peers control those lengths).
pub fn rule_no_panic(path: &str, toks: &[Tok], fns: &[FnBody], findings: &mut Vec<Finding>) {
    let inscope = (path.contains("serve/")
        || path.contains("runtime/")
        || path.contains("sampler/")
        || path.contains("obs/"))
        && !path.contains("testutil");
    if !inscope {
        return;
    }
    let mut seen = BTreeSet::new();
    for f in fns {
        let (bs, be) = (f.body_start, f.body_end.min(toks.len().saturating_sub(1)));
        let decode_fn = f.name.starts_with("decode") || f.name.ends_with("_from_json");
        for i in bs..=be {
            if seen.contains(&i) {
                continue;
            }
            let t = &toks[i];
            if t.kind != TokKind::Ident {
                continue;
            }
            let nt = if i + 1 <= be { toks.get(i + 1) } else { None };
            if (t.text == "unwrap" || t.text == "expect")
                && i >= 1
                && toks[i - 1].text == "."
                && nt.is_some_and(|n| n.text == "(")
            {
                seen.insert(i);
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "no-panic-paths".to_string(),
                    message: format!(
                        "`.{}()` in production serve/runtime code — return a \
                         typed error or degrade with a log",
                        t.text
                    ),
                });
            } else if PANIC_MACROS.contains(&t.text.as_str()) && nt.is_some_and(|n| n.text == "!")
            {
                seen.insert(i);
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "no-panic-paths".to_string(),
                    message: format!("`{}!` in production serve/runtime code", t.text),
                });
            } else if decode_fn
                && path.contains("serve/net")
                && nt.is_some_and(|n| n.text == "[")
                && i >= 1
                && toks[i - 1].text != "&"
                && toks[i - 1].text != "#"
            {
                seen.insert(i);
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "no-panic-paths".to_string(),
                    message: format!(
                        "indexing `{}[..]` on a decode path — use `.get(..)` and \
                         return a typed error",
                        t.text
                    ),
                });
            }
        }
    }
}

/// Rule 4 — `protocol-exhaustiveness`: in `serve/net`, a `match` whose
/// arms name a protocol enum (`Msg::`, `WireError::`, `ShardState::`,
/// `Role::`, `Health::`) must not end in a silent `_ => {}` /
/// `_ => ()` — a new wire variant would be swallowed without a trace.
pub fn rule_protocol(
    path: &str,
    toks: &[Tok],
    skip: &[(usize, usize)],
    findings: &mut Vec<Finding>,
) {
    if !path.contains("serve/net") {
        return;
    }
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if in_regions(i, skip) {
            i += 1;
            continue;
        }
        let t = &toks[i];
        if !(t.kind == TokKind::Ident && t.text == "match") {
            i += 1;
            continue;
        }
        // scrutinee runs to the `{` at bracket depth 0
        let mut d = 0i32;
        let mut j = i + 1;
        while j < n {
            let tj = &toks[j];
            if tj.text == "(" || tj.text == "[" {
                d += 1;
            } else if tj.text == ")" || tj.text == "]" {
                d -= 1;
            } else if tj.text == "{" && d == 0 {
                break;
            }
            j += 1;
        }
        if j >= n {
            break;
        }
        let body_end = match_brace(toks, j);
        let mentions = (j + 1..body_end.min(n)).any(|k| {
            toks[k].kind == TokKind::Ident
                && PROTO_ENUMS.contains(&toks[k].text.as_str())
                && toks.get(k + 1).is_some_and(|t| t.text == ":")
                && toks.get(k + 2).is_some_and(|t| t.text == ":")
        });
        if mentions {
            let mut depth = 1i32;
            let mut k = j + 1;
            while k < body_end.min(n) {
                let tk = &toks[k];
                if tk.text == "{" {
                    depth += 1;
                } else if tk.text == "}" {
                    depth -= 1;
                } else if depth == 1
                    && tk.kind == TokKind::Ident
                    && tk.text == "_"
                    && k + 2 < body_end
                    && toks[k + 1].text == "="
                    && toks[k + 2].text == ">"
                {
                    match toks.get(k + 3) {
                        Some(b) if b.text == "{" => {
                            let e = match_brace(toks, k + 3);
                            if e == k + 4 {
                                findings.push(Finding {
                                    file: path.to_string(),
                                    line: tk.line,
                                    rule: "protocol-exhaustiveness".to_string(),
                                    message: "silent `_ => {}` arm over a protocol \
                                              enum — new variants would be swallowed; \
                                              list them or log"
                                        .to_string(),
                                });
                            }
                        }
                        Some(b)
                            if b.text == "("
                                && toks.get(k + 4).is_some_and(|t| t.text == ")") =>
                        {
                            findings.push(Finding {
                                file: path.to_string(),
                                line: tk.line,
                                rule: "protocol-exhaustiveness".to_string(),
                                message: "silent `_ => ()` arm over a protocol enum"
                                    .to_string(),
                            });
                        }
                        _ => {}
                    }
                }
                k += 1;
            }
        }
        i = if body_end > i { body_end + 1 } else { i + 1 };
    }
}

/// Rule 5 — `reactor-discipline`: in `serve/net` (outside `reactor.rs`
/// itself), a reactor callback — an `on_*` fn or any fn taking a `Ctl`
/// parameter — must not make blocking calls; one stalled handler
/// freezes every connection on the loop. Work handed to
/// `pool.execute(..)` / `spawn(..)` is exempt (it runs elsewhere).
/// With the call graph, a handler call that resolves to an
/// inferred-blocking fn is a finding too, with the chain spelled out.
pub fn rule_reactor(
    path: &str,
    toks: &[Tok],
    fns: &[FnBody],
    graph: &Graph,
    findings: &mut Vec<Finding>,
) {
    if !path.contains("serve/net") || path.ends_with("reactor.rs") {
        return;
    }
    for f in fns {
        let (bs, be) = (f.body_start, f.body_end.min(toks.len().saturating_sub(1)));
        let fid = graph.fn_id(path, f.body_start);
        let mut is_handler = f.name.starts_with("on_");
        if !is_handler {
            // scan the signature backwards to the `fn` keyword
            let mut j = bs;
            let mut steps = 0;
            while j > 0 && steps <= 80 {
                j -= 1;
                steps += 1;
                if toks[j].text == "fn" {
                    break;
                }
                if toks[j].kind == TokKind::Ident && toks[j].text == "Ctl" {
                    is_handler = true;
                }
            }
        }
        if !is_handler {
            continue;
        }
        let offload = offload_ranges(toks, bs, be);
        for i in bs..=be {
            let t = &toks[i];
            if t.kind != TokKind::Ident || in_ranges(i, &offload) {
                continue;
            }
            let is_call = i + 1 <= be && toks.get(i + 1).is_some_and(|n| n.text == "(");
            if !is_call {
                continue;
            }
            if BLOCKING.contains(&t.text.as_str()) || t.text == "wait" || t.text == "wait_timeout"
            {
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "reactor-discipline".to_string(),
                    message: format!(
                        "`{}` can block the reactor thread inside `{}` — queue it \
                         on the pool or use the reactor timer/handle",
                        t.text, f.name
                    ),
                });
            } else if let Some(chain) = fid.and_then(|id| graph.blocking_chain(id, i)) {
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "reactor-discipline".to_string(),
                    message: format!(
                        "call chain `{} -> {chain}` can block the reactor thread — \
                         queue it on the pool or use the reactor timer/handle",
                        f.name
                    ),
                });
            }
        }
    }
}

/// Rule 7 — `stats-plumbing`: every member named by [`STATS_PLUMBING`]
/// must be mentioned in each of its required fns (or carry a
/// [`STATS_EXEMPT`] entry). Mentions are identifiers *or* words inside
/// string literals, so a serde key like `"reuse_hits"` counts; words
/// are exact matches, so `requests` does not satisfy
/// `failed_requests`. Findings anchor at the member's definition line
/// — the place a new field gets added is the place the reminder shows
/// up.
pub fn rule_stats_plumbing(graph: &Graph, findings: &mut Vec<Finding>) {
    for (ty, gate, required) in STATS_PLUMBING {
        let mut members: Vec<(&str, &str, usize)> = Vec::new();
        for (file, s) in graph.structs() {
            if s.name == ty && file.contains(gate) {
                for fl in &s.fields {
                    members.push((file.as_str(), fl.name.as_str(), fl.line));
                }
            }
        }
        for (file, e) in graph.enums() {
            if e.name == ty && file.contains(gate) {
                for v in &e.variants {
                    members.push((file.as_str(), v.name.as_str(), v.line));
                }
            }
        }
        if members.is_empty() {
            continue;
        }
        for spec in required {
            let ids = graph.resolve_spec(spec);
            if ids.is_empty() {
                // the required fn is outside this run's index (e.g. a
                // single-file lint): nothing to check against
                continue;
            }
            let mut mentioned: BTreeSet<&str> = BTreeSet::new();
            for id in &ids {
                mentioned.extend(graph.mentions(*id).iter().map(String::as_str));
            }
            for (file, name, line) in &members {
                if mentioned.contains(name) {
                    continue;
                }
                let exempt = STATS_EXEMPT
                    .iter()
                    .any(|(t, fl, sp, _)| *t == ty && fl == name && sp == spec);
                if exempt {
                    continue;
                }
                findings.push(Finding {
                    file: file.to_string(),
                    line: *line,
                    rule: "stats-plumbing".to_string(),
                    message: format!(
                        "`{ty}.{name}` is not mentioned in `{spec}` — plumb the \
                         new member through, or declare it in STATS_EXEMPT \
                         (analysis/rules.rs) with a reason"
                    ),
                });
            }
        }
    }
}

/// Rule 6 — `non-poisoning-lock`: `.lock().unwrap()` /
/// `.lock().expect(..)` propagate poisoning; every call site belongs on
/// [`crate::util::lock`], which recovers instead.
pub fn rule_lock_helper(
    path: &str,
    toks: &[Tok],
    skip: &[(usize, usize)],
    findings: &mut Vec<Finding>,
) {
    let n = toks.len();
    for i in 0..n {
        if in_regions(i, skip) {
            continue;
        }
        let t = &toks[i];
        if t.kind == TokKind::Ident
            && t.text == "lock"
            && i + 4 < n
            && toks[i + 1].text == "("
            && toks[i + 2].text == ")"
            && toks[i + 3].text == "."
            && (toks[i + 4].text == "unwrap" || toks[i + 4].text == "expect")
        {
            findings.push(Finding {
                file: path.to_string(),
                line: t.line,
                rule: "non-poisoning-lock".to_string(),
                message: "`.lock().unwrap()` poisons on panic — use \
                          crate::util::lock (non-poisoning) instead"
                    .to_string(),
            });
        }
    }
}
