//! Multi-region quantization (paper §III-C), host mirror of the fused
//! pallas kernels (`kernels/mrq.py`).
//!
//! Post-softmax: values concentrate near 0 in [0, 1]. Two regions —
//! R1 = [0, 2^{k-1}·s1) quantized with the calibrated step s1 (2^{k-1}
//! levels), R2 = [2^{k-1}·s1, 1] with the *fixed* step s2 = 1/2^{k-1}.
//!
//! Post-GELU: negative tail vs positive body. R1 = [−2^{k-1}·s1, 0] with
//! step s1, R2 = [0, 2^{k-1}·s2) with step s2, calibrated independently.

/// Twin-uniform post-softmax quantizer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MrqSoftmax {
    /// Calibrated small-value step.
    pub s1: f32,
    /// 2^{k-1} as f32.
    pub half: f32,
}

impl MrqSoftmax {
    pub fn new(s1: f32, bits: u32) -> MrqSoftmax {
        MrqSoftmax { s1, half: (1u64 << (bits - 1)) as f32 }
    }

    /// Default s1 so R1 covers [0, 1/2^{k-1}) — the PTQ4ViT-style init.
    pub fn default_for_bits(bits: u32) -> MrqSoftmax {
        let half = (1u64 << (bits - 1)) as f32;
        MrqSoftmax { s1: 1.0 / (half * half), half }
    }

    pub fn s2(&self) -> f32 {
        1.0 / self.half
    }

    pub fn boundary(&self) -> f32 {
        self.half * self.s1
    }

    pub fn fakequant(&self, p: f32) -> f32 {
        if self.s1 <= 0.0 {
            return p;
        }
        if p < self.boundary() {
            (p / self.s1).round().clamp(0.0, self.half - 1.0) * self.s1
        } else {
            let s2 = self.s2();
            (p / s2).round().clamp(0.0, self.half) * s2
        }
    }

    pub fn fakequant_slice(&self, x: &mut [f32]) {
        if self.s1 <= 0.0 {
            return;
        }
        for v in x.iter_mut() {
            *v = self.fakequant(*v);
        }
    }
}

/// Two-region post-GELU quantizer (negative / positive).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MrqGelu {
    /// Negative-region step.
    pub s1: f32,
    /// Positive-region step.
    pub s2: f32,
    /// 2^{k-1} as f32.
    pub half: f32,
}

impl MrqGelu {
    pub fn new(s1: f32, s2: f32, bits: u32) -> MrqGelu {
        MrqGelu { s1, s2, half: (1u64 << (bits - 1)) as f32 }
    }

    /// Min–max init: negative tail of GELU is bounded by ≈ −0.17·|x|…
    /// use the observed extremes per region.
    pub fn from_tensor(data: &[f32], bits: u32) -> MrqGelu {
        let half = (1u64 << (bits - 1)) as f32;
        let mut neg_min = 0.0f32;
        let mut pos_max = 0.0f32;
        for &x in data {
            if x < neg_min {
                neg_min = x;
            }
            if x > pos_max {
                pos_max = x;
            }
        }
        // positive grid tops out at level half−1, negative at −half
        let s1 = (-neg_min).max(1e-8) / half;
        let s2 = pos_max.max(1e-8) / (half - 1.0);
        MrqGelu { s1, s2, half }
    }

    pub fn fakequant(&self, g: f32) -> f32 {
        if self.s1 <= 0.0 {
            return g;
        }
        if g < 0.0 {
            (g / self.s1).round().clamp(-self.half, 0.0) * self.s1
        } else {
            (g / self.s2).round().clamp(0.0, self.half - 1.0) * self.s2
        }
    }

    pub fn fakequant_slice(&self, x: &mut [f32]) {
        if self.s1 <= 0.0 {
            return;
        }
        for v in x.iter_mut() {
            *v = self.fakequant(*v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_small_values_use_fine_grid() {
        let m = MrqSoftmax::new(1.0 / 1024.0, 8); // s1 << s2 = 1/128
        // a tiny probability keeps sub-s2 resolution
        let p = 0.002f32;
        let err = (m.fakequant(p) - p).abs();
        assert!(err <= m.s1 * 0.5 + 1e-7);
        // a large probability snaps to the coarse fixed grid
        let p2 = 0.9f32;
        let err2 = (m.fakequant(p2) - p2).abs();
        assert!(err2 <= m.s2() * 0.5 + 1e-7);
    }

    #[test]
    fn softmax_one_representable() {
        let m = MrqSoftmax::default_for_bits(8);
        assert!((m.fakequant(1.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_regions_partition_unit_interval() {
        let m = MrqSoftmax::new(0.001, 8);
        let b = m.boundary();
        assert!(b > 0.0 && b < 1.0);
        // continuity-ish: both sides of the boundary stay within coarse step
        let just_below = m.fakequant(b - 1e-4);
        let just_above = m.fakequant(b + 1e-4);
        assert!((just_above - just_below).abs() <= m.s2() + m.s1);
    }

    #[test]
    fn softmax_monotone_nondecreasing() {
        let m = MrqSoftmax::new(0.0005, 6);
        let mut prev = -1.0f32;
        let mut p = 0.0f32;
        while p <= 1.0 {
            let q = m.fakequant(p);
            assert!(q >= prev - 1e-6, "non-monotone at {p}");
            prev = q;
            p += 0.001;
        }
    }

    #[test]
    fn gelu_preserves_sign_regions() {
        let m = MrqGelu::new(0.002, 0.02, 8);
        assert!(m.fakequant(-0.15) <= 0.0);
        assert!(m.fakequant(0.5) >= 0.0);
        assert_eq!(m.fakequant(0.0), 0.0);
    }

    #[test]
    fn gelu_from_tensor_covers_extremes() {
        let data = [-0.17f32, 0.0, 1.4, 3.0, -0.05];
        let m = MrqGelu::from_tensor(&data, 8);
        // extremes representable to within half a step
        assert!((m.fakequant(3.0) - 3.0).abs() <= m.s2 * 0.5 + 1e-6);
        assert!((m.fakequant(-0.17) + 0.17).abs() <= m.s1 * 0.5 + 1e-6);
    }

    #[test]
    fn gelu_negative_region_finer_than_positive() {
        // the GELU negative tail is narrow → s1 ends up smaller
        let data: Vec<f32> = (-300..3000).map(|i| {
            let x = i as f32 * 0.01;
            crate::tensor::gelu_scalar(x)
        }).collect();
        let m = MrqGelu::from_tensor(&data, 8);
        assert!(m.s1 < m.s2);
    }

    #[test]
    fn bypass_identity() {
        let m = MrqSoftmax { s1: 0.0, half: 0.0 };
        assert_eq!(m.fakequant(0.37), 0.37);
        let g = MrqGelu { s1: 0.0, s2: 0.0, half: 0.0 };
        assert_eq!(g.fakequant(-0.1), -0.1);
    }
}
