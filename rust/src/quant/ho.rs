//! Hessian-guided objective (paper §III-B, eq. 14–17).
//!
//! The pre-activation Hessian is approximated by the diagonal Fisher
//! information matrix: `H ≈ diag((∂L/∂z)²)` with L the DDPM noise-MSE
//! (eq. 11). The quantization loss for a layer is then
//! `Σᵢ gᵢ² · (z_fp,i − z_q,i)²` — squared gradients captured once by the
//! `dit_capture` artifact and reused across every candidate evaluation.

/// Fisher-weighted (HO) or plain (MSE-baseline) sum of squared errors.
///
/// `grad` holds ∂L/∂z (NOT pre-squared); pass `None` for the plain MSE
/// objective used by the ablation baseline (Table III row 1).
pub fn quant_loss(z_fp: &[f32], z_q: &[f32], grad: Option<&[f32]>) -> f64 {
    debug_assert_eq!(z_fp.len(), z_q.len());
    match grad {
        Some(g) => {
            debug_assert_eq!(g.len(), z_fp.len());
            let mut acc = 0.0f64;
            for i in 0..z_fp.len() {
                let d = (z_fp[i] - z_q[i]) as f64;
                let w = g[i] as f64;
                acc += w * w * d * d;
            }
            acc
        }
        None => {
            let mut acc = 0.0f64;
            for i in 0..z_fp.len() {
                let d = (z_fp[i] - z_q[i]) as f64;
                acc += d * d;
            }
            acc
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_error_is_zero_loss() {
        let z = [1.0f32, -2.0, 3.0];
        assert_eq!(quant_loss(&z, &z, None), 0.0);
        assert_eq!(quant_loss(&z, &z, Some(&[1.0, 1.0, 1.0])), 0.0);
    }

    #[test]
    fn unit_weights_equal_mse_sum() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.5f32, 2.0, 2.0];
        let plain = quant_loss(&a, &b, None);
        let unit = quant_loss(&a, &b, Some(&[1.0, 1.0, 1.0]));
        assert!((plain - unit).abs() < 1e-12);
        assert!((plain - (0.25 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn fisher_emphasizes_high_gradient_outputs() {
        let z_fp = [0.0f32, 0.0];
        // same absolute error on both coords
        let z_q = [0.1f32, 0.1];
        // coord 0 has 10x the gradient → its error dominates
        let g = [10.0f32, 1.0];
        let loss = quant_loss(&z_fp, &z_q, Some(&g));
        let expected = 100.0 * 0.01 + 1.0 * 0.01;
        assert!((loss - expected as f64).abs() < 1e-6);
        // a candidate that fixes coord 0 wins even if coord 1 worsens
        let fix0 = quant_loss(&z_fp, &[0.0, 0.3], Some(&g));
        assert!(fix0 < loss);
    }

    #[test]
    fn grad_sign_irrelevant() {
        let a = [1.0f32];
        let b = [2.0f32];
        let l1 = quant_loss(&a, &b, Some(&[3.0]));
        let l2 = quant_loss(&a, &b, Some(&[-3.0]));
        assert_eq!(l1, l2);
    }
}
