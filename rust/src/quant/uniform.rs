//! Uniform asymmetric quantization — paper eq. (5)/(6).
//!
//! `x̂ = s · (clip(⌊x/s⌉ + z, 0, 2^k − 1) − z)`; `s` from the value range
//! and `z` the zero-point. Must match `kernels/quant.py` and
//! `kernels/ref.py` bit-for-bit in f32 (tested both here and in the
//! cross-language integration tests).

/// Uniform asymmetric quantizer parameters for bit-width k.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UniformQ {
    pub s: f32,
    pub z: f32,
    /// 2^k − 1 as f32 (shared encoding with the qparams vector).
    pub levels: f32,
}

impl UniformQ {
    /// Min–max initialization (the classic PTQ starting point).
    pub fn from_minmax(min: f32, max: f32, bits: u32) -> UniformQ {
        let levels = ((1u64 << bits) - 1) as f32;
        let range = (max - min).max(1e-8);
        let s = range / levels;
        let z = (-min / s).round();
        UniformQ { s, z, levels }
    }

    /// Initialize from the extreme values of a tensor.
    pub fn from_tensor(data: &[f32], bits: u32) -> UniformQ {
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &x in data {
            mn = mn.min(x);
            mx = mx.max(x);
        }
        if !mn.is_finite() || !mx.is_finite() {
            return UniformQ { s: 0.0, z: 0.0, levels: 0.0 };
        }
        Self::from_minmax(mn, mx, bits)
    }

    /// Same range scaled by `c` around its midpoint (candidate grids).
    pub fn scaled(&self, c: f32) -> UniformQ {
        UniformQ { s: self.s * c, z: self.z, levels: self.levels }
    }

    pub fn fakequant(&self, x: f32) -> f32 {
        if self.s <= 0.0 {
            return x;
        }
        let q = (x / self.s).round() + self.z;
        let q = q.clamp(0.0, self.levels);
        (q - self.z) * self.s
    }

    pub fn fakequant_slice(&self, x: &mut [f32]) {
        if self.s <= 0.0 {
            return;
        }
        for v in x.iter_mut() {
            let q = (*v / self.s).round() + self.z;
            *v = (q.clamp(0.0, self.levels) - self.z) * self.s;
        }
    }

    /// Fake-quant into a fresh vector.
    pub fn fakequant_vec(&self, x: &[f32]) -> Vec<f32> {
        let mut out = x.to_vec();
        self.fakequant_slice(&mut out);
        out
    }

    /// Representable range [lo, hi] of the grid.
    pub fn range(&self) -> (f32, f32) {
        ((0.0 - self.z) * self.s, (self.levels - self.z) * self.s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_on_grid_points_is_exact() {
        let q = UniformQ::from_minmax(-1.0, 1.0, 8);
        for i in 0..=255 {
            let x = (i as f32 - q.z) * q.s;
            assert!((q.fakequant(x) - x).abs() < 1e-6);
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let q = UniformQ::from_minmax(0.0, 1.0, 8);
        let (lo, hi) = q.range();
        assert!(q.fakequant(2.0) <= hi + 1e-6);
        assert!(q.fakequant(-2.0) >= lo - 1e-6);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let q = UniformQ::from_minmax(-2.0, 2.0, 8);
        let mut x = -2.0f32;
        while x <= 2.0 {
            let e = (q.fakequant(x) - x).abs();
            assert!(e <= q.s * 0.5 + 1e-6, "x={x} err={e}");
            x += 0.013;
        }
    }

    #[test]
    fn lower_bits_coarser() {
        let q8 = UniformQ::from_minmax(-1.0, 1.0, 8);
        let q4 = UniformQ::from_minmax(-1.0, 1.0, 4);
        assert!(q4.s > q8.s);
        // mean abs error over a sweep is larger at 4 bits
        let xs: Vec<f32> = (0..1000).map(|i| -1.0 + 0.002 * i as f32).collect();
        let e8: f32 = xs.iter().map(|&x| (q8.fakequant(x) - x).abs()).sum();
        let e4: f32 = xs.iter().map(|&x| (q4.fakequant(x) - x).abs()).sum();
        assert!(e4 > e8);
    }

    #[test]
    fn zero_maps_near_zero() {
        // asymmetric range — zero point keeps 0 representable
        let q = UniformQ::from_minmax(-0.3, 0.9, 8);
        assert!(q.fakequant(0.0).abs() <= q.s * 0.5 + 1e-6);
    }

    #[test]
    fn from_tensor_covers_data() {
        let data = [-0.5f32, 0.1, 0.9, 0.3];
        let q = UniformQ::from_tensor(&data, 8);
        let (lo, hi) = q.range();
        assert!(lo <= -0.5 + q.s && hi >= 0.9 - q.s);
    }

    #[test]
    fn degenerate_tensor_safe() {
        let q = UniformQ::from_tensor(&[0.5; 8], 8);
        // constant tensor: tiny range, still finite behaviour
        assert!(q.s > 0.0);
        assert!(q.fakequant(0.5).is_finite());
    }

    #[test]
    fn bypass_identity() {
        let q = UniformQ { s: 0.0, z: 0.0, levels: 0.0 };
        assert_eq!(q.fakequant(1.234), 1.234);
    }
}
