//! The paper's quantization math, host-side.
//!
//! * [`uniform`] — uniform asymmetric quantization (paper eq. 5/6) and
//!   min–max initialization.
//! * [`mrq`] — multi-region quantization for post-softmax / post-GELU
//!   distributions (paper §III-C).
//! * [`ho`] — Hessian-guided objective: diagonal-Fisher-weighted output
//!   error (paper eq. 14–17).
//! * [`search`] — candidate-scale grids + alternating W/X optimization
//!   (Algorithm 1 phase 3).
//!
//! These operate on host tensors; the AOT model applies the *same*
//! arithmetic in-graph (pallas kernels), with parameters fed at runtime.

pub mod ho;
pub mod mrq;
pub mod search;
pub mod uniform;

pub use mrq::{MrqGelu, MrqSoftmax};
pub use uniform::UniformQ;

/// Stride of one site slot in the flat qparams vector (matches
/// `python/compile/config.py::QP_STRIDE`).
pub const QP_STRIDE: usize = 4;

/// A site's quantization parameters, in every paper variant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SiteParams {
    /// Full precision (bypass slot: s = 0).
    Bypass,
    Uniform(UniformQ),
    MrqSoftmax(MrqSoftmax),
    MrqGelu(MrqGelu),
}

impl SiteParams {
    /// Encode into a stride-4 qparams slot (layout shared with L2).
    pub fn encode(&self, slot: &mut [f32]) {
        assert!(slot.len() >= QP_STRIDE);
        slot[..QP_STRIDE].fill(0.0);
        match self {
            SiteParams::Bypass => {}
            SiteParams::Uniform(u) => {
                slot[0] = u.s;
                slot[1] = u.z;
                slot[2] = u.levels;
            }
            SiteParams::MrqSoftmax(m) => {
                slot[0] = m.s1;
                slot[1] = m.half;
            }
            SiteParams::MrqGelu(m) => {
                slot[0] = m.s1;
                slot[1] = m.s2;
                slot[2] = m.half;
            }
        }
    }

    /// Apply fake-quant to a slice (host mirror of the pallas kernels).
    pub fn apply(&self, x: &mut [f32]) {
        match self {
            SiteParams::Bypass => {}
            SiteParams::Uniform(u) => u.fakequant_slice(x),
            SiteParams::MrqSoftmax(m) => m.fakequant_slice(x),
            SiteParams::MrqGelu(m) => m.fakequant_slice(x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_layout() {
        let mut slot = [9.0f32; 4];
        SiteParams::Bypass.encode(&mut slot);
        assert_eq!(slot, [0.0; 4]);

        SiteParams::Uniform(UniformQ { s: 0.5, z: 3.0, levels: 255.0 })
            .encode(&mut slot);
        assert_eq!(slot, [0.5, 3.0, 255.0, 0.0]);

        SiteParams::MrqSoftmax(MrqSoftmax { s1: 0.01, half: 128.0 })
            .encode(&mut slot);
        assert_eq!(slot, [0.01, 128.0, 0.0, 0.0]);

        SiteParams::MrqGelu(MrqGelu { s1: 0.02, s2: 0.03, half: 32.0 })
            .encode(&mut slot);
        assert_eq!(slot, [0.02, 0.03, 32.0, 0.0]);
    }

    #[test]
    fn bypass_is_identity() {
        let mut x = vec![0.1, -0.7, 3.0];
        let orig = x.clone();
        SiteParams::Bypass.apply(&mut x);
        assert_eq!(x, orig);
    }
}
