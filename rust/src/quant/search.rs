//! Candidate-scale search + alternating optimization (Algorithm 1 ph. 3).
//!
//! A [`Problem`] is the captured, subsampled evidence for one quantizable
//! layer: groups of (A, B) operand pairs plus optional Fisher gradients
//! of the layer's pre-activation output. Linear layers are the 1-group
//! special case with B = the weight matrix. `eval` recomputes the layer
//! output under candidate parameters and scores it with
//! [`ho::quant_loss`]; candidate sets are evaluated in parallel
//! (`par_map`) and the best survives. Coarse→fine two-stage grids keep
//! the evaluation count low — this is the efficiency edge Table IV
//! measures against the PTQ4DiT-style calibrator.

use crate::quant::ho::quant_loss;
use crate::quant::{MrqGelu, MrqSoftmax, SiteParams, UniformQ};
use crate::tensor::Tensor;
use crate::util::threadpool::par_map;

/// Captured evidence for one layer's candidate search.
pub struct Problem {
    /// Per-group left operands (M×K).
    pub a: Vec<Tensor>,
    /// Per-group right operands (K×N).
    pub b: Vec<Tensor>,
    /// Per-group ∂L/∂z (M×N); `None` → plain-MSE objective.
    pub fisher: Option<Vec<Tensor>>,
    /// FP reference outputs (computed once at construction).
    z_fp: Vec<Tensor>,
}

impl Problem {
    pub fn new(a: Vec<Tensor>, b: Vec<Tensor>,
               fisher: Option<Vec<Tensor>>) -> Problem {
        assert_eq!(a.len(), b.len());
        if let Some(f) = &fisher {
            assert_eq!(f.len(), a.len());
        }
        let z_fp = a.iter().zip(&b).map(|(x, w)| x.matmul(w)).collect();
        Problem { a, b, fisher, z_fp }
    }

    /// Score candidate params for the A and B operand sites.
    pub fn eval(&self, qa: &SiteParams, qb: &SiteParams) -> f64 {
        let mut total = 0.0f64;
        for g in 0..self.a.len() {
            let mut aq = self.a[g].clone();
            qa.apply(&mut aq.data);
            let mut bq = self.b[g].clone();
            qb.apply(&mut bq.data);
            let z_q = aq.matmul(&bq);
            let grad = self.fisher.as_ref().map(|f| f[g].data.as_slice());
            total += quant_loss(&self.z_fp[g].data, &z_q.data, grad);
        }
        total
    }

    /// Data extremes of the A operands (for candidate grids).
    pub fn a_minmax(&self) -> (f32, f32) {
        minmax(self.a.iter())
    }

    pub fn b_minmax(&self) -> (f32, f32) {
        minmax(self.b.iter())
    }
}

fn minmax<'a, I: Iterator<Item = &'a Tensor>>(it: I) -> (f32, f32) {
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for t in it {
        mn = mn.min(t.min());
        mx = mx.max(t.max());
    }
    (mn, mx)
}

/// Uniform candidates: clip-ratio grid over the observed range
/// (c·min, c·max), the standard PTQ scale search.
pub fn uniform_candidates(mn: f32, mx: f32, bits: u32, n: usize)
                          -> Vec<SiteParams> {
    let n = n.max(2);
    (0..n)
        .map(|i| {
            let c = 0.25 + (1.15 - 0.25) * i as f32 / (n - 1) as f32;
            SiteParams::Uniform(UniformQ::from_minmax(c * mn, c * mx, bits))
        })
        .collect()
}

/// Post-softmax MRQ candidates: geometric grid over the region boundary
/// `2^{k-1}·s1 ∈ [1e-4, 1]` (probabilities live in [0, 1]).
pub fn softmax_candidates(bits: u32, n: usize) -> Vec<SiteParams> {
    let half = (1u64 << (bits - 1)) as f32;
    let n = n.max(2);
    (0..n)
        .map(|i| {
            let t = i as f32 / (n - 1) as f32;
            let boundary = 10f32.powf(-4.0 + 4.0 * t); // 1e-4 → 1
            SiteParams::MrqSoftmax(MrqSoftmax { s1: boundary / half, half })
        })
        .collect()
}

/// Post-GELU MRQ candidates around the min–max init, one region at a
/// time (`which` = 0 → negative s1, 1 → positive s2). The regions are
/// searched in two 1-D passes.
pub fn gelu_candidates(init: MrqGelu, which: usize, n: usize)
                       -> Vec<SiteParams> {
    let n = n.max(2);
    (0..n)
        .map(|i| {
            let c = 0.25 + (1.15 - 0.25) * i as f32 / (n - 1) as f32;
            let m = match which {
                0 => MrqGelu { s1: c * init.s1, ..init },
                _ => MrqGelu { s2: c * init.s2, ..init },
            };
            SiteParams::MrqGelu(m)
        })
        .collect()
}

/// Pick the best candidate by parallel evaluation.
pub fn argmin_candidates<F>(cands: &[SiteParams], score: F)
                            -> (SiteParams, f64)
where
    F: Fn(&SiteParams) -> f64 + Sync,
{
    assert!(!cands.is_empty());
    let losses = par_map(cands, |c| score(c));
    let (mut best_i, mut best_l) = (0usize, f64::INFINITY);
    for (i, &l) in losses.iter().enumerate() {
        if l < best_l {
            best_l = l;
            best_i = i;
        }
    }
    (cands[best_i], best_l)
}

/// Two-stage coarse→fine 1-D search over a candidate generator.
///
/// `gen(n, center_hint)`: builds a grid; the fine stage re-grids around
/// the coarse winner by index interpolation. With `n_total` evaluations
/// split 60/40 this matches an 80-candidate flat grid to <1% loss in
/// practice at half the cost (EXPERIMENTS.md §Perf).
pub fn coarse_fine<F, G>(n_total: usize, gen: G, score: F)
                         -> (SiteParams, f64)
where
    F: Fn(&SiteParams) -> f64 + Sync,
    G: Fn(usize) -> Vec<SiteParams>,
{
    let n_coarse = (n_total * 3 / 5).max(2);
    let coarse = gen(n_coarse);
    let (best_c, loss_c) = argmin_candidates(&coarse, &score);
    // refine: densify around the winner by scaling its step ±15%
    let n_fine = n_total.saturating_sub(n_coarse).max(2);
    let fine: Vec<SiteParams> = (0..n_fine)
        .map(|i| {
            let c = 0.85 + 0.30 * i as f32 / (n_fine - 1) as f32;
            scale_params(&best_c, c)
        })
        .collect();
    let (best_f, loss_f) = argmin_candidates(&fine, &score);
    if loss_f < loss_c {
        (best_f, loss_f)
    } else {
        (best_c, loss_c)
    }
}

fn scale_params(p: &SiteParams, c: f32) -> SiteParams {
    match p {
        SiteParams::Bypass => SiteParams::Bypass,
        SiteParams::Uniform(u) => SiteParams::Uniform(UniformQ {
            s: u.s * c,
            z: u.z,
            levels: u.levels,
        }),
        SiteParams::MrqSoftmax(m) => SiteParams::MrqSoftmax(MrqSoftmax {
            s1: m.s1 * c,
            half: m.half,
        }),
        SiteParams::MrqGelu(m) => SiteParams::MrqGelu(MrqGelu {
            s1: m.s1 * c,
            s2: m.s2 * c,
            half: m.half,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_problem(fisher: bool) -> Problem {
        let mut rng = Rng::new(1);
        let a = Tensor::new(vec![16, 8], rng.normal_vec(128));
        let b = Tensor::new(vec![8, 4], rng.normal_vec(32));
        let f = if fisher {
            Some(vec![Tensor::new(vec![16, 4], rng.normal_vec(64))])
        } else {
            None
        };
        Problem::new(vec![a], vec![b], f)
    }

    #[test]
    fn bypass_scores_zero() {
        let p = toy_problem(true);
        assert_eq!(p.eval(&SiteParams::Bypass, &SiteParams::Bypass), 0.0);
    }

    #[test]
    fn quantization_increases_loss_monotonically_in_coarseness() {
        let p = toy_problem(false);
        let (mn, mx) = p.a_minmax();
        let q8 = SiteParams::Uniform(UniformQ::from_minmax(mn, mx, 8));
        let q4 = SiteParams::Uniform(UniformQ::from_minmax(mn, mx, 4));
        let l8 = p.eval(&q8, &SiteParams::Bypass);
        let l4 = p.eval(&q4, &SiteParams::Bypass);
        assert!(l8 > 0.0);
        assert!(l4 > l8);
    }

    #[test]
    fn search_beats_minmax_init() {
        // heavy-tailed data: clipping outliers should win
        let mut rng = Rng::new(2);
        let mut data = rng.normal_vec(512);
        data[0] = 40.0; // outlier
        let a = Tensor::new(vec![64, 8], data);
        let b = Tensor::new(vec![8, 8], rng.normal_vec(64));
        let p = Problem::new(vec![a], vec![b], None);
        let (mn, mx) = p.a_minmax();
        let init = SiteParams::Uniform(UniformQ::from_minmax(mn, mx, 6));
        let init_loss = p.eval(&init, &SiteParams::Bypass);
        let cands = uniform_candidates(mn, mx, 6, 40);
        let (_, best_loss) =
            argmin_candidates(&cands, |c| p.eval(c, &SiteParams::Bypass));
        assert!(best_loss < init_loss, "{best_loss} !< {init_loss}");
    }

    #[test]
    fn softmax_candidates_cover_decades() {
        let cands = softmax_candidates(8, 10);
        let bounds: Vec<f32> = cands
            .iter()
            .map(|c| match c {
                SiteParams::MrqSoftmax(m) => m.boundary(),
                _ => unreachable!(),
            })
            .collect();
        assert!(bounds[0] < 2e-4);
        assert!(*bounds.last().unwrap() > 0.9);
    }

    #[test]
    fn coarse_fine_no_worse_than_coarse() {
        let p = toy_problem(true);
        let (mn, mx) = p.a_minmax();
        let score = |c: &SiteParams| p.eval(c, &SiteParams::Bypass);
        let coarse = uniform_candidates(mn, mx, 6, 24);
        let (_, lc) = argmin_candidates(&coarse, score);
        let (_, lcf) = coarse_fine(40, |n| uniform_candidates(mn, mx, 6, n),
                                   score);
        assert!(lcf <= lc * 1.0001);
    }

    #[test]
    fn fisher_changes_the_winner_when_gradients_are_skewed() {
        // construct a case where plain MSE and HO disagree:
        // outputs column 0 has huge gradient; an aggressive clip hurts
        // the big-|a| rows that feed it.
        let mut rng = Rng::new(3);
        let mut adata = rng.normal_vec(256);
        for v in adata.iter_mut().take(32) {
            *v *= 8.0; // rows feeding large outputs
        }
        let a = Tensor::new(vec![32, 8], adata);
        let b = Tensor::new(vec![8, 4], rng.normal_vec(32));
        let mut fish = vec![0.01f32; 128];
        for (row, f) in fish.chunks_mut(4).enumerate().take(4) {
            let _ = row;
            f.fill(25.0);
        }
        let pf = Problem::new(vec![a.clone()], vec![b.clone()],
                              Some(vec![Tensor::new(vec![32, 4], fish)]));
        let pm = Problem::new(vec![a], vec![b], None);
        let (mn, mx) = pf.a_minmax();
        let cands = uniform_candidates(mn, mx, 4, 30);
        let (wf, _) = argmin_candidates(&cands,
                                        |c| pf.eval(c, &SiteParams::Bypass));
        let (wm, _) = argmin_candidates(&cands,
                                        |c| pm.eval(c, &SiteParams::Bypass));
        // they may coincide, but the HO loss under the MSE winner must be
        // ≥ the HO loss under the HO winner (sanity of the ordering).
        let l_ho_of_ho = pf.eval(&wf, &SiteParams::Bypass);
        let l_ho_of_mse = pf.eval(&wm, &SiteParams::Bypass);
        assert!(l_ho_of_ho <= l_ho_of_mse + 1e-9);
    }
}
