//! Inception Score (paper metric [31]) over the trained substitute
//! classifier's per-image class posteriors.
//!
//! IS = exp( E_x[ KL(p(y|x) ‖ p(y)) ] ) with p(y) the marginal over the
//! evaluated set. Higher is better (confident AND diverse predictions).

/// Compute IS from per-image probability rows (each sums to 1).
pub fn inception_score(probs: &[Vec<f32>]) -> f64 {
    if probs.is_empty() {
        return 0.0;
    }
    let k = probs[0].len();
    // marginal p(y)
    let mut marg = vec![0.0f64; k];
    for p in probs {
        debug_assert_eq!(p.len(), k);
        for (m, &v) in marg.iter_mut().zip(p) {
            *m += v as f64;
        }
    }
    for m in marg.iter_mut() {
        *m /= probs.len() as f64;
    }
    // mean KL(p(y|x) || p(y))
    let mut kl_sum = 0.0f64;
    for p in probs {
        let mut kl = 0.0f64;
        for (j, &v) in p.iter().enumerate() {
            let v = v as f64;
            if v > 1e-12 && marg[j] > 1e-12 {
                kl += v * (v / marg[j]).ln();
            }
        }
        kl_sum += kl;
    }
    (kl_sum / probs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_predictions_score_one() {
        // every image: uniform posterior → KL to uniform marginal = 0
        let probs = vec![vec![0.25f32; 4]; 10];
        assert!((inception_score(&probs) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn confident_diverse_predictions_score_k() {
        // each image is a confident one-hot, classes balanced → IS = k
        let k = 4;
        let probs: Vec<Vec<f32>> = (0..16)
            .map(|i| {
                let mut p = vec![0.0f32; k];
                p[i % k] = 1.0;
                p
            })
            .collect();
        assert!((inception_score(&probs) - k as f64).abs() < 1e-6);
    }

    #[test]
    fn mode_collapse_scores_one() {
        // all images confidently the SAME class → marginal == posterior
        let probs: Vec<Vec<f32>> = (0..16)
            .map(|_| vec![1.0f32, 0.0, 0.0, 0.0])
            .collect();
        assert!((inception_score(&probs) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn is_bounded_by_class_count() {
        // arbitrary mixtures never exceed k
        let probs = vec![
            vec![0.7f32, 0.1, 0.1, 0.1],
            vec![0.1, 0.7, 0.1, 0.1],
            vec![0.25, 0.25, 0.25, 0.25],
        ];
        let is = inception_score(&probs);
        assert!(is >= 1.0 - 1e-9 && is <= 4.0 + 1e-9, "{is}");
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(inception_score(&[]), 0.0);
    }
}
