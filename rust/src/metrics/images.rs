//! Image output: [-1, 1] float NHWC → binary PPM, plus the Fig. 6-style
//! sample-grid assembler.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Map a [-1, 1] float to a u8 pixel.
pub fn to_u8(v: f32) -> u8 {
    (((v.clamp(-1.0, 1.0) + 1.0) * 0.5) * 255.0).round() as u8
}

/// Write one (H, W, C) image as binary PPM (P6). C must be 3.
pub fn write_ppm(path: &Path, img: &[f32], h: usize, w: usize) -> Result<()> {
    assert_eq!(img.len(), h * w * 3, "PPM writer needs 3 channels");
    let mut buf = Vec::with_capacity(32 + h * w * 3);
    write!(buf, "P6\n{w} {h}\n255\n")?;
    buf.extend(img.iter().map(|&v| to_u8(v)));
    std::fs::write(path, &buf)
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Assemble n images (each H×W×3, flat, row-major batch) into a
/// rows×cols grid with a 1-px black border, returning (grid, GH, GW).
pub fn make_grid(images: &[f32], h: usize, w: usize, rows: usize,
                 cols: usize) -> (Vec<f32>, usize, usize) {
    let il = h * w * 3;
    let n = images.len() / il;
    let (gh, gw) = (rows * (h + 1) + 1, cols * (w + 1) + 1);
    let mut grid = vec![-1.0f32; gh * gw * 3];
    for idx in 0..n.min(rows * cols) {
        let (r, c) = (idx / cols, idx % cols);
        let (y0, x0) = (1 + r * (h + 1), 1 + c * (w + 1));
        let img = &images[idx * il..(idx + 1) * il];
        for y in 0..h {
            for x in 0..w {
                let src = (y * w + x) * 3;
                let dst = ((y0 + y) * gw + (x0 + x)) * 3;
                grid[dst..dst + 3].copy_from_slice(&img[src..src + 3]);
            }
        }
    }
    (grid, gh, gw)
}

/// Write a grid of images straight to a PPM file.
pub fn write_grid_ppm(path: &Path, images: &[f32], h: usize, w: usize,
                      rows: usize, cols: usize) -> Result<()> {
    let (grid, gh, gw) = make_grid(images, h, w, rows, cols);
    write_ppm(path, &grid, gh, gw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixel_mapping_endpoints() {
        assert_eq!(to_u8(-1.0), 0);
        assert_eq!(to_u8(1.0), 255);
        assert_eq!(to_u8(0.0), 128);
        // out-of-range clamps
        assert_eq!(to_u8(-5.0), 0);
        assert_eq!(to_u8(5.0), 255);
    }

    #[test]
    fn grid_dimensions() {
        let imgs = vec![0.0f32; 4 * 2 * 2 * 3]; // 4 images of 2x2
        let (grid, gh, gw) = make_grid(&imgs, 2, 2, 2, 2);
        assert_eq!((gh, gw), (7, 7));
        assert_eq!(grid.len(), 7 * 7 * 3);
    }

    #[test]
    fn grid_places_image_content() {
        // one all-white 2x2 image in a 1x1 grid
        let imgs = vec![1.0f32; 2 * 2 * 3];
        let (grid, gh, gw) = make_grid(&imgs, 2, 2, 1, 1);
        assert_eq!((gh, gw), (4, 4));
        // border is black (-1), interior pixel (1,1) is white
        assert_eq!(grid[0], -1.0);
        let inner = (1 * gw + 1) * 3;
        assert_eq!(grid[inner], 1.0);
    }

    #[test]
    fn ppm_roundtrip_header() {
        let dir = std::env::temp_dir().join("tqdit_ppm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ppm");
        write_ppm(&p, &vec![0.0f32; 2 * 3 * 3], 2, 3).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P6\n3 2\n255\n"));
        assert_eq!(bytes.len(), 11 + 18);
        std::fs::remove_file(&p).ok();
    }
}
