//! Fréchet Inception Distance (paper metric [29]) and its spatial
//! variant sFID [30], over the substitute feature network's Gaussians.
//!
//! FID(𝒩₁, 𝒩₂) = ‖μ₁ − μ₂‖² + tr(Σ₁ + Σ₂ − 2·(Σ₁Σ₂)^{1/2}).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::Manifest;
use crate::tensor::linalg::trace_sqrt_product;

/// Reference Gaussian statistics computed by `aot.py` over the real
/// synthetic-data distribution (`fid_ref.bin`: mu_f, cov_f, mu_s, cov_s
/// as f32 LE in that order).
#[derive(Clone, Debug)]
pub struct RefStats {
    pub mu_f: Vec<f64>,
    pub cov_f: Vec<f64>,
    pub mu_s: Vec<f64>,
    pub cov_s: Vec<f64>,
}

impl RefStats {
    pub fn load(manifest: &Manifest) -> Result<RefStats> {
        let path = manifest.dir.join(&manifest.fid_ref_file);
        Self::load_file(&path, manifest.feat_dim, manifest.spat_dim)
    }

    pub fn load_file(path: &Path, feat_dim: usize, spat_dim: usize)
                     -> Result<RefStats> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let expected =
            (feat_dim + feat_dim * feat_dim + spat_dim + spat_dim * spat_dim)
                * 4;
        if bytes.len() != expected {
            bail!("fid_ref.bin: {} bytes, expected {}", bytes.len(), expected);
        }
        let mut vals = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]) as f64);
        let mut take = |n: usize| -> Vec<f64> {
            (0..n).map(|_| vals.next().unwrap()).collect()
        };
        Ok(RefStats {
            mu_f: take(feat_dim),
            cov_f: take(feat_dim * feat_dim),
            mu_s: take(spat_dim),
            cov_s: take(spat_dim * spat_dim),
        })
    }
}

/// Fréchet distance between two Gaussians (μ, Σ row-major d×d).
pub fn frechet_distance(mu1: &[f64], cov1: &[f64], mu2: &[f64],
                        cov2: &[f64], d: usize) -> f64 {
    assert_eq!(mu1.len(), d);
    assert_eq!(mu2.len(), d);
    assert_eq!(cov1.len(), d * d);
    assert_eq!(cov2.len(), d * d);
    let mut diff2 = 0.0f64;
    for i in 0..d {
        let dd = mu1[i] - mu2[i];
        diff2 += dd * dd;
    }
    let tr1: f64 = (0..d).map(|i| cov1[i * d + i]).sum();
    let tr2: f64 = (0..d).map(|i| cov2[i * d + i]).sum();
    let cross = trace_sqrt_product(cov1, cov2, d);
    // numerical noise can push the estimate a hair below zero
    (diff2 + tr1 + tr2 - 2.0 * cross).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eye(d: usize, s: f64) -> Vec<f64> {
        let mut m = vec![0.0; d * d];
        for i in 0..d {
            m[i * d + i] = s;
        }
        m
    }

    #[test]
    fn identical_gaussians_have_zero_fid() {
        let mu = vec![0.3, -1.0, 2.0];
        let cov = eye(3, 2.0);
        let f = frechet_distance(&mu, &cov, &mu, &cov, 3);
        assert!(f.abs() < 1e-9, "{f}");
    }

    #[test]
    fn mean_shift_adds_squared_distance() {
        let cov = eye(2, 1.0);
        let f = frechet_distance(&[0.0, 0.0], &cov, &[3.0, 4.0], &cov, 2);
        assert!((f - 25.0).abs() < 1e-8, "{f}");
    }

    #[test]
    fn isotropic_scale_formula() {
        // Σ₁ = a·I, Σ₂ = b·I → FID = d·(√a − √b)²
        let d = 4;
        let f = frechet_distance(
            &vec![0.0; d], &eye(d, 4.0), &vec![0.0; d], &eye(d, 1.0), d);
        let expect = d as f64 * (2.0 - 1.0f64).powi(2);
        assert!((f - expect).abs() < 1e-8, "{f} vs {expect}");
    }

    #[test]
    fn fid_is_symmetric() {
        let c1 = vec![2.0, 0.3, 0.3, 1.0];
        let c2 = vec![1.0, -0.1, -0.1, 3.0];
        let a = frechet_distance(&[0., 1.], &c1, &[1., 0.], &c2, 2);
        let b = frechet_distance(&[1., 0.], &c2, &[0., 1.], &c1, 2);
        assert!((a - b).abs() < 1e-8);
        assert!(a > 0.0);
    }

    #[test]
    fn wider_distribution_increases_fid() {
        let d = 3;
        let base = eye(d, 1.0);
        let f1 = frechet_distance(&vec![0.0; d], &eye(d, 1.2), &vec![0.0; d],
                                  &base, d);
        let f2 = frechet_distance(&vec![0.0; d], &eye(d, 3.0), &vec![0.0; d],
                                  &base, d);
        assert!(f2 > f1);
    }
}
