//! Evaluation metrics — FID, sFID, Inception Score — plus image writers.
//!
//! The paper evaluates every (method, bit-width) cell with FID [29],
//! sFID [30] and IS [31]. Feature extraction runs through the AOT
//! `feature_net` / `classifier` artifacts (InceptionV3 substitutes, see
//! DESIGN.md §1); the Fréchet distance itself is host-side f64 math on
//! the accumulated Gaussian statistics.

pub mod fid;
pub mod images;
pub mod inception_score;

pub use fid::{frechet_distance, RefStats};
pub use inception_score::inception_score;

use anyhow::Result;

use crate::runtime::Runtime;
use crate::tensor::stats::GaussStats;
use crate::tensor::Tensor;

/// One evaluation row (a Table I/II cell).
#[derive(Clone, Copy, Debug)]
pub struct EvalRow {
    pub fid: f64,
    pub sfid: f64,
    pub is_score: f64,
    /// Images evaluated.
    pub n: usize,
}

impl EvalRow {
    pub fn print(&self, label: &str) {
        println!(
            "{label:<28} FID {:>8.3}  sFID {:>8.3}  IS {:>7.3}  (n={})",
            self.fid, self.sfid, self.is_score, self.n
        );
    }
}

/// Streaming evaluator: feed generated image batches, finish into an
/// [`EvalRow`]. Feature batches are padded to the artifact's fixed batch
/// size and the padding rows discarded.
pub struct Evaluator<'a> {
    rt: &'a Runtime,
    refs: RefStats,
    feat: GaussStats,
    spat: GaussStats,
    /// Per-image class probabilities (for IS).
    probs: Vec<Vec<f32>>,
    /// Metric-net weights, resident on device (feature net; classifier).
    feat_bufs: Vec<xla::PjRtBuffer>,
    clf_bufs: Vec<xla::PjRtBuffer>,
    img_len: usize,
    feat_batch: usize,
    /// Buffered images not yet featurized.
    pending: Vec<f32>,
    pending_n: usize,
}

impl<'a> Evaluator<'a> {
    pub fn new(rt: &'a Runtime) -> Result<Evaluator<'a>> {
        let m = &rt.manifest;
        let refs = RefStats::load(m)?;
        let (fw, cw) = m.load_metric_weights()?;
        let feat_bufs = rt.upload_all(&fw)?;
        let clf_bufs = rt.upload_all(&cw)?;
        let img_len = m.model.img_size * m.model.img_size * m.model.channels;
        Ok(Evaluator {
            rt,
            feat: GaussStats::new(m.feat_dim),
            spat: GaussStats::new(m.spat_dim),
            refs,
            probs: Vec::new(),
            feat_bufs,
            clf_bufs,
            img_len,
            feat_batch: m.batches.feat,
            pending: Vec::new(),
            pending_n: 0,
        })
    }

    /// Add generated images, flat (n, H, W, C) in [-1, 1].
    pub fn push_images(&mut self, images: &[f32]) -> Result<()> {
        assert_eq!(images.len() % self.img_len, 0);
        self.pending.extend_from_slice(images);
        self.pending_n += images.len() / self.img_len;
        while self.pending_n >= self.feat_batch {
            self.flush_one_batch(self.feat_batch)?;
        }
        Ok(())
    }

    fn flush_one_batch(&mut self, real: usize) -> Result<()> {
        let m = &self.rt.manifest;
        let fb = self.feat_batch;
        let mut data = self.pending[..real * self.img_len].to_vec();
        // pad to the fixed artifact batch by repeating the first image
        data.resize(fb * self.img_len, 0.0);
        if real < fb {
            for i in real..fb {
                let (src, dst) = data.split_at_mut(i * self.img_len);
                dst[..self.img_len].copy_from_slice(&src[..self.img_len]);
            }
        }
        let img = Tensor::new(
            vec![fb, m.model.img_size, m.model.img_size, m.model.channels],
            data,
        );
        let imgb = self.rt.upload(&img)?;
        let mut fin: Vec<&xla::PjRtBuffer> = self.feat_bufs.iter().collect();
        fin.push(&imgb);
        let feats = self.rt.run_buffers("feature_net", &fin)?;
        // feature_net returns (feat (FB, feat_dim), spat (FB, spat_dim))
        let f = &feats[0];
        let s = &feats[1];
        for i in 0..real {
            self.feat.push(&f.data[i * m.feat_dim..(i + 1) * m.feat_dim]);
            self.spat.push(&s.data[i * m.spat_dim..(i + 1) * m.spat_dim]);
        }
        let mut cin: Vec<&xla::PjRtBuffer> = self.clf_bufs.iter().collect();
        cin.push(&imgb);
        let logits = self.rt.run_buffers("classifier", &cin)?;
        let l = &logits[0];
        let nc = l.cols();
        for i in 0..real {
            let row = &l.data[i * nc..(i + 1) * nc];
            self.probs.push(softmax(row));
        }
        // drop consumed images
        self.pending.drain(..real * self.img_len);
        self.pending_n -= real;
        Ok(())
    }

    /// Finalize: flush the tail, compute FID/sFID/IS.
    pub fn finish(mut self) -> Result<EvalRow> {
        while self.pending_n > 0 {
            let real = self.pending_n.min(self.feat_batch);
            self.flush_one_batch(real)?;
        }
        let n = self.feat.count;
        anyhow::ensure!(n > 1, "need at least 2 images to evaluate");
        let fid = frechet_distance(
            &self.feat.mean(),
            &self.feat.cov(),
            &self.refs.mu_f,
            &self.refs.cov_f,
            self.feat.dim,
        );
        let sfid = frechet_distance(
            &self.spat.mean(),
            &self.spat.cov(),
            &self.refs.mu_s,
            &self.refs.cov_s,
            self.spat.dim,
        );
        let is_score = inception_score(&self.probs);
        Ok(EvalRow { fid, sfid, is_score, n })
    }
}

/// Numerically-stable softmax of one logit row.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exp: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = exp.iter().sum();
    exp.iter().map(|&e| e / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_handles_large_logits() {
        let p = softmax(&[1000.0, 1000.0]);
        assert!((p[0] - 0.5).abs() < 1e-6);
    }
}
