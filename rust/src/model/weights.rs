//! Weight store: loads `weights.bin` (f32 LE, canonical flat order from
//! the manifest) and performs host-side weight fake-quantization —
//! weights are runtime inputs to every artifact, so weight quantization
//! never requires recompiling (DESIGN.md §3).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::UniformQ;
use crate::runtime::Manifest;
use crate::tensor::Tensor;

/// All model parameters, in canonical order + by-name index.
#[derive(Clone, Debug)]
pub struct WeightStore {
    /// Tensors in the manifest's flat parameter order.
    pub tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl WeightStore {
    /// Load from `weights.bin` next to the manifest.
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        let path = manifest.dir.join(&manifest.weights_file);
        Self::load_file(&path, manifest)
    }

    pub fn load_file(path: &Path, manifest: &Manifest) -> Result<WeightStore> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let expected: usize = manifest
            .params
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        if bytes.len() != expected * 4 {
            bail!(
                "weights.bin: {} bytes, expected {} ({} f32)",
                bytes.len(),
                expected * 4,
                expected
            );
        }
        let mut tensors = Vec::with_capacity(manifest.params.len());
        let mut index = HashMap::new();
        let mut off = 0usize;
        for (i, (name, shape)) in manifest.params.iter().enumerate() {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = bytes[off..off + n * 4]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            off += n * 4;
            tensors.push(Tensor::new(shape.clone(), data));
            index.insert(name.clone(), i);
        }
        Ok(WeightStore { tensors, index })
    }

    /// Build from in-memory tensors (tests / train-from-rust driver).
    pub fn from_tensors(manifest: &Manifest, tensors: Vec<Tensor>)
                        -> WeightStore {
        assert_eq!(tensors.len(), manifest.params.len());
        let index = manifest
            .params
            .iter()
            .enumerate()
            .map(|(i, (n, _))| (n.clone(), i))
            .collect();
        WeightStore { tensors, index }
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn position(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    /// Total parameter count.
    pub fn n_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Clone with the named weights fake-quantized using the provided
    /// per-weight quantizers (weight names → params). Non-listed tensors
    /// (biases, embeddings, pos_embed) stay full precision.
    pub fn fakequant(&self, wq: &HashMap<String, UniformQ>) -> WeightStore {
        let mut out = self.clone();
        for (name, q) in wq {
            if let Some(&i) = out.index.get(name.as_str()) {
                q.fakequant_slice(&mut out.tensors[i].data);
            }
        }
        out
    }

    /// Serialize back to the weights.bin layout (train-from-rust driver).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.n_elements() * 4);
        for t in &self.tensors {
            for v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::{Batches, DiffusionMeta, ModelMeta};
    use std::collections::BTreeMap;

    /// Minimal 2-param manifest for loader tests.
    fn toy_manifest(dir: &Path) -> Manifest {
        Manifest {
            dir: dir.to_path_buf(),
            model: ModelMeta {
                img_size: 4, channels: 3, patch: 2, dim: 4, depth: 1,
                heads: 1, num_classes: 2, mlp_ratio: 2, freq_dim: 4,
                tokens: 4, head_dim: 4, patch_dim: 12,
            },
            diffusion: DiffusionMeta {
                train_steps: 10, beta_start: 1e-4, beta_end: 0.02,
            },
            params: vec![
                ("w1".into(), vec![2, 3]),
                ("b1".into(), vec![3]),
            ],
            layers: vec![],
            qp_len: 0,
            batches: Batches { calib: 1, sample: vec![1], train: 1,
                               feat: 1 },
            capture_outputs: vec![],
            feat_dim: 1,
            spat_dim: 1,
            classifier_acc: 0.0,
            feat_params: vec![],
            clf_params: vec![],
            artifacts: BTreeMap::new(),
            weights_file: "weights.bin".into(),
            metric_weights_file: "metric_weights.bin".into(),
            fid_ref_file: "fid_ref.bin".into(),
        }
    }

    #[test]
    fn roundtrip_through_bytes() {
        let dir = std::env::temp_dir();
        let man = toy_manifest(&dir);
        let ws = WeightStore::from_tensors(&man, vec![
            Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
            Tensor::new(vec![3], vec![-1., 0., 1.]),
        ]);
        let bytes = ws.to_bytes();
        assert_eq!(bytes.len(), 9 * 4);
        let tmp = dir.join("tqdit_weights_test.bin");
        std::fs::write(&tmp, &bytes).unwrap();
        let back = WeightStore::load_file(&tmp, &man).unwrap();
        assert_eq!(back.get("w1").unwrap().data, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.get("b1").unwrap().data, vec![-1., 0., 1.]);
        assert_eq!(back.position("b1"), Some(1));
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn load_rejects_size_mismatch() {
        let dir = std::env::temp_dir();
        let man = toy_manifest(&dir);
        let tmp = dir.join("tqdit_weights_bad.bin");
        std::fs::write(&tmp, [0u8; 12]).unwrap();
        assert!(WeightStore::load_file(&tmp, &man).is_err());
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn fakequant_touches_only_listed_weights() {
        let dir = std::env::temp_dir();
        let man = toy_manifest(&dir);
        let ws = WeightStore::from_tensors(&man, vec![
            Tensor::new(vec![2, 3], vec![0.11, 0.52, -0.97, 0.33, 0.7, -0.2]),
            Tensor::new(vec![3], vec![0.123, -0.456, 0.789]),
        ]);
        let mut wq = HashMap::new();
        wq.insert("w1".to_string(), UniformQ::from_minmax(-1.0, 1.0, 4));
        let q = ws.fakequant(&wq);
        // w1 changed (4-bit grid), b1 untouched
        assert_ne!(q.get("w1").unwrap().data, ws.get("w1").unwrap().data);
        assert_eq!(q.get("b1").unwrap().data, ws.get("b1").unwrap().data);
        // quantized values lie on the 4-bit grid
        let g = UniformQ::from_minmax(-1.0, 1.0, 4);
        for &v in &q.get("w1").unwrap().data {
            assert!((g.fakequant(v) - v).abs() < 1e-6);
        }
    }

    #[test]
    fn unknown_weight_names_are_ignored() {
        let dir = std::env::temp_dir();
        let man = toy_manifest(&dir);
        let ws = WeightStore::from_tensors(&man, vec![
            Tensor::zeros(vec![2, 3]),
            Tensor::zeros(vec![3]),
        ]);
        let mut wq = HashMap::new();
        wq.insert("nonexistent".to_string(),
                  UniformQ::from_minmax(-1.0, 1.0, 8));
        let q = ws.fakequant(&wq); // must not panic
        assert_eq!(q.n_elements(), 9);
    }
}
