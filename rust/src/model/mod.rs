//! Model-side host state: weight store + weight fake-quantization.

pub mod weights;

pub use weights::WeightStore;
