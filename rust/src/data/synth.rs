//! Synthetic class-conditional dataset — rust mirror of
//! `python/compile/data.py` (same class parameterization; the model was
//! trained on the python generator, the rust generator feeds calibration
//! and the train-from-rust driver; see DESIGN.md §1).

use crate::util::rng::Rng;

const PHI: f64 = 0.618_033_988_75;

/// Deterministic per-class geometry/hue (mirrors `data.class_params`).
#[derive(Clone, Copy, Debug)]
pub struct ClassParams {
    pub cx: f32,
    pub cy: f32,
    pub sigma: f32,
    pub hue: [f32; 3],
    pub freq: f32,
    pub angle: f32,
}

pub fn class_params(k: usize) -> ClassParams {
    let u = (k as f64 * PHI) % 1.0;
    let cx = 0.25 + 0.5 * u;
    let cy = 0.25 + 0.5 * ((u + 0.37) % 1.0);
    let sigma = 0.12 + 0.10 * ((k as u64 * 2_654_435_761) % 97) as f64 / 97.0;
    let hue = [
        0.5 + 0.5 * (2.0 * std::f64::consts::PI * u).cos(),
        0.5 + 0.5 * (2.0 * std::f64::consts::PI * (u + 1.0 / 3.0)).cos(),
        0.5 + 0.5 * (2.0 * std::f64::consts::PI * (u + 2.0 / 3.0)).cos(),
    ];
    let freq = 1.0 + (k % 4) as f64;
    let angle = std::f64::consts::PI * u;
    ClassParams {
        cx: cx as f32,
        cy: cy as f32,
        sigma: sigma as f32,
        hue: [hue[0] as f32, hue[1] as f32, hue[2] as f32],
        freq: freq as f32,
        angle: angle as f32,
    }
}

/// Generator for (image, label) batches in [-1, 1], NHWC.
#[derive(Clone, Debug)]
pub struct SynthDataset {
    pub img_size: usize,
    pub channels: usize,
    pub num_classes: usize,
}

impl SynthDataset {
    pub fn new(img_size: usize, channels: usize, num_classes: usize)
               -> SynthDataset {
        assert_eq!(channels, 3, "generator is RGB");
        SynthDataset { img_size, channels, num_classes }
    }

    /// Pixels per image.
    pub fn image_len(&self) -> usize {
        self.img_size * self.img_size * self.channels
    }

    /// Render one image for class `k` into `out` (len = image_len).
    pub fn render(&self, k: usize, rng: &mut Rng, out: &mut [f32]) {
        assert_eq!(out.len(), self.image_len());
        let h = self.img_size;
        let p = class_params(k);
        for yi in 0..h {
            let y = yi as f32 / (h - 1) as f32;
            for xi in 0..h {
                let x = xi as f32 / (h - 1) as f32;
                let base = if k % 2 == 0 {
                    let d2 = (x - p.cx) * (x - p.cx) + (y - p.cy) * (y - p.cy);
                    (-d2 / (2.0 * p.sigma * p.sigma)).exp()
                } else {
                    let proj = p.angle.cos() * x + p.angle.sin() * y;
                    0.5 + 0.5
                        * (2.0 * std::f32::consts::PI * p.freq * proj).sin()
                };
                for c in 0..3 {
                    let v = 2.0 * (base * p.hue[c]) - 1.0
                        + 0.05 * rng.normal() as f32;
                    out[(yi * h + xi) * 3 + c] = v.clamp(-1.0, 1.0);
                }
            }
        }
    }

    /// Batch of `n` random-class images: (flat pixels, labels).
    pub fn sample_batch(&self, n: usize, rng: &mut Rng)
                        -> (Vec<f32>, Vec<i32>) {
        let mut imgs = vec![0.0f32; n * self.image_len()];
        let mut labels = Vec::with_capacity(n);
        let il = self.image_len();
        for i in 0..n {
            let k = rng.below(self.num_classes);
            labels.push(k as i32);
            self.render(k, rng, &mut imgs[i * il..(i + 1) * il]);
        }
        (imgs, labels)
    }

    /// Batch with the given labels.
    pub fn batch_for_labels(&self, labels: &[i32], rng: &mut Rng)
                            -> Vec<f32> {
        let il = self.image_len();
        let mut imgs = vec![0.0f32; labels.len() * il];
        for (i, &k) in labels.iter().enumerate() {
            self.render(k as usize, rng, &mut imgs[i * il..(i + 1) * il]);
        }
        imgs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SynthDataset {
        SynthDataset::new(16, 3, 8)
    }

    #[test]
    fn pixels_in_range() {
        let mut rng = Rng::new(1);
        let (imgs, labels) = ds().sample_batch(16, &mut rng);
        assert_eq!(imgs.len(), 16 * 16 * 16 * 3);
        assert_eq!(labels.len(), 16);
        assert!(imgs.iter().all(|&v| (-1.0..=1.0).contains(&v)));
        assert!(labels.iter().all(|&l| (0..8).contains(&l)));
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean image distance between two different classes exceeds the
        // within-class noise floor by a wide margin.
        let d = ds();
        let mut rng = Rng::new(2);
        let il = d.image_len();
        let mut a1 = vec![0.0; il];
        let mut a2 = vec![0.0; il];
        let mut b = vec![0.0; il];
        d.render(0, &mut rng, &mut a1);
        d.render(0, &mut rng, &mut a2);
        d.render(3, &mut rng, &mut b);
        let within: f32 =
            a1.iter().zip(&a2).map(|(x, y)| (x - y).abs()).sum::<f32>()
                / il as f32;
        let between: f32 =
            a1.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f32>()
                / il as f32;
        assert!(between > 4.0 * within, "between {between} within {within}");
    }

    #[test]
    fn class_params_match_python_formulas() {
        // spot values computed from data.py's formulas
        let p0 = class_params(0);
        assert!((p0.cx - 0.25).abs() < 1e-6);
        assert!((p0.hue[0] - 1.0).abs() < 1e-6);
        let p1 = class_params(1);
        assert!((p1.cx - (0.25 + 0.5 * PHI as f32)).abs() < 1e-6);
        assert!((p1.freq - 2.0).abs() < 1e-6);
    }

    #[test]
    fn batch_for_labels_is_class_consistent() {
        let d = ds();
        let mut rng = Rng::new(3);
        let labels = vec![2i32, 2, 5];
        let imgs = d.batch_for_labels(&labels, &mut rng);
        let il = d.image_len();
        let d01: f32 = imgs[..il]
            .iter()
            .zip(&imgs[il..2 * il])
            .map(|(a, b)| (a - b).abs())
            .sum();
        let d02: f32 = imgs[..il]
            .iter()
            .zip(&imgs[2 * il..])
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(d01 < d02);
    }
}
