//! Synthetic dataset substrate.

pub mod synth;

pub use synth::SynthDataset;
