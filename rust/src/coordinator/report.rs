//! Quantization-error attribution: which sites/layers eat the error
//! budget under a calibrated config.
//!
//! For every layer the captured evidence lets us score the chosen
//! parameters with the same HO objective the search used (eq. 16/17) —
//! both in absolute terms and relative to the layer's FP output power.
//! The report is the practical debugging tool behind Table III: it
//! shows the post-softmax/post-GELU sites dominating the baseline's
//! loss and the MRQ/TGQ variants reclaiming it.

use crate::coordinator::capture::Evidence;
use crate::coordinator::store::QuantConfig;
use crate::model::WeightStore;
use crate::quant::ho::quant_loss;
use crate::quant::SiteParams;
use crate::runtime::Manifest;

/// Error attribution for one layer under one config.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub layer: String,
    pub ltype: String,
    /// HO (Fisher-weighted) quantization loss, summed over evidence.
    pub ho_loss: f64,
    /// Plain squared error (no Fisher weighting).
    pub mse_loss: f64,
    /// Σ z_fp² over the same evidence — normalizer for `relative()`.
    pub fp_power: f64,
    /// Evidence matrices scored.
    pub n_mats: usize,
}

impl LayerReport {
    /// MSE loss relative to the FP output power (scale-free).
    pub fn relative(&self) -> f64 {
        self.mse_loss / self.fp_power.max(1e-30)
    }
}

/// Score every layer of `qc` against the captured evidence.
///
/// TGQ sites are scored per group with that group's overlay (exactly
/// what the sampler applies); everything else uses the group-shared
/// parameters. Weights are fake-quantized with the config's weight
/// quantizers, mirroring the runtime path.
pub fn error_report(manifest: &Manifest, weights: &WeightStore,
                    ev: &Evidence, qc: &QuantConfig) -> Vec<LayerReport> {
    let wq = weights.fakequant(&qc.weights);
    let mut out = Vec::with_capacity(manifest.layers.len());
    for layer in &manifest.layers {
        let le = ev.layer(&layer.name);
        let mut rep = LayerReport {
            layer: layer.name.clone(),
            ltype: layer.ltype.clone(),
            ho_loss: 0.0,
            mse_loss: 0.0,
            fp_power: 0.0,
            n_mats: 0,
        };
        for g in 0..le.a.len() {
            // effective params for this group
            let pa = qc.site_for_group(&layer.sites[0].name, g);
            let pb = if layer.ltype == "matmul" {
                qc.site_for_group(&layer.sites[1].name, g)
            } else {
                SiteParams::Bypass // weight quant applied via `wq`
            };
            for (i, am) in le.a[g].iter().enumerate() {
                let bm_fp = if layer.ltype == "linear" {
                    weights.get(&layer.weight).unwrap().clone()
                } else {
                    le.b[g][i].clone()
                };
                let bm_q = if layer.ltype == "linear" {
                    wq.get(&layer.weight).unwrap().clone()
                } else {
                    le.b[g][i].clone()
                };
                let z_fp = am.matmul(&bm_fp);
                let mut aq = am.clone();
                pa.apply(&mut aq.data);
                let mut bq = bm_q;
                pb.apply(&mut bq.data);
                let z_q = aq.matmul(&bq);
                let grad = le.fisher[g].get(i).map(|f| f.data.as_slice());
                rep.ho_loss += quant_loss(&z_fp.data, &z_q.data, grad);
                rep.mse_loss += quant_loss(&z_fp.data, &z_q.data, None);
                rep.fp_power += z_fp
                    .data
                    .iter()
                    .map(|&v| (v as f64) * v as f64)
                    .sum::<f64>();
                rep.n_mats += 1;
            }
        }
        out.push(rep);
    }
    out
}

/// Pretty-print a report, worst layers first.
pub fn print_report(mut reps: Vec<LayerReport>, label: &str) {
    reps.sort_by(|a, b| b.relative().partial_cmp(&a.relative()).unwrap());
    println!("== per-layer quantization error ({label}) ==");
    println!("{:<18} {:<7} {:>12} {:>12} {:>10}", "layer", "type",
             "HO loss", "rel. MSE", "evidence");
    for r in &reps {
        println!("{:<18} {:<7} {:>12.4e} {:>12.4e} {:>10}", r.layer,
                 r.ltype, r.ho_loss, r.relative(), r.n_mats);
    }
    let total: f64 = reps.iter().map(|r| r.ho_loss).sum();
    println!("{:<26} {:>12.4e}", "total HO loss", total);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::capture::LayerEvidence;
    use crate::quant::UniformQ;
    use crate::runtime::artifacts::{Batches, DiffusionMeta, LayerMeta,
                                    ModelMeta, SiteKind, SiteMeta};
    use crate::sched::TimeGroups;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn toy_manifest() -> Manifest {
        Manifest {
            dir: std::env::temp_dir(),
            model: ModelMeta {
                img_size: 4, channels: 3, patch: 2, dim: 4, depth: 1,
                heads: 1, num_classes: 2, mlp_ratio: 2, freq_dim: 4,
                tokens: 4, head_dim: 4, patch_dim: 12,
            },
            diffusion: DiffusionMeta {
                train_steps: 10, beta_start: 1e-4, beta_end: 0.02,
            },
            params: vec![("l0.w".into(), vec![4, 6])],
            layers: vec![
                LayerMeta {
                    name: "l0".into(),
                    ltype: "linear".into(),
                    weight: "l0.w".into(),
                    sites: vec![SiteMeta {
                        name: "l0.x".into(),
                        kind: SiteKind::Uniform,
                        tgq: false,
                        qp_offset: 0,
                    }],
                },
                LayerMeta {
                    name: "m0".into(),
                    ltype: "matmul".into(),
                    weight: String::new(),
                    sites: vec![
                        SiteMeta { name: "m0.a".into(),
                                   kind: SiteKind::MrqSoftmax,
                                   tgq: true, qp_offset: 4 },
                        SiteMeta { name: "m0.b".into(),
                                   kind: SiteKind::Uniform,
                                   tgq: false, qp_offset: 8 },
                    ],
                },
            ],
            qp_len: 12,
            batches: Batches { calib: 1, sample: vec![1], train: 1,
                               feat: 1 },
            capture_outputs: vec![],
            feat_dim: 1,
            spat_dim: 1,
            classifier_acc: 0.0,
            feat_params: vec![],
            clf_params: vec![],
            artifacts: BTreeMap::new(),
            weights_file: "w.bin".into(),
            metric_weights_file: "mw.bin".into(),
            fid_ref_file: "f.bin".into(),
        }
    }

    fn toy_evidence(groups: usize) -> Evidence {
        let mut rng = Rng::new(1);
        let mut linear = LayerEvidence::new("linear", groups);
        let mut matmul = LayerEvidence::new("matmul", groups);
        for g in 0..groups {
            linear.a[g].push(Tensor::new(vec![5, 4], rng.normal_vec(20)));
            linear.fisher[g].push(Tensor::new(vec![5, 6],
                                              rng.normal_vec(30)));
            matmul.a[g].push(Tensor::new(
                vec![3, 3],
                rng.normal_vec(9).iter().map(|v| (v.abs() * 0.1).min(1.0))
                    .collect()));
            matmul.b[g].push(Tensor::new(vec![3, 2], rng.normal_vec(6)));
            matmul.fisher[g].push(Tensor::new(vec![3, 2],
                                              rng.normal_vec(6)));
        }
        let mut layers = std::collections::HashMap::new();
        layers.insert("l0".to_string(), linear);
        layers.insert("m0".to_string(), matmul);
        Evidence {
            layers,
            groups,
            softmax_hist: crate::tensor::stats::Histogram::new(0.0, 1.0, 8),
            gelu_hist: crate::tensor::stats::Histogram::new(-1.0, 1.0, 8),
            softmax_max_by_t: vec![],
            batches_run: groups,
        }
    }

    fn toy_weights(man: &Manifest, rng: &mut Rng) -> WeightStore {
        WeightStore::from_tensors(man, vec![
            Tensor::new(vec![4, 6], rng.normal_vec(24)),
        ])
    }

    #[test]
    fn fp_config_reports_zero_error() {
        let man = toy_manifest();
        let mut rng = Rng::new(2);
        let ws = toy_weights(&man, &mut rng);
        let ev = toy_evidence(2);
        let qc = QuantConfig::fp(TimeGroups::new(10, 2));
        let reps = error_report(&man, &ws, &ev, &qc);
        assert_eq!(reps.len(), 2);
        for r in &reps {
            assert_eq!(r.ho_loss, 0.0, "{}", r.layer);
            assert_eq!(r.mse_loss, 0.0);
            assert!(r.fp_power > 0.0);
            assert_eq!(r.n_mats, 2);
        }
    }

    #[test]
    fn coarser_bits_report_more_error() {
        let man = toy_manifest();
        let mut rng = Rng::new(3);
        let ws = toy_weights(&man, &mut rng);
        let ev = toy_evidence(2);
        let tg = TimeGroups::new(10, 2);

        let mk = |bits: u32| {
            let mut qc = QuantConfig::new("t", bits, bits, tg.clone());
            qc.weights.insert("l0.w".into(),
                              UniformQ::from_minmax(-3.0, 3.0, bits));
            qc.sites.insert("l0.x".into(), SiteParams::Uniform(
                UniformQ::from_minmax(-3.0, 3.0, bits)));
            qc.sites.insert("m0.a".into(), SiteParams::Uniform(
                UniformQ::from_minmax(0.0, 1.0, bits)));
            qc.sites.insert("m0.b".into(), SiteParams::Uniform(
                UniformQ::from_minmax(-3.0, 3.0, bits)));
            qc
        };
        let r8: f64 = error_report(&man, &ws, &ev, &mk(8)).iter()
            .map(|r| r.mse_loss).sum();
        let r4: f64 = error_report(&man, &ws, &ev, &mk(4)).iter()
            .map(|r| r.mse_loss).sum();
        assert!(r8 > 0.0);
        assert!(r4 > r8 * 2.0, "r4 {r4} r8 {r8}");
    }

    #[test]
    fn tgq_overlay_is_scored_per_group() {
        let man = toy_manifest();
        let mut rng = Rng::new(4);
        let ws = toy_weights(&man, &mut rng);
        let ev = toy_evidence(2);
        let tg = TimeGroups::new(10, 2);
        let mut qc = QuantConfig::new("t", 8, 8, tg);
        // group 0: ludicrously coarse; group 1: fine — per-group scoring
        // must land between all-coarse and all-fine.
        qc.tgq.insert("m0.a".into(), vec![
            SiteParams::Uniform(UniformQ::from_minmax(0.0, 1.0, 1)),
            SiteParams::Uniform(UniformQ::from_minmax(0.0, 1.0, 8)),
        ]);
        let mixed: f64 = error_report(&man, &ws, &ev, &qc)
            .iter().find(|r| r.layer == "m0").unwrap().mse_loss;

        let mut coarse = QuantConfig::new("t", 8, 8,
                                          TimeGroups::new(10, 2));
        coarse.sites.insert("m0.a".into(), SiteParams::Uniform(
            UniformQ::from_minmax(0.0, 1.0, 1)));
        let all_coarse: f64 = error_report(&man, &ws, &ev, &coarse)
            .iter().find(|r| r.layer == "m0").unwrap().mse_loss;
        assert!(mixed < all_coarse);
        assert!(mixed > 0.0);
    }
}
