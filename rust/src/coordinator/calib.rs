//! Phase 1 — calibration-dataset construction with time grouping.
//!
//! Timesteps {0..T−1} are split into G contiguous groups (eq. 9); from
//! each group n tuples (x_t, t, y) are drawn (eq. 10) by forward
//! diffusion of synthetic x₀ with known ε (the construction implied by
//! the task loss, eq. 11 — this keeps ∂L/∂z non-degenerate for the
//! Fisher capture in Phase 2).
//!
//! When the sampler is respaced (T=100 over a 250-step training
//! schedule), group membership is decided on the *original* timestep
//! axis and tuples are drawn from the sampler's actual step set, so the
//! calibrated parameters line up with the timesteps the sampler will
//! actually visit.

use anyhow::{bail, Result};

use crate::data::SynthDataset;
use crate::sched::{DdpmSchedule, TimeGroups};
use crate::util::rng::Rng;

/// One calibration tuple (paper Alg. 1, Phase 1).
#[derive(Clone, Debug)]
pub struct CalibTuple {
    /// Noised input x_t (flat NHWC pixels).
    pub x_t: Vec<f32>,
    /// Original (training-schedule) timestep index.
    pub t: usize,
    /// Class label.
    pub y: i32,
    /// The known noise ε used to build x_t (the regression target).
    pub eps: Vec<f32>,
    /// Time-group index of t.
    pub group: usize,
}

/// The grouped calibration dataset 𝒟_cal^TG.
#[derive(Clone, Debug)]
pub struct CalibSet {
    pub tuples: Vec<CalibTuple>,
    pub groups: TimeGroups,
    /// Tuples per group (n in the paper) for grouped sets, where
    /// `len() == per_group × G` holds; `None` for ungrouped (baseline)
    /// sets whose sizes are not a multiple of G.
    pub per_group: Option<usize>,
}

impl CalibSet {
    /// Build with time grouping: n tuples per group, G groups.
    ///
    /// Errors (instead of panicking — this runs inside serve workers)
    /// when some time group covers none of the sampler's respaced
    /// steps, e.g. G > T_sample.
    pub fn build(ds: &SynthDataset, sched: &DdpmSchedule, tg: &TimeGroups,
                 per_group: usize, rng: &mut Rng) -> Result<CalibSet> {
        let il = ds.image_len();
        let mut tuples = Vec::with_capacity(per_group * tg.groups);
        for g in 0..tg.groups {
            // timesteps of this group that the sampler actually visits
            let (lo, hi) = tg.range_of(g);
            let visited: Vec<usize> = sched
                .steps
                .iter()
                .copied()
                .filter(|&t| t >= lo && t <= hi)
                .collect();
            if visited.is_empty() {
                bail!(
                    "time group {g} (t in [{lo}, {hi}]) covers no sampler \
                     steps: {} respaced steps over T={} cannot populate \
                     G={} groups — lower --groups or raise --timesteps",
                    sched.steps.len(), tg.t_total, tg.groups
                );
            }
            for _ in 0..per_group {
                let t = visited[rng.below(visited.len())];
                let y = rng.below(ds.num_classes) as i32;
                let mut x0 = vec![0.0f32; il];
                ds.render(y as usize, rng, &mut x0);
                let eps = rng.normal_vec(il);
                let mut x_t = vec![0.0f32; il];
                sched.q_sample(&x0, t, &eps, &mut x_t);
                tuples.push(CalibTuple { x_t, t, y, eps, group: g });
            }
        }
        Ok(CalibSet { tuples, groups: tg.clone(),
                      per_group: Some(per_group) })
    }

    /// Build WITHOUT grouping (baselines): n_total tuples with t drawn
    /// uniformly over the sampler's step set.
    pub fn build_ungrouped(ds: &SynthDataset, sched: &DdpmSchedule,
                           tg: &TimeGroups, n_total: usize, rng: &mut Rng)
                           -> Result<CalibSet> {
        if sched.steps.is_empty() {
            bail!("sampler schedule has no steps");
        }
        let il = ds.image_len();
        let mut tuples = Vec::with_capacity(n_total);
        for _ in 0..n_total {
            let t = sched.steps[rng.below(sched.steps.len())];
            let y = rng.below(ds.num_classes) as i32;
            let mut x0 = vec![0.0f32; il];
            ds.render(y as usize, rng, &mut x0);
            let eps = rng.normal_vec(il);
            let mut x_t = vec![0.0f32; il];
            sched.q_sample(&x0, t, &eps, &mut x_t);
            tuples.push(CalibTuple { x_t, t, y, eps, group: tg.group_of(t) });
        }
        Ok(CalibSet { tuples, groups: tg.clone(), per_group: None })
    }

    pub fn len(&self) -> usize {
        self.tuples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tuples.is_empty()
    }

    /// Indices of tuples in time group g.
    pub fn group_indices(&self, g: usize) -> Vec<usize> {
        self.tuples
            .iter()
            .enumerate()
            .filter(|(_, t)| t.group == g)
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(t_sample: usize, per_group: usize) -> CalibSet {
        let ds = SynthDataset::new(16, 3, 8);
        let sched = DdpmSchedule::new(250, 1e-4, 0.02, t_sample);
        let tg = TimeGroups::new(250, 10);
        let mut rng = Rng::new(7);
        CalibSet::build(&ds, &sched, &tg, per_group, &mut rng).unwrap()
    }

    #[test]
    fn paper_sizing_holds() {
        // n=4 per group, G=10 → 40 tuples (paper uses n=32; small here)
        let cs = fixture(250, 4);
        assert_eq!(cs.len(), 40);
        assert_eq!(cs.len(), cs.per_group.unwrap() * cs.groups.groups);
        for g in 0..10 {
            assert_eq!(cs.group_indices(g).len(), 4);
        }
    }

    #[test]
    fn empty_group_errors_instead_of_panicking() {
        // 5 respaced sampler steps cannot populate 10 contiguous groups
        let ds = SynthDataset::new(16, 3, 8);
        let sched = DdpmSchedule::new(250, 1e-4, 0.02, 5);
        let tg = TimeGroups::new(250, 10);
        let mut rng = Rng::new(1);
        let err = CalibSet::build(&ds, &sched, &tg, 2, &mut rng)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("covers no sampler steps"), "{msg}");
        assert!(msg.contains("G=10"), "{msg}");
    }

    #[test]
    fn tuples_respect_group_ranges() {
        let cs = fixture(250, 4);
        for tup in &cs.tuples {
            let (lo, hi) = cs.groups.range_of(tup.group);
            assert!(tup.t >= lo && tup.t <= hi);
        }
    }

    #[test]
    fn respaced_sampler_only_uses_visited_steps() {
        let cs = fixture(100, 4);
        let sched = DdpmSchedule::new(250, 1e-4, 0.02, 100);
        for tup in &cs.tuples {
            assert!(sched.steps.contains(&tup.t), "t={} not visited", tup.t);
        }
    }

    #[test]
    fn xt_is_noised_x0() {
        let cs = fixture(250, 2);
        // high-t tuples should look like ~unit-variance noise
        let high = cs
            .tuples
            .iter()
            .filter(|t| t.t > 230)
            .next()
            .expect("some high-t tuple");
        let var: f32 = high.x_t.iter().map(|v| v * v).sum::<f32>()
            / high.x_t.len() as f32;
        assert!(var > 0.5 && var < 2.0, "var {var}");
    }

    #[test]
    fn ungrouped_assigns_consistent_groups() {
        let ds = SynthDataset::new(16, 3, 8);
        let sched = DdpmSchedule::new(250, 1e-4, 0.02, 250);
        let tg = TimeGroups::new(250, 10);
        let mut rng = Rng::new(9);
        let cs = CalibSet::build_ungrouped(&ds, &sched, &tg, 64, &mut rng)
            .unwrap();
        assert_eq!(cs.len(), 64);
        // ungrouped sizing is honest: no fictitious per_group value
        assert_eq!(cs.per_group, None);
        for tup in &cs.tuples {
            assert_eq!(tup.group, tg.group_of(tup.t));
        }
    }
}
