//! Phase 3 — time-aware quantization (Algorithm 1, lines 13–31).
//!
//! Per quantizable layer, R alternating rounds of candidate-scale search
//! under the Hessian-guided objective (eq. 16):
//!
//! * linear layers — alternate Δ_W / Δ_X; the post-GELU X site (fc2.x)
//!   uses MRQ (two independent 1-D region searches) when enabled;
//! * MatMul layers — alternate Δ_A / Δ_B; the post-softmax A site (av.a)
//!   uses MRQ + per-time-group TGQ (eq. 17) when enabled.
//!
//! Toggles (`use_ho`, `use_mrq`, `use_tgq`) implement the Table III
//! ablation: all off = the uniform/MSE baseline, then each adds its
//! component.

use anyhow::Result;

use crate::coordinator::capture::Evidence;
use crate::coordinator::store::QuantConfig;
use crate::model::WeightStore;
use crate::quant::search::{argmin_candidates, coarse_fine, gelu_candidates,
                           softmax_candidates, uniform_candidates, Problem};
use crate::quant::{MrqGelu, SiteParams, UniformQ};
use crate::runtime::{Manifest, SiteKind};
use crate::sched::TimeGroups;

/// Knobs for the Phase-3 search (paper defaults in [`Default`]).
#[derive(Clone, Copy, Debug)]
pub struct QuantizeOpts {
    pub wbits: u32,
    pub abits: u32,
    /// Alternating rounds R (paper: 3).
    pub rounds: usize,
    /// Candidate evaluations per 1-D search.
    pub candidates: usize,
    pub use_ho: bool,
    pub use_mrq: bool,
    pub use_tgq: bool,
    /// Use the coarse→fine two-stage grid (TQ-DiT efficiency edge); the
    /// PTQ4DiT-style baseline sets this false (flat grids).
    pub coarse_fine: bool,
    /// Cap on evidence matrices in a *merged* (all-group) problem —
    /// group-shared parameters don't need every group's full reservoir;
    /// an even subsample across groups keeps the objective unbiased
    /// (§Perf: 2.4× faster search at unchanged winners on this model).
    pub max_merged_mats: usize,
}

impl Default for QuantizeOpts {
    fn default() -> Self {
        QuantizeOpts {
            wbits: 8,
            abits: 8,
            rounds: 3,
            candidates: 80,
            use_ho: true,
            use_mrq: true,
            use_tgq: true,
            coarse_fine: true,
            max_merged_mats: 24,
        }
    }
}

/// Cost counters surfaced for Table IV.
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchCost {
    /// Candidate objective evaluations performed.
    pub evals: u64,
    /// Layers processed.
    pub layers: u64,
}

/// Process-wide count of full Phase-3 runs — observability for the
/// calibration cache: tests assert a warm cache keeps this flat.
static QUANTIZE_RUNS: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(0);

/// How many times [`quantize`] has run in this process.
pub fn quantize_runs() -> u64 {
    QUANTIZE_RUNS.load(std::sync::atomic::Ordering::Relaxed)
}

/// Run Phase 3 and produce the full [`QuantConfig`].
pub fn quantize(manifest: &Manifest, weights: &WeightStore, ev: &Evidence,
                groups: &TimeGroups, method: &str, opts: QuantizeOpts)
                -> Result<(QuantConfig, SearchCost)> {
    QUANTIZE_RUNS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mut qc = QuantConfig::new(method, opts.wbits, opts.abits,
                                  groups.clone());
    let mut cost = SearchCost::default();

    for layer in &manifest.layers {
        let le = ev.layer(&layer.name);
        cost.layers += 1;
        if layer.ltype == "linear" {
            quantize_linear(layer, le, weights, &mut qc, &mut cost, opts)?;
        } else {
            quantize_matmul(layer, le, &mut qc, &mut cost, opts)?;
        }
        crate::debug_log!("calibrated layer {}", layer.name);
    }
    Ok((qc, cost))
}

/// Merge per-group evidence of a layer into one [`Problem`], evenly
/// subsampled down to `max_mats` matrices (unbiased — every group keeps
/// proportional representation). `weight` substitutes the B side for
/// linear layers.
fn merged_problem(le: &crate::coordinator::capture::LayerEvidence,
                  weight: Option<&crate::tensor::Tensor>, use_ho: bool,
                  max_mats: usize) -> Problem {
    let total: usize = le.a.iter().map(|g| g.len()).sum();
    let stride = total.div_ceil(max_mats.max(1)).max(1);
    let mut a = Vec::new();
    let mut b = Vec::new();
    let mut f = Vec::new();
    let mut idx = 0usize;
    for g in 0..le.a.len() {
        for (i, am) in le.a[g].iter().enumerate() {
            if idx % stride != 0 {
                idx += 1;
                continue;
            }
            idx += 1;
            a.push(am.clone());
            b.push(match weight {
                Some(w) => w.clone(),
                None => le.b[g][i].clone(),
            });
            if use_ho {
                f.push(le.fisher[g][i].clone());
            }
        }
    }
    let fisher = if use_ho { Some(f) } else { None };
    Problem::new(a, b, fisher)
}

/// Single-group [`Problem`] (TGQ per-group searches, eq. 17).
fn group_problem(le: &crate::coordinator::capture::LayerEvidence, g: usize,
                 use_ho: bool) -> Option<Problem> {
    if le.a[g].is_empty() {
        return None;
    }
    let fisher = if use_ho {
        Some(le.fisher[g].clone())
    } else {
        None
    };
    Some(Problem::new(le.a[g].clone(), le.b[g].clone(), fisher))
}

/// 1-D search helper honouring the coarse/fine toggle.
fn search_1d<F, G>(opts: QuantizeOpts, cost: &mut SearchCost, gen: G,
                   score: F) -> SiteParams
where
    F: Fn(&SiteParams) -> f64 + Sync,
    G: Fn(usize) -> Vec<SiteParams>,
{
    cost.evals += opts.candidates as u64;
    if opts.coarse_fine {
        coarse_fine(opts.candidates, gen, score).0
    } else {
        argmin_candidates(&gen(opts.candidates), score).0
    }
}

fn quantize_linear(layer: &crate::runtime::LayerMeta,
                   le: &crate::coordinator::capture::LayerEvidence,
                   weights: &WeightStore, qc: &mut QuantConfig,
                   cost: &mut SearchCost, opts: QuantizeOpts) -> Result<()> {
    let w = weights
        .get(&layer.weight)
        .unwrap_or_else(|| panic!("missing weight {}", layer.weight));
    let prob = merged_problem(le, Some(w), opts.use_ho,
                              opts.max_merged_mats);
    let site = &layer.sites[0];

    // inits: min–max on both operands
    let (wmn, wmx) = (w.min(), w.max());
    let mut qw =
        SiteParams::Uniform(UniformQ::from_minmax(wmn, wmx, opts.wbits));
    let (xmn, xmx) = prob.a_minmax();
    let gelu_init = MrqGelu::from_tensor(
        &le.a.iter().flatten().flat_map(|t| t.data.iter().copied())
            .collect::<Vec<f32>>(),
        opts.abits,
    );
    let mut qx = init_site(site.kind, xmn, xmx, gelu_init, opts);

    for _round in 0..opts.rounds {
        // Δ_W update under the current Δ_X (Alg. 1 line 18)
        qw = search_1d(opts, cost,
                       |n| uniform_candidates(wmn, wmx, opts.wbits, n),
                       |c| prob.eval(&qx, c));
        // Δ_X update under the new Δ_W (lines 19–22)
        qx = match (site.kind, opts.use_mrq) {
            (SiteKind::MrqGelu, true) => {
                // two independent 1-D region searches (neg s1, pos s2)
                let cur = match qx {
                    SiteParams::MrqGelu(m) => m,
                    _ => gelu_init,
                };
                let s1 = search_1d(opts, cost,
                                   |n| gelu_candidates(cur, 0, n),
                                   |c| prob.eval(c, &qw));
                let cur = match s1 {
                    SiteParams::MrqGelu(m) => m,
                    _ => cur,
                };
                search_1d(opts, cost, |n| gelu_candidates(cur, 1, n),
                          |c| prob.eval(c, &qw))
            }
            _ => search_1d(opts, cost,
                           |n| uniform_candidates(xmn, xmx, opts.abits, n),
                           |c| prob.eval(c, &qw)),
        };
    }

    if let SiteParams::Uniform(u) = qw {
        qc.weights.insert(layer.weight.clone(), u);
    }
    qc.sites.insert(site.name.clone(), qx);
    Ok(())
}

fn quantize_matmul(layer: &crate::runtime::LayerMeta,
                   le: &crate::coordinator::capture::LayerEvidence,
                   qc: &mut QuantConfig, cost: &mut SearchCost,
                   opts: QuantizeOpts) -> Result<()> {
    let prob = merged_problem(le, None, opts.use_ho,
                              opts.max_merged_mats);
    let sa = &layer.sites[0];
    let sb = &layer.sites[1];
    let (amn, amx) = prob.a_minmax();
    let (bmn, bmx) = prob.b_minmax();

    let mut qa = init_site(sa.kind, amn, amx,
                           MrqGelu::new(0.0, 0.0, opts.abits), opts);
    let mut qb =
        SiteParams::Uniform(UniformQ::from_minmax(bmn, bmx, opts.abits));

    let tgq_site = sa.tgq && opts.use_tgq;
    for _round in 0..opts.rounds {
        // Δ_A (Alg. 1 lines 26–30)
        qa = match (sa.kind, opts.use_mrq) {
            (SiteKind::MrqSoftmax, true) => {
                search_1d(opts, cost, |n| softmax_candidates(opts.abits, n),
                          |c| prob.eval(c, &qb))
            }
            _ => search_1d(opts, cost,
                           |n| uniform_candidates(amn, amx, opts.abits, n),
                           |c| prob.eval(c, &qb)),
        };
        // Δ_B (line 31)
        qb = search_1d(opts, cost,
                       |n| uniform_candidates(bmn, bmx, opts.abits, n),
                       |c| prob.eval(&qa, c));
    }
    qc.sites.insert(sa.name.clone(), qa);
    qc.sites.insert(sb.name.clone(), qb);

    // TGQ overlay: re-run the Δ_A search per time group (eq. 17) with
    // the group's own evidence, holding Δ_B fixed.
    if tgq_site {
        let mut per_group = Vec::with_capacity(qc.groups.groups);
        for g in 0..qc.groups.groups {
            let p = match group_problem(le, g, opts.use_ho) {
                Some(p) => p,
                None => {
                    per_group.push(qa);
                    continue;
                }
            };
            let best = match opts.use_mrq {
                true => search_1d(opts, cost,
                                  |n| softmax_candidates(opts.abits, n),
                                  |c| p.eval(c, &qb)),
                false => {
                    let (gmn, gmx) = p.a_minmax();
                    search_1d(opts, cost,
                              |n| uniform_candidates(gmn, gmx, opts.abits, n),
                              |c| p.eval(c, &qb))
                }
            };
            per_group.push(best);
        }
        qc.tgq.insert(sa.name.clone(), per_group);
    }
    Ok(())
}

fn init_site(kind: SiteKind, mn: f32, mx: f32, gelu_init: MrqGelu,
             opts: QuantizeOpts) -> SiteParams {
    match (kind, opts.use_mrq) {
        (SiteKind::MrqSoftmax, true) => SiteParams::MrqSoftmax(
            crate::quant::MrqSoftmax::default_for_bits(opts.abits)),
        (SiteKind::MrqGelu, true) => SiteParams::MrqGelu(gelu_init),
        _ => SiteParams::Uniform(UniformQ::from_minmax(mn, mx, opts.abits)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::capture::LayerEvidence;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn toy_evidence(groups: usize, softmax_like: bool) -> LayerEvidence {
        let mut rng = Rng::new(5);
        let mut le = LayerEvidence::new("matmul", groups);
        for g in 0..groups {
            for _ in 0..3 {
                let mut a = rng.normal_vec(16 * 8);
                if softmax_like {
                    // probability-ish values concentrated near 0
                    for v in a.iter_mut() {
                        *v = (v.abs() * 0.05).min(1.0);
                    }
                }
                le.a[g].push(Tensor::new(vec![16, 8], a));
                le.b[g].push(Tensor::new(vec![8, 4], rng.normal_vec(32)));
                le.fisher[g].push(Tensor::new(vec![16, 4],
                                              rng.normal_vec(64)));
            }
        }
        le
    }

    #[test]
    fn merged_problem_spans_groups() {
        let le = toy_evidence(3, false);
        let p = merged_problem(&le, None, true, usize::MAX);
        assert_eq!(p.a.len(), 9);
        assert!(p.fisher.is_some());
        let p2 = merged_problem(&le, None, false, usize::MAX);
        assert!(p2.fisher.is_none());
    }

    #[test]
    fn group_problem_isolates_one_group() {
        let le = toy_evidence(2, false);
        let p = group_problem(&le, 1, true).unwrap();
        assert_eq!(p.a.len(), 3);
        // missing group → None
        let empty = LayerEvidence::new("matmul", 2);
        assert!(group_problem(&empty, 0, true).is_none());
    }

    #[test]
    fn search_1d_flat_vs_coarse_fine_agree_roughly() {
        let le = toy_evidence(1, false);
        let p = merged_problem(&le, None, false, usize::MAX);
        let (mn, mx) = p.a_minmax();
        let mut cost = SearchCost::default();
        let score = |c: &SiteParams| p.eval(c, &SiteParams::Bypass);
        let opts_cf = QuantizeOpts { coarse_fine: true, ..Default::default() };
        let opts_flat =
            QuantizeOpts { coarse_fine: false, ..Default::default() };
        let a = search_1d(opts_cf, &mut cost,
                          |n| uniform_candidates(mn, mx, 6, n), score);
        let b = search_1d(opts_flat, &mut cost,
                          |n| uniform_candidates(mn, mx, 6, n), score);
        let la = score(&a);
        let lb = score(&b);
        // coarse/fine within 10% of the flat-grid optimum
        assert!(la <= lb * 1.10 + 1e-12, "{la} vs {lb}");
        assert_eq!(cost.evals, 160);
    }
}
