//! Phase 2 — layer-wise forward/backward over the calibration set.
//!
//! Runs the `dit_capture` artifact (FP forward + ∂L/∂z per quantizable
//! layer, L the DDPM noise-MSE of eq. 11) over the calibration tuples
//! and streams the evidence the Phase-3 search needs into bounded
//! per-(layer, time-group) reservoirs:
//!
//! * the layer's operand matrices (X for linears; A and B for MatMuls),
//!   decomposed into the 2-D sub-matrices the host-side HO objective
//!   multiplies (`quant::search::Problem`);
//! * the matching ∂L/∂z matrices (diagonal-Fisher ingredients, eq. 15);
//! * side products for the Fig. 2/3 reproductions: post-softmax /
//!   post-GELU value histograms and the per-timestep post-softmax
//!   channel-magnitude maxima.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::coordinator::calib::CalibSet;
use crate::model::WeightStore;
use crate::runtime::{Runtime, SiteKind};
use crate::tensor::stats::Histogram;
use crate::tensor::Tensor;

/// Evidence reservoir for one quantizable layer.
#[derive(Clone, Debug, Default)]
pub struct LayerEvidence {
    /// "linear" | "matmul".
    pub ltype: String,
    /// Per time group: captured 2-D A operands (X for linears).
    pub a: Vec<Vec<Tensor>>,
    /// Per time group: captured 2-D B operands (MatMul layers only;
    /// linears take B = the weight matrix from the [`WeightStore`]).
    pub b: Vec<Vec<Tensor>>,
    /// Per time group: 2-D ∂L/∂z matching z = A·B row/col-wise.
    pub fisher: Vec<Vec<Tensor>>,
}

impl LayerEvidence {
    pub fn new(ltype: &str, groups: usize) -> LayerEvidence {
        LayerEvidence {
            ltype: ltype.to_string(),
            a: vec![Vec::new(); groups],
            b: vec![Vec::new(); groups],
            fisher: vec![Vec::new(); groups],
        }
    }

    /// Total A matrices stored across groups.
    pub fn n_mats(&self) -> usize {
        self.a.iter().map(|g| g.len()).sum()
    }

    /// Approximate resident bytes (Table IV memory accounting).
    pub fn bytes(&self) -> usize {
        let f = |v: &Vec<Vec<Tensor>>| -> usize {
            v.iter()
                .flat_map(|g| g.iter())
                .map(|t| t.len() * 4)
                .sum::<usize>()
        };
        f(&self.a) + f(&self.b) + f(&self.fisher)
    }
}

/// Everything Phase 3 needs, plus the Fig. 2/3 side channels.
#[derive(Clone, Debug)]
pub struct Evidence {
    pub layers: HashMap<String, LayerEvidence>,
    pub groups: usize,
    /// Post-softmax value histogram over all blocks (Fig. 2a).
    pub softmax_hist: Histogram,
    /// Post-GELU value histogram over all blocks (Fig. 2b).
    pub gelu_hist: Histogram,
    /// (timestep, max |post-softmax| over channels) per batch (Fig. 3).
    pub softmax_max_by_t: Vec<(usize, f32)>,
    /// Total capture-artifact executions (cost accounting).
    pub batches_run: usize,
}

impl Evidence {
    pub fn layer(&self, name: &str) -> &LayerEvidence {
        self.layers
            .get(name)
            .unwrap_or_else(|| panic!("no evidence for layer `{name}`"))
    }

    /// Total resident evidence bytes (Table IV memory accounting).
    pub fn bytes(&self) -> usize {
        self.layers.values().map(|l| l.bytes()).sum()
    }
}

/// Reservoir caps; the TQ-DiT calibrator keeps these small (that is its
/// Table-IV efficiency edge), the PTQ4DiT-style baseline inflates them.
#[derive(Clone, Copy, Debug)]
pub struct CaptureOpts {
    /// Max stored (A, B, fisher) triples per (layer, group) for MatMul
    /// layers (each calib batch yields B·H candidate matrices).
    pub max_mats_matmul: usize,
    /// Max stored triples per (layer, group) for linear layers (one per
    /// calib batch).
    pub max_mats_linear: usize,
    /// Max token rows kept per linear evidence matrix. The HO objective
    /// is an expectation over rows, so strided row subsampling is an
    /// unbiased cost cut (§Perf: 8× faster candidate evals at <1% loss
    /// change on this model).
    pub max_rows_linear: usize,
}

impl Default for CaptureOpts {
    fn default() -> Self {
        CaptureOpts {
            max_mats_matmul: 12,
            max_mats_linear: 6,
            max_rows_linear: 64,
        }
    }
}

/// Run Phase 2: capture evidence over the whole calibration set.
///
/// Weights stay FP here — the capture artifact measures the *original*
/// model (eq. 16 compares quantized outputs against these references).
pub fn run_capture(rt: &Runtime, weights: &WeightStore, calib: &CalibSet,
                   opts: CaptureOpts) -> Result<Evidence> {
    let m = rt.manifest.clone();
    let bsz = m.batches.calib;
    let img = m.model.img_size;
    let ch = m.model.channels;
    let il = img * img * ch;
    let groups = calib.groups.groups;

    let mut ev = Evidence {
        layers: m
            .layers
            .iter()
            .map(|l| (l.name.clone(), LayerEvidence::new(&l.ltype, groups)))
            .collect(),
        groups,
        softmax_hist: Histogram::new(0.0, 1.0, 64),
        gelu_hist: Histogram::new(-1.0, 6.0, 64),
        softmax_max_by_t: Vec::new(),
        batches_run: 0,
    };

    let pbufs = rt.upload_all(&weights.tensors)?;

    // batch the tuples; tuples are grouped contiguously so a batch is
    // (nearly always) single-group — the tail pads by repetition.
    let n = calib.len();
    let mut start = 0usize;
    while start < n {
        let idx: Vec<usize> =
            (0..bsz).map(|i| (start + i).min(n - 1)).collect();
        let real = (n - start).min(bsz);
        let mut x = vec![0.0f32; bsz * il];
        let mut eps = vec![0.0f32; bsz * il];
        let mut t = vec![0i32; bsz];
        let mut y = vec![0i32; bsz];
        for (bi, &ti) in idx.iter().enumerate() {
            let tup = &calib.tuples[ti];
            x[bi * il..(bi + 1) * il].copy_from_slice(&tup.x_t);
            eps[bi * il..(bi + 1) * il].copy_from_slice(&tup.eps);
            t[bi] = tup.t as i32;
            y[bi] = tup.y;
        }
        let xb = rt.upload(&Tensor::new(vec![bsz, img, img, ch], x))?;
        let tb = rt.upload_i32(&t, &[bsz])?;
        let yb = rt.upload_i32(&y, &[bsz])?;
        let eb = rt.upload(&Tensor::new(vec![bsz, img, img, ch], eps))?;
        let mut inputs: Vec<&xla::PjRtBuffer> = pbufs.iter().collect();
        inputs.extend([&xb, &tb, &yb, &eb]);
        let outs = rt
            .run_buffers("dit_capture", &inputs)
            .context("dit_capture execution")?;
        ev.batches_run += 1;

        // outs[0] = eps_pred; rest in manifest.capture_outputs order.
        let by_name: HashMap<&str, &Tensor> = m
            .capture_outputs
            .iter()
            .enumerate()
            .map(|(i, (name, _))| (name.as_str(), &outs[i + 1]))
            .collect();

        for layer in &m.layers {
            let grad = *by_name
                .get(format!("{}.grad", layer.name).as_str())
                .with_context(|| format!("missing grad for {}", layer.name))?;
            let le = ev.layers.get_mut(&layer.name).unwrap();
            if layer.ltype == "linear" {
                let xsite = *by_name.get(layer.sites[0].name.as_str()).unwrap();
                ingest_linear(le, &calib.tuples, &idx[..real], xsite, grad,
                              opts.max_mats_linear, opts.max_rows_linear);
            } else {
                let a = *by_name.get(layer.sites[0].name.as_str()).unwrap();
                let b = *by_name.get(layer.sites[1].name.as_str()).unwrap();
                ingest_matmul(le, &calib.tuples, &idx[..real], a, b, grad,
                              layer.sites[0].kind == SiteKind::MrqSoftmax,
                              opts.max_mats_matmul);
            }
            // Fig. 2/3 side channels from the MRQ sites
            match layer.sites[0].kind {
                SiteKind::MrqSoftmax => {
                    let a = *by_name.get(layer.sites[0].name.as_str()).unwrap();
                    let per = a.len() / bsz;
                    for (bi, &ti) in idx.iter().enumerate().take(real) {
                        let vals = &a.data[bi * per..(bi + 1) * per];
                        let mx = vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                        ev.softmax_max_by_t.push((calib.tuples[ti].t, mx));
                        // subsample the histogram to bound cost
                        for &v in vals.iter().step_by(7) {
                            ev.softmax_hist.push(v);
                        }
                    }
                }
                _ => {}
            }
            if layer.sites[0].kind == SiteKind::MrqGelu {
                let g = *by_name.get(layer.sites[0].name.as_str()).unwrap();
                for &v in g.data.iter().step_by(11) {
                    ev.gelu_hist.push(v);
                }
            }
        }
        start += real;
    }
    Ok(ev)
}

/// Linear layer: X (B, ..., K) → one 2-D (rows, K) matrix per batch;
/// grad likewise. Stored per time group of each sample — a batch can mix
/// groups at the tail, so rows are bucketed sample-wise. Rows are
/// stride-subsampled down to `max_rows` per stored matrix (unbiased for
/// the HO expectation; see `CaptureOpts::max_rows_linear`).
fn ingest_linear(le: &mut LayerEvidence, tuples: &[crate::coordinator::calib::CalibTuple],
                 idx: &[usize], xsite: &Tensor, grad: &Tensor, cap: usize,
                 max_rows: usize) {
    let bsz_rows = xsite.rows();
    let rows_per_sample = bsz_rows / xsite.shape[0];
    let k = xsite.cols();
    let out = grad.cols();
    debug_assert_eq!(grad.rows() / grad.shape[0], rows_per_sample);
    // bucket samples by group
    let mut by_group: HashMap<usize, Vec<usize>> = HashMap::new();
    for (bi, &ti) in idx.iter().enumerate() {
        by_group.entry(tuples[ti].group).or_default().push(bi);
    }
    for (g, bis) in by_group {
        if le.a[g].len() >= cap {
            continue;
        }
        let total_rows = bis.len() * rows_per_sample;
        let stride = total_rows.div_ceil(max_rows.max(1)).max(1);
        let mut xm = Vec::new();
        let mut gm = Vec::new();
        let mut rows = 0usize;
        let mut r_global = 0usize;
        for &bi in &bis {
            for r in 0..rows_per_sample {
                if r_global % stride == 0 {
                    let xs = (bi * rows_per_sample + r) * k;
                    xm.extend_from_slice(&xsite.data[xs..xs + k]);
                    let gs = (bi * rows_per_sample + r) * out;
                    gm.extend_from_slice(&grad.data[gs..gs + out]);
                    rows += 1;
                }
                r_global += 1;
            }
        }
        le.a[g].push(Tensor::new(vec![rows, k], xm));
        le.fisher[g].push(Tensor::new(vec![rows, out], gm));
    }
}

/// MatMul layer: operands (B, H, N, d)-style → per-(sample, head) 2-D
/// matrices. For QKᵀ the B operand arrives as K (B, H, N, d) and is
/// transposed here so stored pairs satisfy z = A·B directly.
#[allow(clippy::too_many_arguments)]
fn ingest_matmul(le: &mut LayerEvidence, tuples: &[crate::coordinator::calib::CalibTuple],
                 idx: &[usize], a: &Tensor, b: &Tensor, grad: &Tensor,
                 a_is_softmax: bool, cap: usize) {
    let bsz = a.shape[0];
    let heads = a.shape[1];
    let (an, ak) = (a.shape[2], a.shape[3]);
    let (bn, bk) = (b.shape[2], b.shape[3]);
    let (gn, gk) = (grad.shape[2], grad.shape[3]);
    let _ = bsz;
    for (bi, &ti) in idx.iter().enumerate() {
        let g = tuples[ti].group;
        for h in 0..heads {
            if le.a[g].len() >= cap {
                break;
            }
            let off_a = (bi * heads + h) * an * ak;
            let am = Tensor::new(vec![an, ak],
                                 a.data[off_a..off_a + an * ak].to_vec());
            let off_b = (bi * heads + h) * bn * bk;
            let bm_raw = Tensor::new(vec![bn, bk],
                                     b.data[off_b..off_b + bn * bk].to_vec());
            // AV: A (N,N) softmax probs · B = V (N, hd) — already aligned.
            // QKᵀ: A = Q (N, hd), captured B = K (N, hd) → use Kᵀ (hd, N).
            let bm = if a_is_softmax { bm_raw } else { bm_raw.t() };
            debug_assert_eq!(ak, bm.shape[0], "operand alignment");
            let off_g = (bi * heads + h) * gn * gk;
            let gm = Tensor::new(vec![gn, gk],
                                 grad.data[off_g..off_g + gn * gk].to_vec());
            le.a[g].push(am);
            le.b[g].push(bm);
            le.fisher[g].push(gm);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evidence_caps_respected() {
        let mut le = LayerEvidence::new("matmul", 2);
        let tuples: Vec<crate::coordinator::calib::CalibTuple> = (0..4)
            .map(|i| crate::coordinator::calib::CalibTuple {
                x_t: vec![],
                t: i,
                y: 0,
                eps: vec![],
                group: 0,
            })
            .collect();
        let idx: Vec<usize> = (0..4).collect();
        // (B=4, H=2, N=3, d=3) operands
        let a = Tensor::zeros(vec![4, 2, 3, 3]);
        let b = Tensor::zeros(vec![4, 2, 3, 3]);
        let grad = Tensor::zeros(vec![4, 2, 3, 3]);
        ingest_matmul(&mut le, &tuples, &idx, &a, &b, &grad, true, 5);
        // 4 samples × 2 heads = 8 candidates, capped at 5
        assert_eq!(le.a[0].len(), 5);
        assert_eq!(le.b[0].len(), 5);
        assert_eq!(le.fisher[0].len(), 5);
        assert_eq!(le.a[1].len(), 0);
    }

    #[test]
    fn qk_operand_is_transposed() {
        let mut le = LayerEvidence::new("matmul", 1);
        let tuples = vec![crate::coordinator::calib::CalibTuple {
            x_t: vec![],
            t: 0,
            y: 0,
            eps: vec![],
            group: 0,
        }];
        // Q (1,1,2,3), K (1,1,2,3) → stored B must be (3,2)
        let a = Tensor::zeros(vec![1, 1, 2, 3]);
        let b = Tensor::new(vec![1, 1, 2, 3],
                            vec![1., 2., 3., 4., 5., 6.]);
        let grad = Tensor::zeros(vec![1, 1, 2, 2]);
        ingest_matmul(&mut le, &tuples, &[0], &a, &b, &grad, false, 8);
        assert_eq!(le.b[0][0].shape, vec![3, 2]);
        // Kᵀ column 0 is K row 0
        assert_eq!(le.b[0][0].data, vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn linear_rows_bucketed_by_group() {
        let mut le = LayerEvidence::new("linear", 2);
        let tuples: Vec<crate::coordinator::calib::CalibTuple> = [0usize, 1]
            .iter()
            .map(|&g| crate::coordinator::calib::CalibTuple {
                x_t: vec![],
                t: 0,
                y: 0,
                eps: vec![],
                group: g,
            })
            .collect();
        // X (B=2, N=3, K=2), grad (2, 3, 4)
        let x = Tensor::new(vec![2, 3, 2], (0..12).map(|v| v as f32).collect());
        let grad = Tensor::zeros(vec![2, 3, 4]);
        ingest_linear(&mut le, &tuples, &[0, 1], &x, &grad, 4, 1024);
        assert_eq!(le.a[0].len(), 1);
        assert_eq!(le.a[1].len(), 1);
        assert_eq!(le.a[0][0].shape, vec![3, 2]);
        // group-0 matrix holds sample 0's rows
        assert_eq!(le.a[0][0].data, (0..6).map(|v| v as f32).collect::<Vec<_>>());
        assert_eq!(le.a[1][0].data, (6..12).map(|v| v as f32).collect::<Vec<_>>());
    }
}
