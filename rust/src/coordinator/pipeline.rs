//! End-to-end pipelines: calibrate → quantize → sample → evaluate.
//!
//! This is the module the tables, figures and examples drive. One
//! [`Pipeline`] owns the PJRT runtime, the FP weights and the run
//! configuration; [`Pipeline::calibrate`] produces a [`QuantConfig`]
//! (plus the Table-IV cost counters) for any [`Method`], and
//! [`Pipeline::evaluate`] turns a config into a Table-I/II row.
//!
//! Construction and calibration are deliberately split: a `QuantConfig`
//! is plain (cloneable, `Send`) data, so the serve layer calibrates on
//! one pipeline and rebuilds samplers from the shared config on every
//! worker thread via [`Pipeline::sampler`].

use anyhow::Result;

use crate::coordinator::baselines;
use crate::coordinator::cache::{artifacts_fingerprint, CacheKey,
                                CalibCache};
use crate::coordinator::calib::CalibSet;
use crate::coordinator::capture::{run_capture, CaptureOpts, Evidence};
use crate::coordinator::quantize::{quantize, QuantizeOpts};
use crate::coordinator::QuantConfig;
use crate::data::SynthDataset;
use crate::metrics::{EvalRow, Evaluator};
use crate::model::WeightStore;
use crate::runtime::Runtime;
use crate::sampler::Sampler;
use crate::sched::{DdpmSchedule, TimeGroups};
use crate::util::config::RunConfig;
use crate::util::meminfo::MemProbe;
use crate::util::rng::Rng;

/// The five columns of Tables I/II.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Fp,
    QDiffusion,
    Ptqd,
    Ptq4Dit,
    TqDit,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Fp => "fp",
            Method::QDiffusion => "q-diffusion",
            Method::Ptqd => "ptqd",
            Method::Ptq4Dit => "ptq4dit",
            Method::TqDit => "tq-dit",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "fp" => Method::Fp,
            "q-diffusion" | "qdiff" => Method::QDiffusion,
            "ptqd" => Method::Ptqd,
            "ptq4dit" => Method::Ptq4Dit,
            "tq-dit" | "tqdit" => Method::TqDit,
            _ => return None,
        })
    }

    pub const ALL_QUANT: [Method; 4] = [
        Method::QDiffusion,
        Method::Ptqd,
        Method::Ptq4Dit,
        Method::TqDit,
    ];
}

/// Calibration cost (Table IV): wall-clock, peak-RSS delta, evidence
/// bytes and objective evaluations.
#[derive(Clone, Copy, Debug, Default)]
pub struct CalibCost {
    pub wall_s: f64,
    pub peak_rss_delta: u64,
    pub evidence_bytes: usize,
    pub evals: u64,
    pub capture_batches: usize,
}

impl CalibCost {
    pub fn print(&self, label: &str) {
        println!(
            "{label:<14} calib {:>7.2}s  mem {:>10}  evidence {:>10}  \
             {:>8} evals  {:>4} capture batches",
            self.wall_s,
            crate::util::meminfo::fmt_bytes(self.peak_rss_delta),
            crate::util::meminfo::fmt_bytes(self.evidence_bytes as u64),
            self.evals,
            self.capture_batches,
        );
    }
}

/// Owns everything an experiment needs.
pub struct Pipeline {
    pub rt: Runtime,
    pub weights: WeightStore,
    pub cfg: RunConfig,
    pub ds: SynthDataset,
    pub groups: TimeGroups,
}

impl Pipeline {
    pub fn new(cfg: RunConfig) -> Result<Pipeline> {
        let rt = Runtime::load(std::path::Path::new(&cfg.artifacts))?;
        let weights = WeightStore::load(&rt.manifest)?;
        let m = &rt.manifest.model;
        let ds = SynthDataset::new(m.img_size, m.channels, m.num_classes);
        let groups =
            TimeGroups::new(rt.manifest.diffusion.train_steps, cfg.groups);
        Ok(Pipeline { rt, weights, cfg, ds, groups })
    }

    pub fn schedule(&self) -> DdpmSchedule {
        let d = &self.rt.manifest.diffusion;
        DdpmSchedule::new(d.train_steps, d.beta_start, d.beta_end,
                          self.cfg.timesteps)
    }

    /// Phase 1+2 for the time-grouped (TQ-DiT) path.
    pub fn grouped_evidence(&self, rng: &mut Rng)
                            -> Result<(CalibSet, Evidence)> {
        let sched = self.schedule();
        let calib = CalibSet::build(&self.ds, &sched, &self.groups,
                                    self.cfg.calib_per_group, rng)?;
        let ev = run_capture(&self.rt, &self.weights, &calib,
                             CaptureOpts::default())?;
        Ok((calib, ev))
    }

    /// Phase 1+2 for the ungrouped baselines; `scale` multiplies the
    /// calibration-set size (PTQ4DiT uses a large set — its Table IV
    /// cost — while Q-Diffusion/PTQD match TQ-DiT's total).
    pub fn ungrouped_evidence(&self, scale: usize, caps: CaptureOpts,
                              rng: &mut Rng) -> Result<(CalibSet, Evidence)> {
        let sched = self.schedule();
        let total = self.cfg.calib_per_group * self.cfg.groups * scale;
        let calib = CalibSet::build_ungrouped(&self.ds, &sched, &self.groups,
                                              total, rng)?;
        let ev = run_capture(&self.rt, &self.weights, &calib, caps)?;
        Ok((calib, ev))
    }

    /// Schedule-derived per-group ε-drift, recorded on every calibrated
    /// config (all methods, including FP): the statistic the sampler's
    /// step-reuse policy compares against `--reuse-delta`.
    fn stamp_drift(&self, qc: &mut QuantConfig) {
        qc.drift = crate::sampler::reuse::drift_from_schedule(
            &self.schedule(), &self.groups);
    }

    /// Calibrate with `method`, measuring Table-IV costs.
    pub fn calibrate(&self, method: Method, rng: &mut Rng)
                     -> Result<(QuantConfig, CalibCost)> {
        let probe = MemProbe::start();
        let t0 = std::time::Instant::now();
        let c = &self.cfg;
        let (mut qc, evals, ev_bytes, batches) = match method {
            Method::Fp => {
                let mut qc = QuantConfig::fp(self.groups.clone());
                self.stamp_drift(&mut qc);
                return Ok((qc, CalibCost::default()));
            }
            Method::TqDit => {
                let (_, ev) = self.grouped_evidence(rng)?;
                let opts = QuantizeOpts {
                    wbits: c.wbits,
                    abits: c.abits,
                    rounds: c.rounds,
                    candidates: c.candidates,
                    use_ho: c.use_ho,
                    use_mrq: c.use_mrq,
                    use_tgq: c.use_tgq,
                    coarse_fine: true,
                    max_merged_mats: 24,
                };
                let (qc, cost) = quantize(&self.rt.manifest, &self.weights,
                                          &ev, &self.groups, "tq-dit",
                                          opts)?;
                (qc, cost.evals, ev.bytes(), ev.batches_run)
            }
            Method::QDiffusion => {
                let (_, ev) =
                    self.ungrouped_evidence(1, CaptureOpts::default(), rng)?;
                let (qc, cost) = baselines::q_diffusion(
                    &self.rt.manifest, &self.weights, &ev, &self.groups,
                    c.wbits, c.abits, c.rounds, c.candidates)?;
                (qc, cost.evals, ev.bytes(), ev.batches_run)
            }
            Method::Ptqd => {
                let (calib, ev) =
                    self.ungrouped_evidence(1, CaptureOpts::default(), rng)?;
                let (qc, cost) = baselines::ptqd(
                    &self.rt, &self.weights, &ev, &calib, &self.groups,
                    c.wbits, c.abits, c.rounds, c.candidates)?;
                (qc, cost.evals, ev.bytes(), ev.batches_run)
            }
            Method::Ptq4Dit => {
                // salience pass over a 4× calibration set with inflated
                // evidence reservoirs and flat 2× candidate grids.
                let caps = CaptureOpts {
                    max_mats_matmul: 16,
                    max_mats_linear: 8,
                    // 3× the rows TQ-DiT keeps — the salience pass wants
                    // a denser view of the token distribution. Together
                    // with the 4× calib set and flat 2× grids this puts
                    // its calibration cost ~an order of magnitude above
                    // TQ-DiT's, the Table IV regime.
                    max_rows_linear: 192,
                };
                let (_, ev) = self.ungrouped_evidence(4, caps, rng)?;
                let (qc, cost) = baselines::ptq4dit(
                    &self.rt.manifest, &self.weights, &ev, &self.groups,
                    c.wbits, c.abits, c.rounds, c.candidates * 2)?;
                (qc, cost.evals, ev.bytes(), ev.batches_run)
            }
        };
        self.stamp_drift(&mut qc);
        let cost = CalibCost {
            wall_s: t0.elapsed().as_secs_f64(),
            peak_rss_delta: probe.finish().rss_delta,
            evidence_bytes: ev_bytes,
            evals,
            capture_batches: batches,
        };
        Ok((qc, cost))
    }

    /// The persistent calibration cache configured for this run
    /// (`None` when disabled via `--no-calib-cache`).
    pub fn calib_cache(&self) -> Option<CalibCache> {
        self.cfg.calib_cache.as_ref().map(CalibCache::new)
    }

    /// Content-addressed cache key for `method` under the current
    /// config + artifacts. `None` for FP (calibration is free) or when
    /// the artifact files cannot be hashed.
    pub fn cache_key(&self, method: Method) -> Option<CacheKey> {
        if method == Method::Fp {
            return None;
        }
        match artifacts_fingerprint(&self.rt.manifest) {
            Ok(h) => {
                Some(CacheKey::from_config(&self.cfg, method.name(), h))
            }
            Err(e) => {
                crate::warn_log!(
                    "calib cache disabled for this run: {e:#}");
                None
            }
        }
    }

    /// Cache-aware [`Self::calibrate`]: load → on miss calibrate →
    /// persist. The third element reports the cache outcome:
    /// `Some(true)` hit (the [`CalibCost`] is zero — nothing was
    /// computed), `Some(false)` miss, `None` cache not consulted
    /// (disabled, unhashable artifacts, or FP). Cache load failures of
    /// any kind degrade to fresh calibration; store failures are
    /// logged and otherwise ignored.
    ///
    /// The calibration RNG stream is fixed here (`seed ^ 0x5eed`, the
    /// same stream the table/CLI paths use) rather than taken from the
    /// caller: the cached config is keyed as a pure function of
    /// (artifacts, settings), so every consumer must calibrate from the
    /// same stream or a warm cache would alias differently-seeded runs.
    pub fn calibrate_cached(&self, method: Method)
                            -> Result<(QuantConfig, CalibCost,
                                       Option<bool>)> {
        let cache = self.calib_cache();
        let key = if cache.is_some() { self.cache_key(method) } else { None };
        let consulted = cache.is_some() && key.is_some();
        if let (Some(cache), Some(key)) = (&cache, &key) {
            if let Some(qc) = cache.load(key) {
                crate::info!(
                    "calibration cache hit for {} (skipping phases 1-3)",
                    method.name()
                );
                return Ok((qc, CalibCost::default(), Some(true)));
            }
        }
        let mut rng = Rng::new(self.cfg.seed ^ 0x5eed);
        let (qc, cost) = self.calibrate(method, &mut rng)?;
        if let (Some(cache), Some(key)) = (&cache, &key) {
            if let Err(e) = cache.store(key, &qc) {
                crate::warn_log!("calib cache store failed: {e:#}");
            }
        }
        Ok((qc, cost, if consulted { Some(false) } else { None }))
    }

    /// Build a sampler for an already-calibrated config at the largest
    /// lowered batch rung. This is the second half of the
    /// calibrate/serve split: serve workers calibrate *once*, clone the
    /// resulting [`QuantConfig`] across threads, and each builds its
    /// own sampler here without re-running calibration.
    pub fn sampler(&self, qc: &QuantConfig) -> Result<Sampler<'_>> {
        let mut s = Sampler::new(&self.rt, &self.weights, qc.clone(),
                                 self.cfg.timesteps)?;
        s.set_reuse_delta(self.cfg.reuse_delta);
        Ok(s)
    }

    /// Build one sampler per lowered batch rung (optionally restricted
    /// to `rungs`), sharing a single resident upload of the quantized
    /// weights. Serve workers hold the whole ladder so the router's
    /// batch policy can dispatch trickle traffic on small rungs and
    /// bursts on the full batch.
    pub fn sampler_ladder(&self, qc: &QuantConfig,
                          rungs: Option<&[usize]>)
                          -> Result<Vec<Sampler<'_>>> {
        let mut ladder = Sampler::ladder(&self.rt, &self.weights, qc,
                                         self.cfg.timesteps, rungs)?;
        for s in ladder.iter_mut() {
            s.set_reuse_delta(self.cfg.reuse_delta);
        }
        Ok(ladder)
    }

    /// Sample `n` images under `qc` and score FID/sFID/IS.
    pub fn evaluate(&self, qc: &QuantConfig, n: usize, seed: u64)
                    -> Result<EvalRow> {
        let sampler = self.sampler(qc)?;
        let mut eval = Evaluator::new(&self.rt)?;
        let mut rng = Rng::new(seed);
        sampler.generate(n, self.ds.num_classes, &mut rng,
                         |imgs, _| eval.push_images(imgs))?;
        eval.finish()
    }

    /// Sample a grid of images (Fig. 6) under `qc`.
    pub fn sample_grid(&self, qc: &QuantConfig, n: usize, seed: u64)
                       -> Result<Vec<f32>> {
        let sampler = self.sampler(qc)?;
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(n * sampler.img_len());
        sampler.generate(n, self.ds.num_classes, &mut rng, |imgs, _| {
            out.extend_from_slice(imgs);
            Ok(())
        })?;
        Ok(out)
    }

    /// One full table row: calibrate + evaluate.
    pub fn table_cell(&self, method: Method, n_eval: usize)
                      -> Result<(EvalRow, CalibCost)> {
        let mut rng = Rng::new(self.cfg.seed ^ 0x5eed);
        let (qc, cost) = self.calibrate(method, &mut rng)?;
        let row = self.evaluate(&qc, n_eval, self.cfg.seed ^ 0xe7a1)?;
        Ok((row, cost))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names_roundtrip() {
        for m in [Method::Fp, Method::QDiffusion, Method::Ptqd,
                  Method::Ptq4Dit, Method::TqDit] {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }
}
