//! Quantization configuration store + runtime qparams packing.
//!
//! A [`QuantConfig`] is the complete output of any calibrator: per-site
//! activation parameters (with per-time-group overlays for TGQ sites),
//! per-weight quantizers, and optional PTQD-style output correction.
//! `qparams_for_group` packs the flat f32 vector the `dit_quant`
//! artifact consumes; the sampler swaps vectors at group boundaries.

use std::collections::HashMap;

use crate::quant::{SiteParams, UniformQ, QP_STRIDE};
use crate::runtime::Manifest;
use crate::sched::TimeGroups;

/// PTQD-style quantization-noise correction statistics (per time group).
#[derive(Clone, Copy, Debug)]
pub struct NoiseCorrection {
    /// Correlated part: ε̂ ≈ ρ·ε_fp → divide by ρ.
    pub rho: f32,
    /// Mean residual bias to subtract.
    pub bias: f32,
    /// Residual (uncorrelated) variance to remove from σ².
    pub resid_var: f32,
}

impl Default for NoiseCorrection {
    fn default() -> Self {
        NoiseCorrection { rho: 1.0, bias: 0.0, resid_var: 0.0 }
    }
}

/// Complete quantization decision for one (method, bit-width) run.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    /// Human-readable calibrator name ("tq-dit", "q-diffusion", ...).
    pub method: String,
    pub wbits: u32,
    pub abits: u32,
    /// Activation params per site (group-independent sites).
    pub sites: HashMap<String, SiteParams>,
    /// TGQ overlays: site → per-group params (len = groups).
    pub tgq: HashMap<String, Vec<SiteParams>>,
    /// Weight quantizers by param name (host-side fake-quant).
    pub weights: HashMap<String, UniformQ>,
    /// Time grouping used for the TGQ overlays.
    pub groups: TimeGroups,
    /// PTQD sampler correction per time group (identity by default).
    pub correction: Vec<NoiseCorrection>,
}

impl QuantConfig {
    /// Full-precision passthrough (every slot bypassed).
    pub fn fp(groups: TimeGroups) -> QuantConfig {
        QuantConfig {
            method: "fp".into(),
            wbits: 32,
            abits: 32,
            sites: HashMap::new(),
            tgq: HashMap::new(),
            weights: HashMap::new(),
            groups: groups.clone(),
            correction: vec![NoiseCorrection::default(); groups.groups],
        }
    }

    pub fn new(method: &str, wbits: u32, abits: u32, groups: TimeGroups)
               -> QuantConfig {
        QuantConfig {
            method: method.into(),
            wbits,
            abits,
            sites: HashMap::new(),
            tgq: HashMap::new(),
            weights: HashMap::new(),
            groups: groups.clone(),
            correction: vec![NoiseCorrection::default(); groups.groups],
        }
    }

    /// Site params effective for time group `g`.
    pub fn site_for_group(&self, site: &str, g: usize) -> SiteParams {
        if let Some(per_group) = self.tgq.get(site) {
            return per_group[g.min(per_group.len() - 1)];
        }
        self.sites.get(site).copied().unwrap_or(SiteParams::Bypass)
    }

    /// Pack the flat qparams vector for time group `g`.
    pub fn qparams_for_group(&self, manifest: &Manifest, g: usize)
                             -> Vec<f32> {
        let mut v = vec![0.0f32; manifest.qp_len];
        for layer in &manifest.layers {
            for site in &layer.sites {
                let p = self.site_for_group(&site.name, g);
                p.encode(&mut v[site.qp_offset..site.qp_offset + QP_STRIDE]);
            }
        }
        v
    }

    /// All per-group qparams vectors (precomputed for the sampler).
    pub fn qparams_all_groups(&self, manifest: &Manifest) -> Vec<Vec<f32>> {
        (0..self.groups.groups)
            .map(|g| self.qparams_for_group(manifest, g))
            .collect()
    }

    /// Correction for the group containing training timestep `t`.
    pub fn correction_for_t(&self, t: usize) -> NoiseCorrection {
        let g = self.groups.group_of(t.min(self.groups.t_total - 1));
        self.correction[g.min(self.correction.len() - 1)]
    }

    /// True if any TGQ overlay differs across groups (sampler fast-path
    /// check: no overlay → one packed vector for the whole trajectory).
    pub fn has_tgq(&self) -> bool {
        !self.tgq.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::MrqSoftmax;

    fn groups() -> TimeGroups {
        TimeGroups::new(250, 10)
    }

    #[test]
    fn fp_config_is_all_bypass() {
        let c = QuantConfig::fp(groups());
        assert_eq!(c.site_for_group("anything", 3), SiteParams::Bypass);
        assert!(!c.has_tgq());
    }

    #[test]
    fn tgq_overlay_wins_over_base_site() {
        let mut c = QuantConfig::new("tq-dit", 8, 8, groups());
        c.sites.insert(
            "blk0.av.a".into(),
            SiteParams::MrqSoftmax(MrqSoftmax::new(0.9, 8)),
        );
        let per_group: Vec<SiteParams> = (0..10)
            .map(|g| {
                SiteParams::MrqSoftmax(MrqSoftmax::new(1e-3 * (g + 1) as f32, 8))
            })
            .collect();
        c.tgq.insert("blk0.av.a".into(), per_group);
        match c.site_for_group("blk0.av.a", 4) {
            SiteParams::MrqSoftmax(m) => {
                assert!((m.s1 - 5e-3).abs() < 1e-9)
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(c.has_tgq());
    }

    #[test]
    fn correction_defaults_are_identity() {
        let c = QuantConfig::new("ptqd", 8, 8, groups());
        let nc = c.correction_for_t(200);
        assert_eq!(nc.rho, 1.0);
        assert_eq!(nc.bias, 0.0);
        assert_eq!(nc.resid_var, 0.0);
    }
}
