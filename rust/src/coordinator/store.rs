//! Quantization configuration store + runtime qparams packing.
//!
//! A [`QuantConfig`] is the complete output of any calibrator: per-site
//! activation parameters (with per-time-group overlays for TGQ sites),
//! per-weight quantizers, and optional PTQD-style output correction.
//! `qparams_for_group` packs the flat f32 vector the `dit_quant`
//! artifact consumes; the sampler swaps vectors at group boundaries.
//!
//! [`QuantConfig::to_json`]/[`QuantConfig::from_json`] give the full
//! round-trip serde the persistent calibration cache
//! ([`crate::coordinator::cache`]) relies on: every qparam survives the
//! cycle bit-for-bit (f32 → f64 widening is exact and [`Json::dump`]
//! is shortest-roundtrip), and `from_json` validates structure —
//! finite numbers, known site kinds, a coherent time grouping, overlay
//! and correction lengths — returning typed errors (never panicking)
//! so a corrupt cache entry degrades into fresh calibration.

use std::collections::{BTreeMap, HashMap};

use anyhow::{bail, Context, Result};

use crate::quant::{MrqGelu, MrqSoftmax, SiteParams, UniformQ, QP_STRIDE};
use crate::runtime::Manifest;
use crate::sched::TimeGroups;
use crate::util::json::Json;

/// PTQD-style quantization-noise correction statistics (per time group).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NoiseCorrection {
    /// Correlated part: ε̂ ≈ ρ·ε_fp → divide by ρ.
    pub rho: f32,
    /// Mean residual bias to subtract.
    pub bias: f32,
    /// Residual (uncorrelated) variance to remove from σ².
    pub resid_var: f32,
}

impl Default for NoiseCorrection {
    fn default() -> Self {
        NoiseCorrection { rho: 1.0, bias: 0.0, resid_var: 0.0 }
    }
}

/// Complete quantization decision for one (method, bit-width) run.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantConfig {
    /// Human-readable calibrator name ("tq-dit", "q-diffusion", ...).
    pub method: String,
    pub wbits: u32,
    pub abits: u32,
    /// Activation params per site (group-independent sites).
    pub sites: HashMap<String, SiteParams>,
    /// TGQ overlays: site → per-group params (len = groups).
    pub tgq: HashMap<String, Vec<SiteParams>>,
    /// Weight quantizers by param name (host-side fake-quant).
    pub weights: HashMap<String, UniformQ>,
    /// Time grouping used for the TGQ overlays.
    pub groups: TimeGroups,
    /// PTQD sampler correction per time group (identity by default).
    pub correction: Vec<NoiseCorrection>,
    /// Per-group ε-drift recorded at calibration time (len = groups):
    /// how much ε̂ can move between adjacent sampler steps inside the
    /// group. Drives the sampler's step-reuse policy
    /// ([`crate::sampler::reuse`]); the default sentinel 1.0 means
    /// "never reuse".
    pub drift: Vec<f32>,
}

impl QuantConfig {
    /// Full-precision passthrough (every slot bypassed).
    pub fn fp(groups: TimeGroups) -> QuantConfig {
        QuantConfig {
            method: "fp".into(),
            wbits: 32,
            abits: 32,
            sites: HashMap::new(),
            tgq: HashMap::new(),
            weights: HashMap::new(),
            groups: groups.clone(),
            correction: vec![NoiseCorrection::default(); groups.groups],
            drift: vec![1.0; groups.groups],
        }
    }

    pub fn new(method: &str, wbits: u32, abits: u32, groups: TimeGroups)
               -> QuantConfig {
        QuantConfig {
            method: method.into(),
            wbits,
            abits,
            sites: HashMap::new(),
            tgq: HashMap::new(),
            weights: HashMap::new(),
            groups: groups.clone(),
            correction: vec![NoiseCorrection::default(); groups.groups],
            drift: vec![1.0; groups.groups],
        }
    }

    /// Site params effective for time group `g`.
    pub fn site_for_group(&self, site: &str, g: usize) -> SiteParams {
        if let Some(per_group) = self.tgq.get(site) {
            return per_group[g.min(per_group.len() - 1)];
        }
        self.sites.get(site).copied().unwrap_or(SiteParams::Bypass)
    }

    /// Pack the flat qparams vector for time group `g`.
    pub fn qparams_for_group(&self, manifest: &Manifest, g: usize)
                             -> Vec<f32> {
        let mut v = vec![0.0f32; manifest.qp_len];
        for layer in &manifest.layers {
            for site in &layer.sites {
                let p = self.site_for_group(&site.name, g);
                p.encode(&mut v[site.qp_offset..site.qp_offset + QP_STRIDE]);
            }
        }
        v
    }

    /// All per-group qparams vectors (precomputed for the sampler).
    pub fn qparams_all_groups(&self, manifest: &Manifest) -> Vec<Vec<f32>> {
        (0..self.groups.groups)
            .map(|g| self.qparams_for_group(manifest, g))
            .collect()
    }

    /// Correction for the group containing training timestep `t`.
    pub fn correction_for_t(&self, t: usize) -> NoiseCorrection {
        let g = self.groups.group_of(t.min(self.groups.t_total - 1));
        self.correction[g.min(self.correction.len() - 1)]
    }

    /// True if any TGQ overlay differs across groups (sampler fast-path
    /// check: no overlay → one packed vector for the whole trajectory).
    pub fn has_tgq(&self) -> bool {
        !self.tgq.is_empty()
    }

    // -- serde (persistent calibration cache) ----------------------------

    /// Serialize the complete config. Sorted-map output keeps the text
    /// canonical: equal configs dump to byte-identical JSON.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("method".into(), Json::Str(self.method.clone()));
        m.insert("wbits".into(), Json::Num(self.wbits as f64));
        m.insert("abits".into(), Json::Num(self.abits as f64));
        m.insert("groups".into(), time_groups_to_json(&self.groups));
        m.insert(
            "sites".into(),
            Json::Obj(
                self.sites
                    .iter()
                    .map(|(k, p)| (k.clone(), site_params_to_json(p)))
                    .collect(),
            ),
        );
        m.insert(
            "tgq".into(),
            Json::Obj(
                self.tgq
                    .iter()
                    .map(|(k, v)| {
                        (k.clone(),
                         Json::Arr(v.iter()
                             .map(site_params_to_json)
                             .collect()))
                    })
                    .collect(),
            ),
        );
        m.insert(
            "weights".into(),
            Json::Obj(
                self.weights
                    .iter()
                    .map(|(k, u)| (k.clone(), uniform_to_json(u)))
                    .collect(),
            ),
        );
        m.insert(
            "correction".into(),
            Json::Arr(self.correction
                .iter()
                .map(correction_to_json)
                .collect()),
        );
        m.insert(
            "drift".into(),
            Json::Arr(self.drift.iter().map(|&d| num(d)).collect()),
        );
        Json::Obj(m)
    }

    /// Parse a config serialized by [`Self::to_json`]. Validates every
    /// structural invariant the runtime later relies on; any violation
    /// is a typed error, never a panic.
    pub fn from_json(j: &Json) -> Result<QuantConfig> {
        let groups = time_groups_from_json(
            j.get("groups").context("missing `groups`")?,
        )?;
        let mut sites = HashMap::new();
        for (name, p) in obj_entries(j, "sites")? {
            sites.insert(
                name.clone(),
                site_params_from_json(p)
                    .with_context(|| format!("site `{name}`"))?,
            );
        }
        let mut tgq = HashMap::new();
        for (name, arr) in obj_entries(j, "tgq")? {
            let v = arr
                .as_arr()
                .with_context(|| format!("tgq `{name}`: expected array"))?
                .iter()
                .map(site_params_from_json)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("tgq `{name}`"))?;
            if v.len() != groups.groups {
                bail!("tgq `{name}`: {} overlay entries for {} groups",
                      v.len(), groups.groups);
            }
            tgq.insert(name.clone(), v);
        }
        let mut weights = HashMap::new();
        for (name, u) in obj_entries(j, "weights")? {
            weights.insert(
                name.clone(),
                uniform_from_json(u)
                    .with_context(|| format!("weight `{name}`"))?,
            );
        }
        let correction = j
            .get("correction")
            .and_then(Json::as_arr)
            .context("missing `correction` array")?
            .iter()
            .map(correction_from_json)
            .collect::<Result<Vec<_>>>()?;
        if correction.len() != groups.groups {
            bail!("correction length {} != groups {}", correction.len(),
                  groups.groups);
        }
        let drift = j
            .get("drift")
            .and_then(Json::as_arr)
            .context("missing `drift` array")?
            .iter()
            .enumerate()
            .map(|(i, v)| {
                let d = v
                    .as_f64()
                    .with_context(|| format!("drift[{i}]: expected a number"))?
                    as f32;
                if !d.is_finite() || d < 0.0 {
                    bail!("drift[{i}]: expected a finite non-negative \
                           value");
                }
                Ok(d)
            })
            .collect::<Result<Vec<f32>>>()?;
        if drift.len() != groups.groups {
            bail!("drift length {} != groups {}", drift.len(),
                  groups.groups);
        }
        Ok(QuantConfig {
            method: str_field(j, "method")?.to_string(),
            wbits: usize_field(j, "wbits")? as u32,
            abits: usize_field(j, "abits")? as u32,
            sites,
            tgq,
            weights,
            groups,
            correction,
            drift,
        })
    }
}

// -- serde helpers (shared by QuantConfig and the cache header) ----------

fn num(v: f32) -> Json {
    Json::Num(v as f64)
}

fn f32_field(j: &Json, key: &str) -> Result<f32> {
    let v = j
        .get(key)
        .with_context(|| format!("missing field `{key}`"))?
        .as_f64()
        .with_context(|| format!("field `{key}`: expected a number"))?;
    let narrowed = v as f32;
    // check finiteness *after* narrowing: a finite f64 like 1e39
    // overflows f32 to infinity
    if !narrowed.is_finite() {
        bail!("field `{key}`: non-finite value (read {v})");
    }
    Ok(narrowed)
}

pub(crate) fn usize_field(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .with_context(|| format!("missing field `{key}`"))?
        .as_exact_usize()
        .with_context(|| format!("field `{key}`: expected an integer"))
}

pub(crate) fn str_field<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key)
        .with_context(|| format!("missing field `{key}`"))?
        .as_str()
        .with_context(|| format!("field `{key}`: expected a string"))
}

fn obj_entries<'a>(j: &'a Json, key: &str)
                   -> Result<&'a BTreeMap<String, Json>> {
    match j.get(key) {
        Some(Json::Obj(m)) => Ok(m),
        Some(_) => bail!("field `{key}`: expected an object"),
        None => bail!("missing field `{key}`"),
    }
}

fn time_groups_to_json(tg: &TimeGroups) -> Json {
    let mut m = BTreeMap::new();
    m.insert("t_total".into(), Json::Num(tg.t_total as f64));
    m.insert("groups".into(), Json::Num(tg.groups as f64));
    Json::Obj(m)
}

fn time_groups_from_json(j: &Json) -> Result<TimeGroups> {
    let t_total = usize_field(j, "t_total")?;
    let groups = usize_field(j, "groups")?;
    // validate before TimeGroups::new — its assert must never fire on
    // untrusted cache bytes
    if groups < 1 || groups > t_total {
        bail!("invalid time grouping: G={groups}, T={t_total}");
    }
    Ok(TimeGroups::new(t_total, groups))
}

fn uniform_to_json(u: &UniformQ) -> Json {
    let mut m = BTreeMap::new();
    m.insert("s".into(), num(u.s));
    m.insert("z".into(), num(u.z));
    m.insert("levels".into(), num(u.levels));
    Json::Obj(m)
}

fn uniform_from_json(j: &Json) -> Result<UniformQ> {
    Ok(UniformQ {
        s: f32_field(j, "s")?,
        z: f32_field(j, "z")?,
        levels: f32_field(j, "levels")?,
    })
}

fn site_params_to_json(p: &SiteParams) -> Json {
    let mut m = BTreeMap::new();
    match p {
        SiteParams::Bypass => {
            m.insert("kind".into(), Json::Str("bypass".into()));
        }
        SiteParams::Uniform(u) => {
            m.insert("kind".into(), Json::Str("uniform".into()));
            m.insert("s".into(), num(u.s));
            m.insert("z".into(), num(u.z));
            m.insert("levels".into(), num(u.levels));
        }
        SiteParams::MrqSoftmax(q) => {
            m.insert("kind".into(), Json::Str("mrq_softmax".into()));
            m.insert("s1".into(), num(q.s1));
            m.insert("half".into(), num(q.half));
        }
        SiteParams::MrqGelu(q) => {
            m.insert("kind".into(), Json::Str("mrq_gelu".into()));
            m.insert("s1".into(), num(q.s1));
            m.insert("s2".into(), num(q.s2));
            m.insert("half".into(), num(q.half));
        }
    }
    Json::Obj(m)
}

fn site_params_from_json(j: &Json) -> Result<SiteParams> {
    Ok(match str_field(j, "kind")? {
        "bypass" => SiteParams::Bypass,
        "uniform" => SiteParams::Uniform(uniform_from_json(j)?),
        "mrq_softmax" => SiteParams::MrqSoftmax(MrqSoftmax {
            s1: f32_field(j, "s1")?,
            half: f32_field(j, "half")?,
        }),
        "mrq_gelu" => SiteParams::MrqGelu(MrqGelu {
            s1: f32_field(j, "s1")?,
            s2: f32_field(j, "s2")?,
            half: f32_field(j, "half")?,
        }),
        other => bail!("unknown site-params kind `{other}`"),
    })
}

fn correction_to_json(nc: &NoiseCorrection) -> Json {
    let mut m = BTreeMap::new();
    m.insert("rho".into(), num(nc.rho));
    m.insert("bias".into(), num(nc.bias));
    m.insert("resid_var".into(), num(nc.resid_var));
    Json::Obj(m)
}

fn correction_from_json(j: &Json) -> Result<NoiseCorrection> {
    Ok(NoiseCorrection {
        rho: f32_field(j, "rho")?,
        bias: f32_field(j, "bias")?,
        resid_var: f32_field(j, "resid_var")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::MrqSoftmax;

    fn groups() -> TimeGroups {
        TimeGroups::new(250, 10)
    }

    #[test]
    fn fp_config_is_all_bypass() {
        let c = QuantConfig::fp(groups());
        assert_eq!(c.site_for_group("anything", 3), SiteParams::Bypass);
        assert!(!c.has_tgq());
    }

    #[test]
    fn tgq_overlay_wins_over_base_site() {
        let mut c = QuantConfig::new("tq-dit", 8, 8, groups());
        c.sites.insert(
            "blk0.av.a".into(),
            SiteParams::MrqSoftmax(MrqSoftmax::new(0.9, 8)),
        );
        let per_group: Vec<SiteParams> = (0..10)
            .map(|g| {
                SiteParams::MrqSoftmax(MrqSoftmax::new(1e-3 * (g + 1) as f32, 8))
            })
            .collect();
        c.tgq.insert("blk0.av.a".into(), per_group);
        match c.site_for_group("blk0.av.a", 4) {
            SiteParams::MrqSoftmax(m) => {
                assert!((m.s1 - 5e-3).abs() < 1e-9)
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(c.has_tgq());
    }

    #[test]
    fn correction_defaults_are_identity() {
        let c = QuantConfig::new("ptqd", 8, 8, groups());
        let nc = c.correction_for_t(200);
        assert_eq!(nc.rho, 1.0);
        assert_eq!(nc.bias, 0.0);
        assert_eq!(nc.resid_var, 0.0);
    }

    // -- serde ----------------------------------------------------------

    fn random_site(g: &mut crate::util::check::Gen) -> SiteParams {
        match g.usize_in(0, 3) {
            0 => SiteParams::Bypass,
            1 => SiteParams::Uniform(UniformQ {
                s: g.f32_in(1e-5, 2.0),
                z: g.usize_in(0, 255) as f32,
                levels: 255.0,
            }),
            2 => SiteParams::MrqSoftmax(MrqSoftmax::new(
                g.f32_in(1e-6, 0.1), 8)),
            _ => SiteParams::MrqGelu(MrqGelu::new(
                g.f32_in(1e-5, 0.5), g.f32_in(1e-5, 0.5), 8)),
        }
    }

    /// Serialize → parse → identical qparams for every site/group.
    #[test]
    fn quant_config_serde_roundtrip_property() {
        crate::util::check::check("quant_config_serde_roundtrip", 40, |g| {
            let t_total = g.usize_in(10, 300);
            let n_groups = g.usize_in(1, t_total.min(12));
            let mut c = QuantConfig::new(
                "tq-dit", 8, 6, TimeGroups::new(t_total, n_groups));
            for i in 0..g.usize_in(0, 6) {
                c.sites.insert(format!("blk{i}.x"), random_site(g));
            }
            for i in 0..g.usize_in(0, 3) {
                let overlay: Vec<SiteParams> =
                    (0..n_groups).map(|_| random_site(g)).collect();
                c.tgq.insert(format!("blk{i}.av.a"), overlay);
            }
            for i in 0..g.usize_in(0, 4) {
                c.weights.insert(
                    format!("w{i}"),
                    UniformQ {
                        s: g.f32_in(1e-5, 1.0),
                        z: g.usize_in(0, 255) as f32,
                        levels: 255.0,
                    },
                );
            }
            for nc in c.correction.iter_mut() {
                nc.rho = g.f32_in(0.5, 1.5);
                nc.bias = g.f32_normal() * 1e-2;
                nc.resid_var = g.f32_in(0.0, 1e-2);
            }
            for d in c.drift.iter_mut() {
                *d = g.f32_in(0.0, 0.2);
            }
            let text = c.to_json().dump();
            let parsed = crate::util::json::Json::parse(&text)
                .map_err(|e| e.to_string())?;
            let back = QuantConfig::from_json(&parsed)
                .map_err(|e| format!("{e:#}"))?;
            if back != c {
                return Err(format!(
                    "roundtrip mismatch:\n  orig {c:?}\n  back {back:?}"
                ));
            }
            Ok(())
        });
    }

    fn reparse(c: &QuantConfig) -> Json {
        crate::util::json::Json::parse(&c.to_json().dump()).unwrap()
    }

    #[test]
    fn serde_rejects_corrupt_structures() {
        let mut c = QuantConfig::new("tq-dit", 8, 8, groups());
        c.sites.insert(
            "a".into(),
            SiteParams::MrqSoftmax(MrqSoftmax::new(0.01, 8)),
        );
        let good = reparse(&c);

        // baseline: the untampered dump parses
        assert!(QuantConfig::from_json(&good).is_ok());

        // non-finite qparam (serialized as null) is rejected, not read
        let mut bad = c.clone();
        if let Some(SiteParams::MrqSoftmax(m)) = bad.sites.get_mut("a") {
            m.s1 = f32::NAN;
        }
        let e = QuantConfig::from_json(&reparse(&bad)).unwrap_err();
        assert!(format!("{e:#}").contains("s1"), "{e:#}");

        // incoherent time grouping must not trip TimeGroups::new's assert
        let text = c.to_json().dump().replace(
            "\"groups\":{\"groups\":10,\"t_total\":250}",
            "\"groups\":{\"groups\":10,\"t_total\":3}",
        );
        let j = crate::util::json::Json::parse(&text).unwrap();
        let e = QuantConfig::from_json(&j).unwrap_err();
        assert!(format!("{e:#}").contains("grouping"), "{e:#}");

        // empty TGQ overlay would panic site_for_group later: reject now
        let mut bad = c.clone();
        bad.tgq.insert("a".into(), Vec::new());
        assert!(QuantConfig::from_json(&reparse(&bad)).is_err());

        // a truncated overlay would silently serve the wrong group's
        // qparams via the site_for_group clamp: reject at load time
        let mut bad = c.clone();
        bad.tgq.insert(
            "a".into(),
            vec![SiteParams::MrqSoftmax(MrqSoftmax::new(0.01, 8)); 3],
        );
        let e = QuantConfig::from_json(&reparse(&bad)).unwrap_err();
        assert!(format!("{e:#}").contains("overlay"), "{e:#}");

        // correction length must match the group count
        let mut bad = c.clone();
        bad.correction.pop();
        assert!(QuantConfig::from_json(&reparse(&bad)).is_err());

        // drift length must match the group count too — a short vector
        // would silently disable reuse for the tail groups
        let mut bad = c.clone();
        bad.drift.pop();
        let e = QuantConfig::from_json(&reparse(&bad)).unwrap_err();
        assert!(format!("{e:#}").contains("drift"), "{e:#}");

        // a negative or non-finite drift entry is rejected (it would
        // confuse the reuse policy's strict `drift < δ` comparison)
        let mut bad = c.clone();
        bad.drift[0] = -0.5;
        assert!(QuantConfig::from_json(&reparse(&bad)).is_err());

        // unknown site kind
        let text = c.to_json().dump().replace("mrq_softmax", "mystery");
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert!(QuantConfig::from_json(&j).is_err());

        // a finite f64 that overflows f32 must be rejected, not read
        // back as an infinite quantizer scale
        let j = crate::util::json::Json::parse(
            r#"{"kind":"uniform","s":1e39,"z":0,"levels":255}"#,
        )
        .unwrap();
        assert!(site_params_from_json(&j).is_err());

        // truncated text fails at the parser, not with a panic
        let text = c.to_json().dump();
        assert!(Json::parse(&text[..text.len() / 2]).is_err());
    }

    #[test]
    fn serde_dump_is_canonical() {
        let mut a = QuantConfig::new("tq-dit", 8, 8, groups());
        a.weights.insert("w.b".into(),
                         UniformQ { s: 0.5, z: 1.0, levels: 255.0 });
        a.weights.insert("w.a".into(),
                         UniformQ { s: 0.25, z: 0.0, levels: 255.0 });
        let b = a.clone();
        // HashMap iteration order may differ between equal configs; the
        // sorted dump must not
        assert_eq!(a.to_json().dump(), b.to_json().dump());
    }
}
