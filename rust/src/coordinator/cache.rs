//! Persistent calibration cache: content-addressed `QuantConfig`
//! storage so a server cold-start (or repeated CLI run) skips the full
//! MRQ/TGQ calibration pipeline when nothing that feeds it has changed.
//!
//! # Keying and staleness
//!
//! A cached entry is valid only for the exact calibration inputs that
//! produced it. The [`CacheKey`] therefore covers the *content* hash of
//! the artifacts (manifest + weights bytes — not paths or mtimes), the
//! method name, bit-widths, sampler steps T, time groups G, the
//! calibration-set sizing (n per group, rounds, candidate grid), the
//! ablation toggles and the calibration seed. The entry file name is a
//! 64-bit FNV-1a hash of the canonical (sorted-key) JSON encoding of
//! the key, prefixed with the format version — any input change, format
//! change, or artifact rebuild addresses a different file, so a stale
//! entry is simply never found.
//!
//! # Crash-proofness guarantees
//!
//! * **Atomic publish:** [`CalibCache::store`] writes to a
//!   process-unique temp file in the cache directory and `rename`s it
//!   into place. Readers see either the complete old entry, the
//!   complete new entry, or nothing — never a torn write, even if the
//!   process dies mid-store.
//! * **Load never panics and never lies:** [`CalibCache::load`]
//!   re-verifies the embedded format version and the *full* embedded
//!   key (defending against file-name hash collisions and
//!   hand-copied/renamed entries, including a wrong artifacts hash),
//!   then runs the strict [`QuantConfig::from_json`] validator.
//!   Corrupted, truncated, version-skewed or mismatched entries log a
//!   warning and return `None`; the caller falls back to fresh
//!   calibration. A config calibrated for different artifacts is never
//!   served.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::coordinator::store::{str_field, usize_field};
use crate::coordinator::QuantConfig;
use crate::runtime::Manifest;
use crate::util::config::RunConfig;
use crate::util::json::Json;

/// Bumped whenever the entry format or the semantics of any keyed
/// input change; older entries are ignored (and re-written on the next
/// calibration), never misread. v2: `QuantConfig` gained the per-group
/// `drift` statistics the sampler's step-reuse policy consumes.
pub const CACHE_VERSION: u32 = 2;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over `bytes`, continuing from `h` (seed with [`FNV_OFFSET`]).
fn fnv1a_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// 64-bit FNV-1a content hash.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_update(FNV_OFFSET, bytes)
}

/// Content hash of the calibration-relevant artifact files (manifest +
/// model weights). Errors only if a file vanished since the manifest
/// loaded; callers treat that as "cache unusable", not a failure.
pub fn artifacts_fingerprint(manifest: &Manifest) -> Result<u64> {
    let mut h = FNV_OFFSET;
    for file in ["manifest.json", manifest.weights_file.as_str()] {
        let path = manifest.dir.join(file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("hashing {}", path.display()))?;
        h = fnv1a_update(h, file.as_bytes());
        h = fnv1a_update(h, &bytes);
    }
    Ok(h)
}

/// Everything a calibration result is a pure function of.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CacheKey {
    /// Content hash of the artifacts (see [`artifacts_fingerprint`]).
    pub artifacts_hash: u64,
    pub method: String,
    pub wbits: u32,
    pub abits: u32,
    /// Sampler steps T (calibration tuples are drawn from the respaced
    /// step set).
    pub timesteps: usize,
    /// Time groups G.
    pub groups: usize,
    /// Calibration sizing: n per group, alternating rounds, candidate
    /// grid size.
    pub calib_per_group: usize,
    pub rounds: usize,
    pub candidates: usize,
    /// Ablation toggles (Table III) change the emitted config.
    pub use_ho: bool,
    pub use_mrq: bool,
    pub use_tgq: bool,
    /// Calibration RNG stream seed.
    pub seed: u64,
}

impl CacheKey {
    pub fn from_config(cfg: &RunConfig, method: &str, artifacts_hash: u64)
                       -> CacheKey {
        CacheKey {
            artifacts_hash,
            method: method.to_string(),
            wbits: cfg.wbits,
            abits: cfg.abits,
            timesteps: cfg.timesteps,
            groups: cfg.groups,
            calib_per_group: cfg.calib_per_group,
            rounds: cfg.rounds,
            candidates: cfg.candidates,
            use_ho: cfg.use_ho,
            use_mrq: cfg.use_mrq,
            use_tgq: cfg.use_tgq,
            seed: cfg.seed,
        }
    }

    /// Canonical JSON encoding (sorted keys). u64 fields are encoded as
    /// strings — JSON numbers are f64 and would lose bits above 2^53.
    fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("artifacts_hash".into(),
                 Json::Str(format!("{:016x}", self.artifacts_hash)));
        m.insert("method".into(), Json::Str(self.method.clone()));
        m.insert("wbits".into(), Json::Num(self.wbits as f64));
        m.insert("abits".into(), Json::Num(self.abits as f64));
        m.insert("timesteps".into(), Json::Num(self.timesteps as f64));
        m.insert("groups".into(), Json::Num(self.groups as f64));
        m.insert("calib_per_group".into(),
                 Json::Num(self.calib_per_group as f64));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("candidates".into(), Json::Num(self.candidates as f64));
        m.insert("use_ho".into(), Json::Bool(self.use_ho));
        m.insert("use_mrq".into(), Json::Bool(self.use_mrq));
        m.insert("use_tgq".into(), Json::Bool(self.use_tgq));
        m.insert("seed".into(), Json::Str(self.seed.to_string()));
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<CacheKey> {
        let hash_hex = str_field(j, "artifacts_hash")?;
        let artifacts_hash = u64::from_str_radix(hash_hex, 16)
            .with_context(|| format!("bad artifacts_hash `{hash_hex}`"))?;
        let seed_str = str_field(j, "seed")?;
        let seed = seed_str
            .parse::<u64>()
            .with_context(|| format!("bad seed `{seed_str}`"))?;
        let bool_field = |key: &str| -> Result<bool> {
            j.get(key)
                .with_context(|| format!("missing field `{key}`"))?
                .as_bool()
                .with_context(|| format!("field `{key}`: expected a bool"))
        };
        Ok(CacheKey {
            artifacts_hash,
            method: str_field(j, "method")?.to_string(),
            wbits: usize_field(j, "wbits")? as u32,
            abits: usize_field(j, "abits")? as u32,
            timesteps: usize_field(j, "timesteps")?,
            groups: usize_field(j, "groups")?,
            calib_per_group: usize_field(j, "calib_per_group")?,
            rounds: usize_field(j, "rounds")?,
            candidates: usize_field(j, "candidates")?,
            use_ho: bool_field("use_ho")?,
            use_mrq: bool_field("use_mrq")?,
            use_tgq: bool_field("use_tgq")?,
            seed,
        })
    }

    /// Content-addressed entry file name.
    pub fn file_name(&self) -> String {
        format!("calib-v{}-{:016x}.json", CACHE_VERSION,
                fnv1a(self.to_json().dump().as_bytes()))
    }
}

/// Handle to one on-disk cache directory.
#[derive(Clone, Debug)]
pub struct CalibCache {
    dir: PathBuf,
}

impl CalibCache {
    /// No I/O happens here; the directory is created lazily on the
    /// first [`Self::store`].
    pub fn new(dir: impl Into<PathBuf>) -> CalibCache {
        CalibCache { dir: dir.into() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Entry path for `key` (exists or not).
    pub fn path_for(&self, key: &CacheKey) -> PathBuf {
        self.dir.join(key.file_name())
    }

    /// Load the config cached for `key`. Any failure — missing entry,
    /// unreadable file, corrupt JSON, version or key mismatch, invalid
    /// config — returns `None` (logging the reason unless the entry
    /// simply doesn't exist), so callers always have the fresh-
    /// calibration fallback. Never panics.
    pub fn load(&self, key: &CacheKey) -> Option<QuantConfig> {
        let path = self.path_for(key);
        if !path.exists() {
            return None;
        }
        match load_entry(&path, key) {
            Ok(qc) => Some(qc),
            Err(e) => {
                crate::warn_log!(
                    "calib cache: ignoring {}: {e:#}; falling back to \
                     fresh calibration",
                    path.display()
                );
                None
            }
        }
    }

    /// Atomically persist `qc` under `key` (write temp + rename).
    pub fn store(&self, key: &CacheKey, qc: &QuantConfig) -> Result<()> {
        std::fs::create_dir_all(&self.dir).with_context(|| {
            format!("creating calib cache dir {}", self.dir.display())
        })?;
        let mut m = std::collections::BTreeMap::new();
        m.insert("version".into(), Json::Num(CACHE_VERSION as f64));
        m.insert("key".into(), key.to_json());
        m.insert("config".into(), qc.to_json());
        let text = Json::Obj(m).dump();
        let path = self.path_for(key);
        // pid + in-process sequence number: two threads (or processes)
        // storing the same key never share a temp file
        static TMP_SEQ: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(0);
        let tmp = self.dir.join(format!(
            "{}.tmp.{}.{}",
            key.file_name(),
            std::process::id(),
            TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        let publish = std::fs::write(&tmp, &text)
            .with_context(|| format!("writing {}", tmp.display()))
            .and_then(|()| {
                std::fs::rename(&tmp, &path).with_context(|| {
                    format!("publishing {}", path.display())
                })
            });
        if publish.is_err() {
            // clean up the orphan (failed write or failed rename) so
            // retries under disk pressure can't accumulate temp files
            let _ = std::fs::remove_file(&tmp);
        }
        publish
    }
}

fn load_entry(path: &Path, key: &CacheKey) -> Result<QuantConfig> {
    let text = std::fs::read_to_string(path).context("reading entry")?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("corrupt entry: {e}"))?;
    let version = usize_field(&j, "version")? as u32;
    if version != CACHE_VERSION {
        bail!("version {version} != supported {CACHE_VERSION}");
    }
    let stored = CacheKey::from_json(
        j.get("key").context("missing `key` header")?,
    )?;
    if stored != *key {
        // defends file-name collisions and copied/renamed entries; the
        // artifacts_hash arm is what makes a config calibrated against
        // different artifacts unservable
        bail!(
            "stale key: entry was calibrated for artifacts {:016x} \
             (method {}), requested {:016x} (method {})",
            stored.artifacts_hash, stored.method,
            key.artifacts_hash, key.method
        );
    }
    let qc = QuantConfig::from_json(
        j.get("config").context("missing `config`")?,
    )
    .context("invalid cached config")?;
    if qc.groups.groups != key.groups {
        bail!("cached config has G={}, key says G={}", qc.groups.groups,
              key.groups);
    }
    Ok(qc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{MrqSoftmax, SiteParams, UniformQ};
    use crate::sched::TimeGroups;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tqdit_calib_cache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn test_key(hash: u64) -> CacheKey {
        let cfg = RunConfig { groups: 5, timesteps: 25,
                              ..RunConfig::default() };
        CacheKey::from_config(&cfg, "tq-dit", hash)
    }

    fn test_config() -> QuantConfig {
        let mut c = QuantConfig::new("tq-dit", 8, 8,
                                     TimeGroups::new(25, 5));
        c.sites.insert(
            "blk0.x".into(),
            SiteParams::Uniform(UniformQ { s: 0.03, z: 4.0, levels: 255.0 }),
        );
        c.tgq.insert(
            "blk0.av.a".into(),
            (0..5)
                .map(|g| SiteParams::MrqSoftmax(
                    MrqSoftmax::new(1e-4 * (g + 1) as f32, 8)))
                .collect(),
        );
        c.weights.insert("w0".into(),
                         UniformQ { s: 0.01, z: 128.0, levels: 255.0 });
        c
    }

    #[test]
    fn store_then_load_roundtrips() {
        let dir = tmp_dir("roundtrip");
        let cache = CalibCache::new(&dir);
        let key = test_key(0xdead_beef);
        assert!(cache.load(&key).is_none(), "empty cache must miss");
        let qc = test_config();
        cache.store(&key, &qc).unwrap();
        assert_eq!(cache.load(&key), Some(qc));
        // no temp files left behind
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().path().to_string_lossy().contains(".tmp.")
            })
            .collect();
        assert!(stray.is_empty(), "{stray:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_entry_falls_back() {
        let dir = tmp_dir("corrupt");
        let cache = CalibCache::new(&dir);
        let key = test_key(1);
        cache.store(&key, &test_config()).unwrap();
        std::fs::write(cache.path_for(&key), b"{not json at all").unwrap();
        assert_eq!(cache.load(&key), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_entry_falls_back() {
        let dir = tmp_dir("trunc");
        let cache = CalibCache::new(&dir);
        let key = test_key(2);
        cache.store(&key, &test_config()).unwrap();
        let path = cache.path_for(&key);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        assert_eq!(cache.load(&key), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_mismatch_falls_back() {
        let dir = tmp_dir("version");
        let cache = CalibCache::new(&dir);
        let key = test_key(3);
        cache.store(&key, &test_config()).unwrap();
        let path = cache.path_for(&key);
        let text = std::fs::read_to_string(&path)
            .unwrap()
            .replace("\"version\":2", "\"version\":99");
        std::fs::write(&path, text).unwrap();
        assert_eq!(cache.load(&key), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wrong_artifacts_hash_falls_back() {
        let dir = tmp_dir("wronghash");
        let cache = CalibCache::new(&dir);
        let key_a = test_key(0xaaaa);
        let key_b = test_key(0xbbbb);
        cache.store(&key_a, &test_config()).unwrap();
        // different artifacts address a different file: clean miss
        assert_eq!(cache.load(&key_b), None);
        // even a hand-copied entry (simulating a file-name collision)
        // is rejected by the embedded-key check
        std::fs::copy(cache.path_for(&key_a), cache.path_for(&key_b))
            .unwrap();
        assert_eq!(cache.load(&key_b), None);
        assert!(cache.load(&key_a).is_some(), "original stays valid");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sizing_and_toggles_address_distinct_entries() {
        let base = test_key(7);
        for variant in [
            CacheKey { wbits: 6, ..base.clone() },
            CacheKey { timesteps: 100, ..base.clone() },
            CacheKey { groups: 10, ..base.clone() },
            CacheKey { calib_per_group: 64, ..base.clone() },
            CacheKey { use_tgq: false, ..base.clone() },
            CacheKey { seed: 1, ..base.clone() },
            CacheKey { method: "ptqd".into(), ..base.clone() },
        ] {
            assert_ne!(variant.file_name(), base.file_name(), "{variant:?}");
        }
    }

    #[test]
    fn key_json_roundtrips_u64_exactly() {
        let key = CacheKey { artifacts_hash: u64::MAX - 3,
                             seed: (1u64 << 60) + 7,
                             ..test_key(0) };
        let back = CacheKey::from_json(&Json::parse(
            &key.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, key);
    }

    #[test]
    fn missing_directory_is_a_clean_miss() {
        let cache = CalibCache::new("/nonexistent/tqdit/calib/cache");
        assert_eq!(cache.load(&test_key(9)), None);
    }
}
