//! The three comparison calibrators of Tables I/II (simplified
//! reimplementations on our substrate — DESIGN.md §1 documents the
//! fidelity of each substitution):
//!
//! * **Q-Diffusion** [25] — uniform PTQ with MSE-objective scale search
//!   over a timestep-spread calibration set; no Hessian weighting, no
//!   MRQ, no time grouping. (Also the "Baseline" row of Table III.)
//! * **PTQD** [22] — Q-Diffusion plus quantization-noise correction:
//!   the correlated part of the quantization error is divided out of
//!   ε̂ and the residual variance is removed from the sampler's σ², per
//!   time group.
//! * **PTQ4DiT** [16] — salience-weighted calibration in the style of
//!   its channel-salience redistribution, run over a much larger
//!   ungrouped calibration set with flat candidate grids: per-channel
//!   activation salience (abs-max) weights the objective so
//!   outlier-heavy channels dominate the scale choice. Its cost profile
//!   (large calib set, no coarse→fine, salience pre-pass) is what
//!   Table IV contrasts against TQ-DiT.

use anyhow::Result;

use crate::coordinator::calib::CalibSet;
use crate::coordinator::capture::Evidence;
use crate::coordinator::quantize::{quantize, QuantizeOpts, SearchCost};
use crate::coordinator::store::{NoiseCorrection, QuantConfig};
use crate::model::WeightStore;
use crate::quant::search::{argmin_candidates, uniform_candidates, Problem};
use crate::quant::{SiteParams, UniformQ};
use crate::runtime::{Manifest, Runtime};
use crate::sched::TimeGroups;
use crate::tensor::Tensor;

/// Q-Diffusion-style calibrator: uniform + MSE everywhere.
pub fn q_diffusion(manifest: &Manifest, weights: &WeightStore, ev: &Evidence,
                   groups: &TimeGroups, wbits: u32, abits: u32,
                   rounds: usize, candidates: usize)
                   -> Result<(QuantConfig, SearchCost)> {
    let opts = QuantizeOpts {
        wbits,
        abits,
        rounds,
        candidates,
        use_ho: false,
        use_mrq: false,
        use_tgq: false,
        coarse_fine: true,
        max_merged_mats: 24,
    };
    quantize(manifest, weights, ev, groups, "q-diffusion", opts)
}

/// PTQD: Q-Diffusion base + per-time-group noise-correction statistics
/// measured by comparing quantized vs FP ε̂ over the calibration set.
pub fn ptqd(rt: &Runtime, weights: &WeightStore, ev: &Evidence,
            calib: &CalibSet, groups: &TimeGroups, wbits: u32, abits: u32,
            rounds: usize, candidates: usize)
            -> Result<(QuantConfig, SearchCost)> {
    let manifest = &rt.manifest;
    let (mut qc, cost) = q_diffusion(manifest, weights, ev, groups, wbits,
                                     abits, rounds, candidates)?;
    qc.method = "ptqd".into();
    qc.correction = measure_correction(rt, weights, &qc, calib)?;
    Ok((qc, cost))
}

/// Estimate ε̂_q ≈ ρ·ε_fp + bias + η per time group over the calibration
/// set; the sampler divides the correlated part out and shrinks σ² by
/// var(η) (PTQD's correlated/uncorrelated decomposition).
pub fn measure_correction(rt: &Runtime, weights: &WeightStore,
                          qc: &QuantConfig, calib: &CalibSet)
                          -> Result<Vec<NoiseCorrection>> {
    let m = rt.manifest.clone();
    let bsz = m.batches.calib;
    let img = m.model.img_size;
    let ch = m.model.channels;
    let il = img * img * ch;

    let wq = weights.fakequant(&qc.weights);
    let fp_bufs = rt.upload_all(&weights.tensors)?;
    let q_bufs = rt.upload_all(&wq.tensors)?;

    // accumulators per group: Σ fp·q, Σ fp², Σ(q−fp), Σ(q−fp)², count
    let g_n = qc.groups.groups;
    let mut s_fq = vec![0.0f64; g_n];
    let mut s_ff = vec![0.0f64; g_n];
    let mut s_d = vec![0.0f64; g_n];
    let mut s_dd = vec![0.0f64; g_n];
    let mut cnt = vec![0.0f64; g_n];

    let n = calib.len();
    let mut start = 0usize;
    while start < n {
        let idx: Vec<usize> =
            (0..bsz).map(|i| (start + i).min(n - 1)).collect();
        let real = (n - start).min(bsz);
        let mut x = vec![0.0f32; bsz * il];
        let mut t = vec![0i32; bsz];
        let mut y = vec![0i32; bsz];
        for (bi, &ti) in idx.iter().enumerate() {
            let tup = &calib.tuples[ti];
            x[bi * il..(bi + 1) * il].copy_from_slice(&tup.x_t);
            t[bi] = tup.t as i32;
            y[bi] = tup.y;
        }
        let xt = Tensor::new(vec![bsz, img, img, ch], x);
        let xb = rt.upload(&xt)?;
        let tb = rt.upload_i32(&t, &[bsz])?;
        let yb = rt.upload_i32(&y, &[bsz])?;

        // FP reference
        let mut inputs: Vec<&xla::PjRtBuffer> = fp_bufs.iter().collect();
        inputs.extend([&xb, &tb, &yb]);
        let eps_fp = &rt.run_buffers("dit_fp_calib", &inputs)?[0];

        // quantized prediction — per-sample group decides the qparams;
        // batches are group-contiguous so use the first sample's group.
        let g0 = calib.tuples[idx[0]].group;
        let qp = Tensor::new(vec![m.qp_len],
                             qc.qparams_for_group(&m, g0));
        let qpb = rt.upload(&qp)?;
        let mut qinputs: Vec<&xla::PjRtBuffer> = q_bufs.iter().collect();
        qinputs.extend([&xb, &tb, &yb, &qpb]);
        let eps_q = &rt.run_buffers("dit_quant_calib", &qinputs)?[0];

        for (bi, &ti) in idx.iter().enumerate().take(real) {
            let g = calib.tuples[ti].group;
            let f = &eps_fp.data[bi * il..(bi + 1) * il];
            let q = &eps_q.data[bi * il..(bi + 1) * il];
            for i in 0..il {
                let (fv, qv) = (f[i] as f64, q[i] as f64);
                s_fq[g] += fv * qv;
                s_ff[g] += fv * fv;
                s_d[g] += qv - fv;
                s_dd[g] += (qv - fv) * (qv - fv);
            }
            cnt[g] += il as f64;
        }
        start += real;
    }

    Ok((0..g_n)
        .map(|g| {
            if cnt[g] < 1.0 || s_ff[g] < 1e-12 {
                return NoiseCorrection::default();
            }
            // ε_q ≈ ρ·ε_fp: ρ = Σ fq / Σ ff (least squares through 0)
            let rho = (s_fq[g] / s_ff[g]).clamp(0.25, 4.0) as f32;
            let bias = (s_d[g] / cnt[g]) as f32;
            let var_d = (s_dd[g] / cnt[g] - (s_d[g] / cnt[g]).powi(2))
                .max(0.0);
            // residual variance after removing the correlated part:
            // var(q − ρf − b) = var(d) − (ρ−1)²·var(f) approximated by
            // the directly-measured var(d) shrunk by the correlation.
            let resid_var = (var_d
                - ((rho - 1.0) as f64).powi(2) * s_ff[g] / cnt[g])
                .max(0.0) as f32;
            NoiseCorrection { rho, bias, resid_var }
        })
        .collect())
}

/// PTQ4DiT-style calibrator: salience-weighted objective over a large
/// ungrouped evidence pool, flat candidate grids.
pub fn ptq4dit(manifest: &Manifest, weights: &WeightStore, ev: &Evidence,
               groups: &TimeGroups, wbits: u32, abits: u32, rounds: usize,
               candidates: usize) -> Result<(QuantConfig, SearchCost)> {
    let mut qc = QuantConfig::new("ptq4dit", wbits, abits, groups.clone());
    let mut cost = SearchCost::default();

    for layer in &manifest.layers {
        let le = ev.layer(&layer.name);
        cost.layers += 1;
        // salience pre-pass: per-channel abs-max of A over ALL evidence,
        // expanded to output weights via the layer's weight/operand —
        // simplified to per-output-row activation salience.
        let salience = channel_salience(le);

        if layer.ltype == "linear" {
            let w = weights.get(&layer.weight).unwrap();
            let prob = salient_problem(le, Some(w), &salience);
            let (wmn, wmx) = (w.min(), w.max());
            let (xmn, xmx) = prob.a_minmax();
            let mut qw = SiteParams::Uniform(UniformQ::from_minmax(
                wmn, wmx, wbits));
            let mut qx = SiteParams::Uniform(UniformQ::from_minmax(
                xmn, xmx, abits));
            for _ in 0..rounds {
                cost.evals += candidates as u64 * 2;
                qw = argmin_candidates(
                    &uniform_candidates(wmn, wmx, wbits, candidates),
                    |c| prob.eval(&qx, c),
                ).0;
                qx = argmin_candidates(
                    &uniform_candidates(xmn, xmx, abits, candidates),
                    |c| prob.eval(c, &qw),
                ).0;
            }
            if let SiteParams::Uniform(u) = qw {
                qc.weights.insert(layer.weight.clone(), u);
            }
            qc.sites.insert(layer.sites[0].name.clone(), qx);
        } else {
            let prob = salient_problem(le, None, &salience);
            let (amn, amx) = prob.a_minmax();
            let (bmn, bmx) = prob.b_minmax();
            let mut qa = SiteParams::Uniform(UniformQ::from_minmax(
                amn, amx, abits));
            let mut qb = SiteParams::Uniform(UniformQ::from_minmax(
                bmn, bmx, abits));
            for _ in 0..rounds {
                cost.evals += candidates as u64 * 2;
                qa = argmin_candidates(
                    &uniform_candidates(amn, amx, abits, candidates),
                    |c| prob.eval(c, &qb),
                ).0;
                qb = argmin_candidates(
                    &uniform_candidates(bmn, bmx, abits, candidates),
                    |c| prob.eval(&qa, c),
                ).0;
            }
            qc.sites.insert(layer.sites[0].name.clone(), qa);
            qc.sites.insert(layer.sites[1].name.clone(), qb);
        }
    }
    Ok((qc, cost))
}

/// Per-channel (last-axis) abs-max of the A operands — the salience
/// signal PTQ4DiT redistributes by.
fn channel_salience(le: &crate::coordinator::capture::LayerEvidence)
                    -> Vec<f32> {
    let mut sal: Vec<f32> = Vec::new();
    for g in &le.a {
        for t in g {
            let k = t.cols();
            if sal.len() != k {
                sal = vec![0.0; k];
            }
            for row in t.data.chunks(k) {
                for (s, &v) in sal.iter_mut().zip(row) {
                    *s = s.max(v.abs());
                }
            }
        }
    }
    sal
}

/// Build a Problem whose fisher weights encode activation salience
/// (outlier channels dominate), PTQ4DiT-style, over ALL groups.
fn salient_problem(le: &crate::coordinator::capture::LayerEvidence,
                   weight: Option<&Tensor>, salience: &[f32]) -> Problem {
    let mut a = Vec::new();
    let mut b = Vec::new();
    let mut f = Vec::new();
    let smax = salience.iter().fold(1e-8f32, |m, &v| m.max(v));
    for g in 0..le.a.len() {
        for (i, am) in le.a[g].iter().enumerate() {
            let bm = match weight {
                Some(w) => w.clone(),
                None => le.b[g][i].clone(),
            };
            // output weight = mean input salience (uniform across outputs)
            let rows = am.rows();
            let cols = bm.cols();
            let w_val = salience.iter().sum::<f32>()
                / (salience.len().max(1) as f32)
                / smax
                + 1.0;
            f.push(Tensor::full(vec![rows, cols], w_val));
            a.push(am.clone());
            b.push(bm);
        }
    }
    Problem::new(a, b, Some(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::capture::LayerEvidence;
    use crate::util::rng::Rng;

    fn evidence() -> LayerEvidence {
        let mut rng = Rng::new(11);
        let mut le = LayerEvidence::new("matmul", 2);
        for g in 0..2 {
            for _ in 0..2 {
                le.a[g].push(Tensor::new(vec![8, 4], rng.normal_vec(32)));
                le.b[g].push(Tensor::new(vec![4, 4], rng.normal_vec(16)));
                le.fisher[g].push(Tensor::new(vec![8, 4],
                                              rng.normal_vec(32)));
            }
        }
        le
    }

    #[test]
    fn salience_tracks_channel_magnitude() {
        let mut le = LayerEvidence::new("matmul", 1);
        let mut data = vec![0.1f32; 8];
        data[3] = 9.0; // channel 3 of a (2,4) matrix
        data[7] = -9.5;
        le.a[0].push(Tensor::new(vec![2, 4], data));
        let s = channel_salience(&le);
        assert_eq!(s.len(), 4);
        assert!(s[3] > 9.0 && s[3] <= 9.5);
        assert!(s[0] < 1.0);
    }

    #[test]
    fn salient_problem_has_uniform_positive_fisher() {
        let le = evidence();
        let sal = channel_salience(&le);
        let p = salient_problem(&le, None, &sal);
        assert_eq!(p.a.len(), 4);
        let f = p.fisher.as_ref().unwrap();
        assert!(f.iter().all(|t| t.data.iter().all(|&v| v > 0.0)));
    }

    #[test]
    fn correction_defaults_on_empty_stats() {
        // the per-group estimator falls back to identity when unseen
        let nc = NoiseCorrection::default();
        assert_eq!(nc.rho, 1.0);
    }
}
