//! L3 coordinator — the paper's system contribution.
//!
//! Algorithm 1 (Time-Aware Quantization) split into its three phases:
//!
//! * [`calib`]    — Phase 1: calibration-set construction with time
//!   grouping (eq. 9/10).
//! * [`capture`]  — Phase 2: layer-wise forward/backward over the
//!   calibration set via the `dit_capture` artifact; streams per-layer
//!   evidence (inputs + Fisher diagonals) into bounded reservoirs.
//! * [`quantize`] — Phase 3: time-aware quantization — alternating
//!   HO rounds for linear/matmul layers, MRQ for post-GELU /
//!   post-softmax, TGQ for the post-softmax sites (eq. 12–17).
//!
//! [`baselines`] re-implements the three comparison calibrators
//! (Q-Diffusion, PTQD, PTQ4DiT — simplified per DESIGN.md §1);
//! [`store`] holds the resulting [`store::QuantConfig`] and packs the
//! runtime qparams vectors; [`cache`] persists calibrated configs to
//! disk (content-addressed by artifacts + settings) so cold starts
//! skip Phases 1–3 entirely; [`pipeline`] wires everything into the
//! calibrate→quantize→sample→evaluate flows the tables use.

pub mod baselines;
pub mod cache;
pub mod calib;
pub mod capture;
pub mod pipeline;
pub mod quantize;
pub mod report;
pub mod store;

pub use cache::{CacheKey, CalibCache};
pub use store::QuantConfig;
