//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.

pub mod artifacts;
pub mod client;

pub use artifacts::{LayerMeta, Manifest, SiteKind, SiteMeta};
pub use client::Runtime;
