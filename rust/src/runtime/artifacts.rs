//! Typed view of `artifacts/manifest.json` — the contract between the
//! python build path and the rust request path.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Model hyperparameters (mirror of `python/compile/config.ModelConfig`).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub img_size: usize,
    pub channels: usize,
    pub patch: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub num_classes: usize,
    pub mlp_ratio: usize,
    pub freq_dim: usize,
    pub tokens: usize,
    pub head_dim: usize,
    pub patch_dim: usize,
}

/// Diffusion-schedule hyperparameters baked at training time.
#[derive(Clone, Debug)]
pub struct DiffusionMeta {
    pub train_steps: usize,
    pub beta_start: f64,
    pub beta_end: f64,
}

/// Kind of a quantization site (see DESIGN.md §4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteKind {
    Uniform,
    MrqSoftmax,
    MrqGelu,
}

/// One activation quantization site.
#[derive(Clone, Debug)]
pub struct SiteMeta {
    pub name: String,
    pub kind: SiteKind,
    pub tgq: bool,
    pub qp_offset: usize,
}

/// One quantizable layer (linear or matmul).
#[derive(Clone, Debug)]
pub struct LayerMeta {
    pub name: String,
    /// "linear" | "matmul"
    pub ltype: String,
    /// Weight param name (linear layers only, else empty).
    pub weight: String,
    pub sites: Vec<SiteMeta>,
}

/// Fixed batch sizes the artifacts were lowered with.
#[derive(Clone, Copy, Debug)]
pub struct Batches {
    pub calib: usize,
    pub sample: usize,
    pub train: usize,
    pub feat: usize,
}

/// Parsed manifest + artifact directory handle.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub diffusion: DiffusionMeta,
    /// (name, shape) in the canonical flat parameter order.
    pub params: Vec<(String, Vec<usize>)>,
    pub layers: Vec<LayerMeta>,
    pub qp_len: usize,
    pub batches: Batches,
    /// (name, shape) of `dit_capture` outputs after eps_pred.
    pub capture_outputs: Vec<(String, Vec<usize>)>,
    pub feat_dim: usize,
    pub spat_dim: usize,
    pub classifier_acc: f64,
    /// (name, shape) of the FID/sFID feature-net parameters, in the
    /// order they appear in `metric_weights.bin`.
    pub feat_params: Vec<(String, Vec<usize>)>,
    /// (name, shape) of the IS-classifier parameters (after feat's).
    pub clf_params: Vec<(String, Vec<usize>)>,
    /// Logical artifact name → file name.
    pub artifacts: BTreeMap<String, String>,
    pub weights_file: String,
    pub metric_weights_file: String,
    pub fid_ref_file: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing manifest: {e}"))?;

        let m = j.req("model");
        let model = ModelMeta {
            img_size: m.req("img_size").as_usize().unwrap(),
            channels: m.req("channels").as_usize().unwrap(),
            patch: m.req("patch").as_usize().unwrap(),
            dim: m.req("dim").as_usize().unwrap(),
            depth: m.req("depth").as_usize().unwrap(),
            heads: m.req("heads").as_usize().unwrap(),
            num_classes: m.req("num_classes").as_usize().unwrap(),
            mlp_ratio: m.req("mlp_ratio").as_usize().unwrap(),
            freq_dim: m.req("freq_dim").as_usize().unwrap(),
            tokens: m.req("tokens").as_usize().unwrap(),
            head_dim: m.req("head_dim").as_usize().unwrap(),
            patch_dim: m.req("patch_dim").as_usize().unwrap(),
        };
        let d = j.req("diffusion");
        let diffusion = DiffusionMeta {
            train_steps: d.req("train_steps").as_usize().unwrap(),
            beta_start: d.req("beta_start").as_f64().unwrap(),
            beta_end: d.req("beta_end").as_f64().unwrap(),
        };

        let params = j
            .req("params")
            .as_arr()
            .context("params array")?
            .iter()
            .map(|p| {
                Ok((
                    p.req("name").as_str().unwrap().to_string(),
                    p.req("shape").as_shape().context("param shape")?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;

        let layers = j
            .req("layers")
            .as_arr()
            .context("layers array")?
            .iter()
            .map(parse_layer)
            .collect::<Result<Vec<_>>>()?;

        let b = j.req("batches");
        let batches = Batches {
            calib: b.req("calib").as_usize().unwrap(),
            sample: b.req("sample").as_usize().unwrap(),
            train: b.req("train").as_usize().unwrap(),
            feat: b.req("feat").as_usize().unwrap(),
        };

        let capture_outputs = j
            .req("capture_outputs")
            .as_arr()
            .context("capture_outputs")?
            .iter()
            .map(|c| {
                Ok((
                    c.req("name").as_str().unwrap().to_string(),
                    c.req("shape").as_shape().context("capture shape")?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;

        let mut artifacts = BTreeMap::new();
        if let Json::Obj(map) = j.req("artifacts") {
            for (k, v) in map {
                artifacts.insert(
                    k.clone(),
                    v.as_str().context("artifact path")?.to_string(),
                );
            }
        } else {
            bail!("artifacts must be an object");
        }

        let parse_specs = |node: &Json| -> Result<Vec<(String, Vec<usize>)>> {
            node.as_arr()
                .context("metric param array")?
                .iter()
                .map(|p| {
                    Ok((
                        p.req("name").as_str().unwrap().to_string(),
                        p.req("shape").as_shape().context("param shape")?,
                    ))
                })
                .collect()
        };
        let mp = j.req("metric_params");
        let feat_params = parse_specs(mp.req("feature"))?;
        let clf_params = parse_specs(mp.req("classifier"))?;

        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            diffusion,
            params,
            layers,
            qp_len: j.req("qp_len").as_usize().unwrap(),
            batches,
            capture_outputs,
            feat_dim: j.req("feat_dim").as_usize().unwrap(),
            spat_dim: j.req("spat_dim").as_usize().unwrap(),
            classifier_acc: j.req("classifier_acc").as_f64().unwrap_or(0.0),
            feat_params,
            clf_params,
            artifacts,
            weights_file: j.req("weights").as_str().unwrap().to_string(),
            metric_weights_file: j
                .req("metric_weights")
                .as_str()
                .unwrap()
                .to_string(),
            fid_ref_file: j.req("fid_ref").as_str().unwrap().to_string(),
        })
    }

    /// Load `metric_weights.bin`: (feature-net tensors, classifier
    /// tensors) in canonical order.
    pub fn load_metric_weights(&self)
                               -> Result<(Vec<crate::tensor::Tensor>,
                                          Vec<crate::tensor::Tensor>)> {
        let path = self.dir.join(&self.metric_weights_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let expected: usize = self
            .feat_params
            .iter()
            .chain(&self.clf_params)
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        if bytes.len() != expected * 4 {
            bail!("metric_weights.bin: {} bytes, expected {}", bytes.len(),
                  expected * 4);
        }
        let mut off = 0usize;
        let mut take = |shape: &Vec<usize>| {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = bytes[off..off + n * 4]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            off += n * 4;
            crate::tensor::Tensor::new(shape.clone(), data)
        };
        let feat = self.feat_params.iter().map(|(_, s)| take(s)).collect();
        let clf = self.clf_params.iter().map(|(_, s)| take(s)).collect();
        Ok((feat, clf))
    }

    /// Absolute path of a logical artifact.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let file = self
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact `{name}`"))?;
        Ok(self.dir.join(file))
    }

    /// Number of flat parameters.
    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Index of the capture output `name` (position AFTER eps_pred).
    pub fn capture_index(&self, name: &str) -> Option<usize> {
        self.capture_outputs.iter().position(|(n, _)| n == name)
    }

    /// Look up a layer by name.
    pub fn layer(&self, name: &str) -> Option<&LayerMeta> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// All sites flattened in qp-offset order.
    pub fn sites(&self) -> Vec<&SiteMeta> {
        let mut s: Vec<&SiteMeta> =
            self.layers.iter().flat_map(|l| l.sites.iter()).collect();
        s.sort_by_key(|x| x.qp_offset);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TOY: &str = r#"{
      "model": {"img_size": 8, "channels": 3, "patch": 2, "dim": 8,
                "depth": 1, "heads": 2, "num_classes": 4, "mlp_ratio": 2,
                "freq_dim": 8, "tokens": 16, "head_dim": 4,
                "patch_dim": 12},
      "diffusion": {"train_steps": 50, "beta_start": 0.0001,
                    "beta_end": 0.02},
      "params": [{"name": "w", "shape": [2, 3]},
                 {"name": "b", "shape": [3]}],
      "layers": [
        {"name": "l0", "ltype": "linear", "weight": "w",
         "sites": [{"name": "l0.x", "kind": "uniform", "tgq": false,
                    "qp_offset": 0}]},
        {"name": "m0", "ltype": "matmul", "weight": "",
         "sites": [{"name": "m0.a", "kind": "mrq_softmax", "tgq": true,
                    "qp_offset": 4},
                   {"name": "m0.b", "kind": "uniform", "tgq": false,
                    "qp_offset": 8}]}
      ],
      "qp_len": 12,
      "batches": {"calib": 2, "sample": 4, "train": 8, "feat": 16},
      "capture_outputs": [{"name": "l0.x", "shape": [2, 5]},
                          {"name": "l0.grad", "shape": [2, 3]}],
      "feat_dim": 7,
      "spat_dim": 9,
      "classifier_acc": 0.875,
      "metric_params": {
        "feature": [{"name": "c1", "shape": [3, 3, 3, 4]}],
        "classifier": [{"name": "d", "shape": [4, 2]}]
      },
      "metric_weights": "metric_weights.bin",
      "artifacts": {"dit_fp_sample": "dit_fp_sample.hlo.txt"},
      "weights": "weights.bin",
      "fid_ref": "fid_ref.bin"
    }"#;

    fn write_toy() -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tqdit_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), TOY).unwrap();
        dir
    }

    #[test]
    fn parses_toy_manifest_end_to_end() {
        let dir = write_toy();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.dim, 8);
        assert_eq!(m.diffusion.train_steps, 50);
        assert_eq!(m.params, vec![("w".to_string(), vec![2, 3]),
                                  ("b".to_string(), vec![3])]);
        assert_eq!(m.qp_len, 12);
        assert_eq!(m.batches.feat, 16);
        assert_eq!(m.feat_params.len(), 1);
        assert_eq!(m.clf_params[0].1, vec![4, 2]);
        assert!((m.classifier_acc - 0.875).abs() < 1e-12);
        // site parsing
        let sites = m.sites();
        assert_eq!(sites.len(), 3);
        assert_eq!(sites[1].kind, SiteKind::MrqSoftmax);
        assert!(sites[1].tgq);
        // lookups
        assert!(m.layer("m0").is_some());
        assert_eq!(m.capture_index("l0.grad"), Some(1));
        assert!(m.artifact_path("dit_fp_sample").unwrap()
            .ends_with("dit_fp_sample.hlo.txt"));
        assert!(m.artifact_path("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_metric_weights_checks_size() {
        let dir = write_toy();
        let m = Manifest::load(&dir).unwrap();
        // expected: 3*3*3*4 + 4*2 = 116 f32 = 464 bytes
        std::fs::write(dir.join("metric_weights.bin"), vec![0u8; 464])
            .unwrap();
        let (f, c) = m.load_metric_weights().unwrap();
        assert_eq!(f[0].shape, vec![3, 3, 3, 4]);
        assert_eq!(c[0].shape, vec![4, 2]);
        std::fs::write(dir.join("metric_weights.bin"), vec![0u8; 100])
            .unwrap();
        assert!(m.load_metric_weights().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_unknown_site_kind() {
        let dir = std::env::temp_dir()
            .join(format!("tqdit_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"),
                       TOY.replace("mrq_softmax", "mystery")).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

fn parse_layer(l: &Json) -> Result<LayerMeta> {
    let sites = l
        .req("sites")
        .as_arr()
        .context("sites")?
        .iter()
        .map(|s| {
            let kind = match s.req("kind").as_str().unwrap() {
                "uniform" => SiteKind::Uniform,
                "mrq_softmax" => SiteKind::MrqSoftmax,
                "mrq_gelu" => SiteKind::MrqGelu,
                other => bail!("unknown site kind `{other}`"),
            };
            Ok(SiteMeta {
                name: s.req("name").as_str().unwrap().to_string(),
                kind,
                tgq: s.req("tgq").as_bool().unwrap_or(false),
                qp_offset: s.req("qp_offset").as_usize().unwrap(),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(LayerMeta {
        name: l.req("name").as_str().unwrap().to_string(),
        ltype: l.req("ltype").as_str().unwrap().to_string(),
        weight: l
            .req("weight")
            .as_str()
            .unwrap_or_default()
            .to_string(),
        sites,
    })
}
