//! Typed view of `artifacts/manifest.json` — the contract between the
//! python build path and the rust request path.
//!
//! Parsing is fully fallible: a malformed or truncated manifest yields
//! an error naming the offending key (with its JSON path) and the file,
//! never a panic — the serve layer turns these into typed
//! `WorkerInitFailed` causes instead of dead worker threads.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Model hyperparameters (mirror of `python/compile/config.ModelConfig`).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub img_size: usize,
    pub channels: usize,
    pub patch: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub num_classes: usize,
    pub mlp_ratio: usize,
    pub freq_dim: usize,
    pub tokens: usize,
    pub head_dim: usize,
    pub patch_dim: usize,
}

/// Diffusion-schedule hyperparameters baked at training time.
#[derive(Clone, Debug)]
pub struct DiffusionMeta {
    pub train_steps: usize,
    pub beta_start: f64,
    pub beta_end: f64,
}

/// Kind of a quantization site (see DESIGN.md §4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteKind {
    Uniform,
    MrqSoftmax,
    MrqGelu,
}

/// One activation quantization site.
#[derive(Clone, Debug)]
pub struct SiteMeta {
    pub name: String,
    pub kind: SiteKind,
    pub tgq: bool,
    pub qp_offset: usize,
}

/// One quantizable layer (linear or matmul).
#[derive(Clone, Debug)]
pub struct LayerMeta {
    pub name: String,
    /// "linear" | "matmul"
    pub ltype: String,
    /// Weight param name (linear layers only, else empty).
    pub weight: String,
    pub sites: Vec<SiteMeta>,
}

/// Fixed batch sizes the artifacts were lowered with.
#[derive(Clone, Debug)]
pub struct Batches {
    pub calib: usize,
    /// Batch ladder for the sampling graphs: every batch dim the
    /// sample/quant artifacts were lowered at, sorted ascending and
    /// deduped. A scalar `batches.sample` (the pre-ladder manifest
    /// format) parses as a one-rung ladder.
    pub sample: Vec<usize>,
    pub train: usize,
    pub feat: usize,
}

impl Batches {
    /// Largest lowered sampling batch — the classic single batch dim,
    /// and the rung the unsuffixed sample artifacts are lowered at.
    pub fn sample_max(&self) -> usize {
        // manifest parsing rejects an empty sample ladder; the
        // fallback only keeps this panic-free
        self.sample.last().copied().unwrap_or(1)
    }
}

/// Parsed manifest + artifact directory handle.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub diffusion: DiffusionMeta,
    /// (name, shape) in the canonical flat parameter order.
    pub params: Vec<(String, Vec<usize>)>,
    pub layers: Vec<LayerMeta>,
    pub qp_len: usize,
    pub batches: Batches,
    /// (name, shape) of `dit_capture` outputs after eps_pred.
    pub capture_outputs: Vec<(String, Vec<usize>)>,
    pub feat_dim: usize,
    pub spat_dim: usize,
    pub classifier_acc: f64,
    /// (name, shape) of the FID/sFID feature-net parameters, in the
    /// order they appear in `metric_weights.bin`.
    pub feat_params: Vec<(String, Vec<usize>)>,
    /// (name, shape) of the IS-classifier parameters (after feat's).
    pub clf_params: Vec<(String, Vec<usize>)>,
    /// Logical artifact name → file name.
    pub artifacts: BTreeMap<String, String>,
    pub weights_file: String,
    pub metric_weights_file: String,
    pub fid_ref_file: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing manifest: {e}"))
            .and_then(|j| Manifest::parse_json(&j, dir))
            .with_context(|| {
                format!("malformed manifest {}", path.display())
            })
    }

    /// Parse an already-loaded manifest document. Every missing or
    /// mis-typed field errors with its dotted key path.
    fn parse_json(j: &Json, dir: &Path) -> Result<Manifest> {
        let m = req(j, "", "model")?;
        let model = ModelMeta {
            img_size: req_usize(m, "model.", "img_size")?,
            channels: req_usize(m, "model.", "channels")?,
            patch: req_usize(m, "model.", "patch")?,
            dim: req_usize(m, "model.", "dim")?,
            depth: req_usize(m, "model.", "depth")?,
            heads: req_usize(m, "model.", "heads")?,
            num_classes: req_usize(m, "model.", "num_classes")?,
            mlp_ratio: req_usize(m, "model.", "mlp_ratio")?,
            freq_dim: req_usize(m, "model.", "freq_dim")?,
            tokens: req_usize(m, "model.", "tokens")?,
            head_dim: req_usize(m, "model.", "head_dim")?,
            patch_dim: req_usize(m, "model.", "patch_dim")?,
        };
        let d = req(j, "", "diffusion")?;
        let diffusion = DiffusionMeta {
            train_steps: req_usize(d, "diffusion.", "train_steps")?,
            beta_start: req_f64(d, "diffusion.", "beta_start")?,
            beta_end: req_f64(d, "diffusion.", "beta_end")?,
        };

        let params = parse_specs(req(j, "", "params")?, "params")?;

        let layers = req(j, "", "layers")?
            .as_arr()
            .context("key `layers`: expected an array")?
            .iter()
            .enumerate()
            .map(|(i, l)| parse_layer(l, i))
            .collect::<Result<Vec<_>>>()?;

        let b = req(j, "", "batches")?;
        let batches = Batches {
            calib: req_usize(b, "batches.", "calib")?,
            sample: parse_ladder(req(b, "batches.", "sample")?,
                                 "batches.sample")?,
            train: req_usize(b, "batches.", "train")?,
            feat: req_usize(b, "batches.", "feat")?,
        };

        let capture_outputs =
            parse_specs(req(j, "", "capture_outputs")?, "capture_outputs")?;

        let mut artifacts = BTreeMap::new();
        if let Json::Obj(map) = req(j, "", "artifacts")? {
            for (k, v) in map {
                artifacts.insert(
                    k.clone(),
                    v.as_str()
                        .with_context(|| {
                            format!("key `artifacts.{k}`: expected a string")
                        })?
                        .to_string(),
                );
            }
        } else {
            bail!("key `artifacts`: expected an object");
        }

        let mp = req(j, "", "metric_params")?;
        let feat_params = parse_specs(req(mp, "metric_params.", "feature")?,
                                      "metric_params.feature")?;
        let clf_params = parse_specs(
            req(mp, "metric_params.", "classifier")?,
            "metric_params.classifier",
        )?;

        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            diffusion,
            params,
            layers,
            qp_len: req_usize(j, "", "qp_len")?,
            batches,
            capture_outputs,
            feat_dim: req_usize(j, "", "feat_dim")?,
            spat_dim: req_usize(j, "", "spat_dim")?,
            // optional: older builds predate the classifier report
            classifier_acc: j
                .get("classifier_acc")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            feat_params,
            clf_params,
            artifacts,
            weights_file: req_str(j, "", "weights")?.to_string(),
            metric_weights_file: req_str(j, "", "metric_weights")?
                .to_string(),
            fid_ref_file: req_str(j, "", "fid_ref")?.to_string(),
        })
    }

    /// Load `metric_weights.bin`: (feature-net tensors, classifier
    /// tensors) in canonical order.
    pub fn load_metric_weights(&self)
                               -> Result<(Vec<crate::tensor::Tensor>,
                                          Vec<crate::tensor::Tensor>)> {
        let path = self.dir.join(&self.metric_weights_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let expected: usize = self
            .feat_params
            .iter()
            .chain(&self.clf_params)
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        if bytes.len() != expected * 4 {
            bail!("metric_weights.bin: {} bytes, expected {}", bytes.len(),
                  expected * 4);
        }
        let mut off = 0usize;
        let mut take = |shape: &Vec<usize>| {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = bytes[off..off + n * 4]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            off += n * 4;
            crate::tensor::Tensor::new(shape.clone(), data)
        };
        let feat = self.feat_params.iter().map(|(_, s)| take(s)).collect();
        let clf = self.clf_params.iter().map(|(_, s)| take(s)).collect();
        Ok((feat, clf))
    }

    /// Logical artifact name for the sampling graph `base`
    /// (`"dit_fp_sample"` or `"dit_quant"`) lowered at batch dim
    /// `rung`. The largest rung keeps the unsuffixed name (the
    /// pre-ladder convention, so scalar manifests resolve unchanged);
    /// every smaller rung is `{base}@b{rung}` and must be present in
    /// the artifacts map.
    pub fn sample_artifact(&self, base: &str, rung: usize)
                           -> Result<String> {
        if !self.batches.sample.contains(&rung) {
            bail!(
                "batch {rung} is not a lowered sample rung (manifest \
                 `batches.sample` ladder is {:?})",
                self.batches.sample
            );
        }
        if rung == self.batches.sample_max() {
            return Ok(base.to_string());
        }
        let name = format!("{base}@b{rung}");
        if !self.artifacts.contains_key(&name) {
            bail!(
                "artifact `{name}` (batch-{rung} lowering of `{base}`) \
                 is missing from the manifest artifacts map"
            );
        }
        Ok(name)
    }

    /// Absolute path of a logical artifact.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let file = self
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact `{name}`"))?;
        Ok(self.dir.join(file))
    }

    /// Number of flat parameters.
    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Index of the capture output `name` (position AFTER eps_pred).
    pub fn capture_index(&self, name: &str) -> Option<usize> {
        self.capture_outputs.iter().position(|(n, _)| n == name)
    }

    /// Look up a layer by name.
    pub fn layer(&self, name: &str) -> Option<&LayerMeta> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// All sites flattened in qp-offset order.
    pub fn sites(&self) -> Vec<&SiteMeta> {
        let mut s: Vec<&SiteMeta> =
            self.layers.iter().flat_map(|l| l.sites.iter()).collect();
        s.sort_by_key(|x| x.qp_offset);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TOY: &str = r#"{
      "model": {"img_size": 8, "channels": 3, "patch": 2, "dim": 8,
                "depth": 1, "heads": 2, "num_classes": 4, "mlp_ratio": 2,
                "freq_dim": 8, "tokens": 16, "head_dim": 4,
                "patch_dim": 12},
      "diffusion": {"train_steps": 50, "beta_start": 0.0001,
                    "beta_end": 0.02},
      "params": [{"name": "w", "shape": [2, 3]},
                 {"name": "b", "shape": [3]}],
      "layers": [
        {"name": "l0", "ltype": "linear", "weight": "w",
         "sites": [{"name": "l0.x", "kind": "uniform", "tgq": false,
                    "qp_offset": 0}]},
        {"name": "m0", "ltype": "matmul", "weight": "",
         "sites": [{"name": "m0.a", "kind": "mrq_softmax", "tgq": true,
                    "qp_offset": 4},
                   {"name": "m0.b", "kind": "uniform", "tgq": false,
                    "qp_offset": 8}]}
      ],
      "qp_len": 12,
      "batches": {"calib": 2, "sample": 4, "train": 8, "feat": 16},
      "capture_outputs": [{"name": "l0.x", "shape": [2, 5]},
                          {"name": "l0.grad", "shape": [2, 3]}],
      "feat_dim": 7,
      "spat_dim": 9,
      "classifier_acc": 0.875,
      "metric_params": {
        "feature": [{"name": "c1", "shape": [3, 3, 3, 4]}],
        "classifier": [{"name": "d", "shape": [4, 2]}]
      },
      "metric_weights": "metric_weights.bin",
      "artifacts": {"dit_fp_sample": "dit_fp_sample.hlo.txt"},
      "weights": "weights.bin",
      "fid_ref": "fid_ref.bin"
    }"#;

    fn write_toy() -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tqdit_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), TOY).unwrap();
        dir
    }

    #[test]
    fn parses_toy_manifest_end_to_end() {
        let dir = write_toy();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.dim, 8);
        assert_eq!(m.diffusion.train_steps, 50);
        assert_eq!(m.params, vec![("w".to_string(), vec![2, 3]),
                                  ("b".to_string(), vec![3])]);
        assert_eq!(m.qp_len, 12);
        assert_eq!(m.batches.feat, 16);
        // scalar `batches.sample` parses as a one-rung ladder whose only
        // rung resolves to the unsuffixed artifact names
        assert_eq!(m.batches.sample, vec![4]);
        assert_eq!(m.batches.sample_max(), 4);
        assert_eq!(m.sample_artifact("dit_fp_sample", 4).unwrap(),
                   "dit_fp_sample");
        assert!(m.sample_artifact("dit_fp_sample", 2).is_err());
        assert_eq!(m.feat_params.len(), 1);
        assert_eq!(m.clf_params[0].1, vec![4, 2]);
        assert!((m.classifier_acc - 0.875).abs() < 1e-12);
        // site parsing
        let sites = m.sites();
        assert_eq!(sites.len(), 3);
        assert_eq!(sites[1].kind, SiteKind::MrqSoftmax);
        assert!(sites[1].tgq);
        // lookups
        assert!(m.layer("m0").is_some());
        assert_eq!(m.capture_index("l0.grad"), Some(1));
        assert!(m.artifact_path("dit_fp_sample").unwrap()
            .ends_with("dit_fp_sample.hlo.txt"));
        assert!(m.artifact_path("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_metric_weights_checks_size() {
        let dir = write_toy();
        let m = Manifest::load(&dir).unwrap();
        // expected: 3*3*3*4 + 4*2 = 116 f32 = 464 bytes
        std::fs::write(dir.join("metric_weights.bin"), vec![0u8; 464])
            .unwrap();
        let (f, c) = m.load_metric_weights().unwrap();
        assert_eq!(f[0].shape, vec![3, 3, 3, 4]);
        assert_eq!(c[0].shape, vec![4, 2]);
        std::fs::write(dir.join("metric_weights.bin"), vec![0u8; 100])
            .unwrap();
        assert!(m.load_metric_weights().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ladder_manifest_parses_sorted_and_resolves_per_rung() {
        let dir = std::env::temp_dir().join(format!(
            "tqdit_manifest_ladder_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = TOY
            .replace("\"sample\": 4", "\"sample\": [4, 1, 2, 2]")
            .replace(
                "\"dit_fp_sample\": \"dit_fp_sample.hlo.txt\"",
                "\"dit_fp_sample\": \"dit_fp_sample.hlo.txt\",
                 \"dit_fp_sample@b1\": \"dit_fp_sample@b1.hlo.txt\",
                 \"dit_fp_sample@b2\": \"dit_fp_sample@b2.hlo.txt\"",
            );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        // sorted ascending, deduped
        assert_eq!(m.batches.sample, vec![1, 2, 4]);
        assert_eq!(m.batches.sample_max(), 4);
        // largest rung keeps the unsuffixed name; smaller rungs resolve
        // to their @b names from the artifacts map
        assert_eq!(m.sample_artifact("dit_fp_sample", 4).unwrap(),
                   "dit_fp_sample");
        assert_eq!(m.sample_artifact("dit_fp_sample", 1).unwrap(),
                   "dit_fp_sample@b1");
        assert_eq!(m.sample_artifact("dit_fp_sample", 2).unwrap(),
                   "dit_fp_sample@b2");
        // a rung outside the ladder is a typed error naming the ladder
        let e = format!("{:#}",
                        m.sample_artifact("dit_fp_sample", 8).unwrap_err());
        assert!(e.contains("[1, 2, 4]"), "{e}");
        // a lowered rung whose artifact entry is missing names the key
        let e = format!("{:#}",
                        m.sample_artifact("dit_quant", 2).unwrap_err());
        assert!(e.contains("dit_quant@b2"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_ladders_error_with_key() {
        for (tag, bad) in [("empty", "\"sample\": []"),
                           ("zero", "\"sample\": [0, 4]"),
                           ("strrung", "\"sample\": [4, \"x\"]"),
                           ("type", "\"sample\": true")] {
            let e = load_error(&format!("ladder_{tag}"), "\"sample\": 4",
                               bad);
            assert!(e.contains("batches.sample"), "{tag}: {e}");
        }
    }

    #[test]
    fn rejects_unknown_site_kind() {
        let dir = std::env::temp_dir()
            .join(format!("tqdit_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"),
                       TOY.replace("mrq_softmax", "mystery")).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Write a tampered TOY manifest and return the load error text.
    fn load_error(tag: &str, from: &str, to: &str) -> String {
        let dir = std::env::temp_dir().join(format!(
            "tqdit_manifest_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = TOY.replace(from, to);
        assert_ne!(text, TOY, "tamper pattern `{from}` did not match");
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let err = Manifest::load(&dir).unwrap_err();
        std::fs::remove_dir_all(&dir).ok();
        format!("{err:#}")
    }

    #[test]
    fn missing_field_errors_name_key_and_file() {
        let e = load_error("nodepth", "\"depth\": 1,", "");
        assert!(e.contains("model.depth"), "{e}");
        assert!(e.contains("manifest.json"), "{e}");

        let e = load_error("noqplen", "\"qp_len\": 12,", "");
        assert!(e.contains("qp_len"), "{e}");

        let e = load_error("nosteps", "\"train_steps\": 50,", "");
        assert!(e.contains("diffusion.train_steps"), "{e}");

        let e = load_error("noweights", "\"weights\": \"weights.bin\",", "");
        assert!(e.contains("`weights`"), "{e}");
    }

    #[test]
    fn wrong_type_errors_name_key_not_panic() {
        let e = load_error("strqplen", "\"qp_len\": 12", "\"qp_len\": \"x\"");
        assert!(e.contains("qp_len") && e.contains("integer"), "{e}");

        let e = load_error("badshape", "\"shape\": [2, 3]",
                           "\"shape\": \"oops\"");
        assert!(e.contains("shape"), "{e}");

        let e = load_error("badsite", "\"qp_offset\": 0}", "\"qp_offset\": 0,
                            \"name\": 7}");
        assert!(e.contains("sites[0].name"), "{e}");
    }

    #[test]
    fn truncated_manifest_errors_cleanly() {
        let dir = std::env::temp_dir().join(format!(
            "tqdit_manifest_trunc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"),
                       &TOY[..TOY.len() / 2]).unwrap();
        let e = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(e.contains("parsing manifest"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

// -- fallible field access (errors name the dotted key path) -------------

fn req<'a>(j: &'a Json, ctx: &str, key: &str) -> Result<&'a Json> {
    j.get(key)
        .ok_or_else(|| anyhow::anyhow!("missing key `{ctx}{key}`"))
}

fn req_usize(j: &Json, ctx: &str, key: &str) -> Result<usize> {
    req(j, ctx, key)?.as_exact_usize().ok_or_else(|| {
        anyhow::anyhow!("key `{ctx}{key}`: expected an integer")
    })
}

fn req_f64(j: &Json, ctx: &str, key: &str) -> Result<f64> {
    req(j, ctx, key)?.as_f64().ok_or_else(|| {
        anyhow::anyhow!("key `{ctx}{key}`: expected a number")
    })
}

fn req_str<'a>(j: &'a Json, ctx: &str, key: &str) -> Result<&'a str> {
    req(j, ctx, key)?.as_str().ok_or_else(|| {
        anyhow::anyhow!("key `{ctx}{key}`: expected a string")
    })
}

/// Parse a batch ladder: either a positive integer (one-rung ladder,
/// the pre-ladder manifest format) or a non-empty array of positive
/// integers. Returned sorted ascending and deduped.
fn parse_ladder(j: &Json, key: &str) -> Result<Vec<usize>> {
    let mut rungs: Vec<usize> = if let Some(n) = j.as_exact_usize() {
        vec![n]
    } else if let Some(arr) = j.as_arr() {
        arr.iter()
            .enumerate()
            .map(|(i, v)| {
                v.as_exact_usize().with_context(|| {
                    format!("key `{key}[{i}]`: expected an integer")
                })
            })
            .collect::<Result<Vec<_>>>()?
    } else {
        bail!("key `{key}`: expected an integer or an integer array");
    };
    if rungs.is_empty() {
        bail!("key `{key}`: batch ladder needs at least one rung");
    }
    if rungs.contains(&0) {
        bail!("key `{key}`: batch ladder rungs must be positive");
    }
    rungs.sort_unstable();
    rungs.dedup();
    Ok(rungs)
}

fn req_shape(j: &Json, ctx: &str, key: &str) -> Result<Vec<usize>> {
    req(j, ctx, key)?.as_shape().ok_or_else(|| {
        anyhow::anyhow!("key `{ctx}{key}`: expected an integer array")
    })
}

/// Parse an array of `{"name": ..., "shape": [...]}` specs.
fn parse_specs(node: &Json, ctx: &str) -> Result<Vec<(String, Vec<usize>)>> {
    node.as_arr()
        .with_context(|| format!("key `{ctx}`: expected an array"))?
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let ctx = format!("{ctx}[{i}].");
            Ok((
                req_str(p, &ctx, "name")?.to_string(),
                req_shape(p, &ctx, "shape")?,
            ))
        })
        .collect()
}

fn parse_layer(l: &Json, idx: usize) -> Result<LayerMeta> {
    let lctx = format!("layers[{idx}].");
    let sites = req(l, &lctx, "sites")?
        .as_arr()
        .with_context(|| format!("key `{lctx}sites`: expected an array"))?
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let sctx = format!("{lctx}sites[{i}].");
            let kind = match req_str(s, &sctx, "kind")? {
                "uniform" => SiteKind::Uniform,
                "mrq_softmax" => SiteKind::MrqSoftmax,
                "mrq_gelu" => SiteKind::MrqGelu,
                other => {
                    bail!("key `{sctx}kind`: unknown site kind `{other}`")
                }
            };
            Ok(SiteMeta {
                name: req_str(s, &sctx, "name")?.to_string(),
                kind,
                // optional: non-TGQ sites may omit the flag
                tgq: s.get("tgq").and_then(Json::as_bool).unwrap_or(false),
                qp_offset: req_usize(s, &sctx, "qp_offset")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(LayerMeta {
        name: req_str(l, &lctx, "name")?.to_string(),
        ltype: req_str(l, &lctx, "ltype")?.to_string(),
        // matmul layers carry no weight param; tolerate an absent key
        weight: match l.get("weight") {
            None => String::new(),
            Some(v) => v
                .as_str()
                .with_context(|| {
                    format!("key `{lctx}weight`: expected a string")
                })?
                .to_string(),
        },
        sites,
    })
}
