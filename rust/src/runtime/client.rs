//! PJRT CPU client wrapper: compile HLO-text artifacts once, execute
//! with host tensors, get host tensors back.
//!
//! The hot path keeps model weights resident as device buffers
//! (`execute_b`), so each sampler step uploads only the small dynamic
//! inputs (x_t, t, y, qparams) — see EXPERIMENTS.md §Perf for the
//! before/after of that change.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::artifacts::Manifest;
use crate::tensor::Tensor;

/// Execution statistics per artifact (observability for the §Perf pass).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_s: f64,
}

/// PJRT runtime handle. Not `Sync` — PJRT calls stay on one thread while
/// host-side math parallelizes underneath (see `util::threadpool`).
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<HashMap<String, ExecStats>>,
}

impl Runtime {
    /// Create the CPU client and parse the manifest.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            executables: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (or fetch the cached) executable for a logical artifact.
    pub fn executable(&self, name: &str)
                      -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let path = self.manifest.artifact_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse HLO {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        crate::info!("compiled artifact `{name}` in {:.2}s",
                     t0.elapsed().as_secs_f64());
        let rc = Rc::new(exe);
        self.executables
            .borrow_mut()
            .insert(name.to_string(), Rc::clone(&rc));
        Ok(rc)
    }

    /// Compile (or fetch) the executable for the sampling graph `base`
    /// lowered at batch dim `rung`, resolving the per-rung artifact
    /// name (`{base}@b{rung}`, unsuffixed for the largest rung) through
    /// the manifest's batch ladder.
    pub fn executable_for_rung(&self, base: &str, rung: usize)
                               -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let name = self.manifest.sample_artifact(base, rung)?;
        self.executable(&name)
    }

    /// Execute with literal inputs; outputs as host tensors (the
    /// artifact returns one tuple — we decompose it).
    pub fn run(&self, name: &str, inputs: &[xla::Literal])
               -> Result<Vec<Tensor>> {
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let out = Self::decompose(&result[0][0])?;
        self.note(name, t0.elapsed().as_secs_f64());
        Ok(out)
    }

    /// Execute with pre-uploaded device buffers (weights stay resident).
    pub fn run_buffers(&self, name: &str, inputs: &[&xla::PjRtBuffer])
                       -> Result<Vec<Tensor>> {
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute_b(inputs)
            .map_err(|e| anyhow::anyhow!("execute_b {name}: {e:?}"))?;
        let out = Self::decompose(&result[0][0])?;
        self.note(name, t0.elapsed().as_secs_f64());
        Ok(out)
    }

    /// Upload a host tensor once; reuse the buffer across calls.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let dims: Vec<usize> = t.shape.clone();
        self.client
            .buffer_from_host_buffer(&t.data, &dims, None)
            .map_err(|e| anyhow::anyhow!("upload: {e:?}"))
    }

    pub fn upload_i32(&self, data: &[i32], shape: &[usize])
                      -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(|e| anyhow::anyhow!("upload i32: {e:?}"))
    }

    /// Upload a raw f32 slice with an explicit shape — the zero-copy
    /// sibling of [`Self::upload`] for hot paths that keep their state
    /// in a plain `Vec<f32>` (the sampler trajectory) and must not pay
    /// a `Tensor` clone per step.
    pub fn upload_f32(&self, data: &[f32], shape: &[usize])
                      -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(|e| anyhow::anyhow!("upload f32: {e:?}"))
    }

    /// Upload a set of tensors once (e.g. the model weights) so the hot
    /// path reuses resident device buffers across calls.
    pub fn upload_all(&self, tensors: &[Tensor]) -> Result<Vec<xla::PjRtBuffer>> {
        tensors.iter().map(|t| self.upload(t)).collect()
    }

    fn decompose(buf: &xla::PjRtBuffer) -> Result<Vec<Tensor>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("tuple decompose: {e:?}"))?;
        parts.iter().map(literal_to_tensor).collect()
    }

    fn note(&self, name: &str, secs: f64) {
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_s += secs;
    }

    /// Snapshot of per-artifact execution stats.
    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<(String, ExecStats)> = self
            .stats
            .borrow()
            .iter()
            .map(|(k, s)| (k.clone(), *s))
            .collect();
        // total_cmp, not partial_cmp().unwrap(): one NaN timing must
        // not panic a stats snapshot
        v.sort_by(|a, b| b.1.total_s.total_cmp(&a.1.total_s));
        v
    }
}

/// Host tensor → literal (f32).
pub fn lit_f32(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&t.data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
}

/// i32 slice → literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
}

/// Literal → host tensor (f32; int literals are converted).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = match lit.to_vec::<f32>() {
        Ok(v) => v,
        Err(_) => {
            let converted = lit
                .convert(xla::PrimitiveType::F32)
                .map_err(|e| anyhow::anyhow!("convert literal: {e:?}"))?;
            converted
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("literal data: {e:?}"))?
        }
    };
    Ok(Tensor::new(dims, data))
}
