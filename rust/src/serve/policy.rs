//! Deadline-aware batch planning over a ladder of lowered batch dims.
//!
//! The sampling artifacts are lowered at a *ladder* of batch sizes
//! (`Manifest::batches.sample`); the policy decides, for the current
//! queue, whether to dispatch now — and on which rung — or hold for
//! more fill. The rule:
//!
//! * queue ≥ largest rung → dispatch the largest rung, full (a burst
//!   always fills the big batch);
//! * queue exactly matches a rung → dispatch it now, zero padding
//!   (trickle traffic rides the small rungs at low latency);
//! * otherwise hold until the oldest queued slot has lingered past the
//!   configured deadline, then dispatch the *whole* queue on the
//!   smallest rung that covers it, padding the shortfall. One covering
//!   dispatch is chosen over decomposing the queue into exact smaller
//!   rungs: per-dispatch overhead (buffer uploads, lock round-trips)
//!   is paid once, and padding never exceeds what the fixed-batch
//!   dispatcher would burn for the same queue (property-tested below).
//!
//! With a one-rung ladder and a zero linger this degenerates to the
//! classic fixed-batch `pop_batch(max_batch)` behavior, which keeps
//! scalar-manifest deployments byte-identical.
//!
//! The policy is a pure function of (ladder, queue depth, oldest wait,
//! draining) — no clocks, no locks — so every property below is tested
//! deterministically, with durations as plain values.

use std::time::Duration;

use anyhow::{bail, Result};

/// Validated batch ladder: the batch dims a backend can execute,
/// sorted ascending and deduped, never empty.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ladder {
    rungs: Vec<usize>,
}

impl Ladder {
    pub fn new(mut rungs: Vec<usize>) -> Result<Ladder> {
        rungs.sort_unstable();
        rungs.dedup();
        if rungs.is_empty() {
            bail!("batch ladder must have at least one rung");
        }
        if rungs[0] == 0 {
            bail!("batch ladder rungs must be positive");
        }
        Ok(Ladder { rungs })
    }

    /// Ascending rung sizes.
    pub fn rungs(&self) -> &[usize] {
        &self.rungs
    }

    /// Largest rung (the classic full artifact batch).
    pub fn max(&self) -> usize {
        // Ladder::new rejects an empty rung list; the fallback only
        // keeps this panic-free
        self.rungs.last().copied().unwrap_or(1)
    }

    /// Smallest rung that covers `n` slots, or the largest rung when
    /// none does (`n` then spans several dispatches).
    pub fn rung_for(&self, n: usize) -> usize {
        match self.rungs.iter().find(|&&r| r >= n) {
            Some(&r) => r,
            None => self.max(),
        }
    }

    /// Whether some rung holds exactly `n` slots (zero padding).
    pub fn has_exact(&self, n: usize) -> bool {
        self.rungs.binary_search(&n).is_ok()
    }
}

/// What the policy decided for the head of the queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPlan {
    /// Pop `take` slots now and run them on a `rung`-slot artifact
    /// (padding `rung - take` slots, zero unless the deadline forced a
    /// partial rung).
    Dispatch { rung: usize, take: usize },
    /// Hold for more fill; re-consult the policy once `remaining` has
    /// elapsed (the oldest slot's linger deadline) or new work arrives.
    Wait { remaining: Duration },
}

/// Dispatch policy: how long a partially-filled rung may wait for more
/// slots before it is dispatched padded.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Linger deadline. Zero means dispatch immediately (the classic
    /// greedy batcher).
    pub linger: Duration,
}

impl BatchPolicy {
    pub fn new(linger: Duration) -> BatchPolicy {
        BatchPolicy { linger }
    }

    /// Decide for a non-empty queue. `pending` is the queued slot
    /// count, `oldest_wait` how long the head slot has been queued, and
    /// `draining` disables lingering (shutdown: flush everything now).
    pub fn plan(&self, ladder: &Ladder, pending: usize,
                oldest_wait: Duration, draining: bool) -> BatchPlan {
        debug_assert!(pending > 0, "plan() needs a non-empty queue");
        let max = ladder.max();
        if pending >= max {
            // a full largest rung never waits and never pads
            return BatchPlan::Dispatch { rung: max, take: max };
        }
        if ladder.has_exact(pending) {
            // an exact fit pads nothing; waiting could only grow the
            // queue into a padded bigger rung, so go now
            return BatchPlan::Dispatch { rung: pending, take: pending };
        }
        if draining || oldest_wait >= self.linger {
            // deadline passed: smallest rung covering the queue
            return BatchPlan::Dispatch {
                rung: ladder.rung_for(pending),
                take: pending,
            };
        }
        BatchPlan::Wait { remaining: self.linger - oldest_wait }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, Gen};

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn ladder_sorts_dedups_and_rejects_junk() {
        let l = Ladder::new(vec![8, 1, 4, 4]).unwrap();
        assert_eq!(l.rungs(), &[1, 4, 8]);
        assert_eq!(l.max(), 8);
        assert!(Ladder::new(vec![]).is_err());
        assert!(Ladder::new(vec![0, 2]).is_err());
    }

    #[test]
    fn rung_for_picks_smallest_cover() {
        let l = Ladder::new(vec![1, 2, 4, 8]).unwrap();
        assert_eq!(l.rung_for(1), 1);
        assert_eq!(l.rung_for(3), 4);
        assert_eq!(l.rung_for(8), 8);
        assert_eq!(l.rung_for(100), 8);
        assert!(l.has_exact(2));
        assert!(!l.has_exact(3));
    }

    #[test]
    fn zero_linger_one_rung_matches_fixed_batch() {
        // the backward-compat contract: scalar manifest + --linger-ms 0
        // behaves exactly like the old pop_batch(max_batch)
        let l = Ladder::new(vec![4]).unwrap();
        let p = BatchPolicy::new(ms(0));
        for pending in 1..=9usize {
            let plan = p.plan(&l, pending, ms(0), false);
            let take = pending.min(4);
            assert_eq!(plan, BatchPlan::Dispatch { rung: 4, take },
                       "pending={pending}");
        }
    }

    #[test]
    fn full_and_exact_fits_never_wait() {
        let l = Ladder::new(vec![1, 2, 8]).unwrap();
        let p = BatchPolicy::new(ms(1000));
        // burst fills the big rung immediately
        assert_eq!(p.plan(&l, 20, ms(0), false),
                   BatchPlan::Dispatch { rung: 8, take: 8 });
        // exact small rungs dispatch with zero padding, zero linger
        assert_eq!(p.plan(&l, 1, ms(0), false),
                   BatchPlan::Dispatch { rung: 1, take: 1 });
        assert_eq!(p.plan(&l, 2, ms(0), false),
                   BatchPlan::Dispatch { rung: 2, take: 2 });
    }

    #[test]
    fn partial_rung_lingers_until_the_deadline() {
        let l = Ladder::new(vec![2, 8]).unwrap();
        let p = BatchPolicy::new(ms(50));
        // 3 slots: no exact rung, below max — hold, reporting time left
        assert_eq!(p.plan(&l, 3, ms(10), false),
                   BatchPlan::Wait { remaining: ms(40) });
        // deadline reached: smallest covering rung, padded
        assert_eq!(p.plan(&l, 3, ms(50), false),
                   BatchPlan::Dispatch { rung: 8, take: 3 });
        assert_eq!(p.plan(&l, 3, ms(90), false),
                   BatchPlan::Dispatch { rung: 8, take: 3 });
        // draining flushes immediately regardless of the deadline
        assert_eq!(p.plan(&l, 3, ms(0), true),
                   BatchPlan::Dispatch { rung: 8, take: 3 });
    }

    #[test]
    fn prop_rung_selection_is_sound() {
        // the three satellite properties, against random ladders:
        //  1. never a rung smaller than the take when a larger exists
        //  2. never padded when an exact rung exists (or queue >= max)
        //  3. padded dispatches only at/after the linger deadline
        check("policy rung selection", 500, |g: &mut Gen| {
            let n_rungs = g.usize_in(1, 5);
            let rungs: Vec<usize> =
                (0..n_rungs).map(|_| g.usize_in(1, 32)).collect();
            let ladder = Ladder::new(rungs).unwrap();
            let linger = ms(g.usize_in(0, 100) as u64);
            let policy = BatchPolicy::new(linger);
            let pending = g.usize_in(1, 64);
            let waited = ms(g.usize_in(0, 200) as u64);
            let draining = g.bool();
            match policy.plan(&ladder, pending, waited, draining) {
                BatchPlan::Dispatch { rung, take } => {
                    assert!(ladder.rungs().contains(&rung));
                    assert!(take <= rung, "take {take} > rung {rung}");
                    assert!(take <= pending);
                    // (1) smallest covering rung — no larger rung
                    // would be needed, no smaller rung would fit
                    assert_eq!(rung, ladder.rung_for(take));
                    // (2) exact fits and full batches never pad
                    if ladder.has_exact(pending) || pending >= ladder.max()
                    {
                        assert_eq!(take, rung, "padded an exact fit");
                    }
                    // (3) padding waits out the deadline
                    if take < rung {
                        assert!(draining || waited >= linger,
                                "padded before the deadline");
                    }
                }
                BatchPlan::Wait { remaining } => {
                    assert!(!draining, "waited while draining");
                    assert!(waited < linger);
                    assert_eq!(remaining, linger - waited);
                    assert!(pending < ladder.max());
                    assert!(!ladder.has_exact(pending));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_ladder_never_pads_more_than_fixed() {
        // dispatching a whole queue through the policy pads no more
        // than the fixed largest-rung batcher would for the same queue
        check("ladder padding <= fixed padding", 300, |g: &mut Gen| {
            let mut rungs: Vec<usize> =
                (0..g.usize_in(1, 4)).map(|_| g.usize_in(1, 16)).collect();
            let max = g.usize_in(1, 16).max(*rungs.iter().max().unwrap());
            rungs.push(max);
            let ladder = Ladder::new(rungs).unwrap();
            let policy = BatchPolicy::new(ms(0));
            let mut pending = g.usize_in(1, 100);
            let total = pending;
            let mut padded = 0usize;
            while pending > 0 {
                match policy.plan(&ladder, pending, ms(0), false) {
                    BatchPlan::Dispatch { rung, take } => {
                        padded += rung - take;
                        pending -= take;
                    }
                    BatchPlan::Wait { .. } => unreachable!("linger 0"),
                }
            }
            let fixed_padded = (max - total % max) % max;
            assert!(
                padded <= fixed_padded,
                "ladder {:?} padded {padded} > fixed {fixed_padded} \
                 for {total} slots",
                ladder.rungs()
            );
            Ok(())
        });
    }
}
