//! Typed serve-layer errors.
//!
//! Every failure mode a client can observe is a [`ServeError`] variant:
//! rejected submits (shutdown, backpressure, dead service) surface as
//! `Err` from `submit`, and in-flight failures (a worker dying mid-batch,
//! the whole service going down with queued work) are *sent* to the
//! waiting client over its response channel — clients never hang on a
//! channel whose producer has died, and the process never panics on a
//! dead worker.

use std::fmt;

/// Client-visible generation-service failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The server is shutting down (or already shut down); the request
    /// was not accepted.
    ShuttingDown,
    /// Backpressure: accepting the request would exceed the queue cap.
    /// Transient — retry once the queue drains.
    QueueFull { queued: usize, cap: usize },
    /// The request alone exceeds the queue cap, so it can *never* be
    /// accepted (unlike `QueueFull`, retrying is pointless).
    RequestTooLarge { n: usize, cap: usize },
    /// A worker thread failed before it could serve (pipeline build or
    /// calibration error).
    WorkerInitFailed { worker: usize, cause: String },
    /// A worker failed while generating the batch containing this
    /// request.
    WorkerFailed { worker: usize, cause: String },
    /// Every worker has exited; `cause` carries the first recorded
    /// failure (or a generic note when workers exited cleanly).
    AllWorkersDead { cause: String },
    /// Cross-node serving: the shard node holding this request was
    /// lost and no surviving shard remained to take it (a lost node
    /// with survivors re-queues silently instead of surfacing this).
    NodeLost { cause: String },
    /// A wire-protocol violation scoped to this one request (bad
    /// message, response channel torn down without a result) — the
    /// connection and the rest of the service keep going.
    Protocol { cause: String },
    /// The caller-supplied per-request deadline elapsed before a
    /// response arrived. The request may still complete server-side;
    /// only the waiting is over (multiplexing clients drop the late
    /// response when it lands).
    Deadline { after_ms: u64 },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::ShuttingDown => {
                write!(f, "generation server is shutting down")
            }
            ServeError::QueueFull { queued, cap } => {
                write!(f, "generation queue full ({queued} slots queued, \
                           cap {cap})")
            }
            ServeError::RequestTooLarge { n, cap } => {
                write!(f, "request for {n} images exceeds the queue cap \
                           {cap} and can never be served whole")
            }
            ServeError::WorkerInitFailed { worker, cause } => {
                write!(f, "worker {worker} failed to initialize: {cause}")
            }
            ServeError::WorkerFailed { worker, cause } => {
                write!(f, "worker {worker} failed while generating: {cause}")
            }
            ServeError::AllWorkersDead { cause } => {
                write!(f, "no live generation workers ({cause})")
            }
            ServeError::NodeLost { cause } => {
                write!(f, "shard node lost with no surviving shard \
                           ({cause})")
            }
            ServeError::Protocol { cause } => {
                write!(f, "wire protocol violation: {cause}")
            }
            ServeError::Deadline { after_ms } => {
                write!(f, "request deadline exceeded after {after_ms} ms")
            }
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_worker_and_cause() {
        let e = ServeError::WorkerFailed {
            worker: 3,
            cause: "execute dit_quant: OOM".into(),
        };
        let s = e.to_string();
        assert!(s.contains("worker 3"), "{s}");
        assert!(s.contains("OOM"), "{s}");
    }

    #[test]
    fn queue_full_reports_both_numbers() {
        let s = ServeError::QueueFull { queued: 99, cap: 64 }.to_string();
        assert!(s.contains("99") && s.contains("64"), "{s}");
    }

    #[test]
    fn net_variants_name_their_cause() {
        let s = ServeError::NodeLost {
            cause: "shard 127.0.0.1:7070: heartbeat timeout".into(),
        }
        .to_string();
        assert!(s.contains("127.0.0.1:7070"), "{s}");
        let s = ServeError::Protocol { cause: "bad frame".into() }
            .to_string();
        assert!(s.contains("bad frame"), "{s}");
    }

    #[test]
    fn deadline_reports_the_budget() {
        let s = ServeError::Deadline { after_ms: 250 }.to_string();
        assert!(s.contains("250"), "{s}");
        assert!(s.contains("deadline"), "{s}");
    }
}
