//! Multi-worker generation router: N worker threads pulling batches off
//! one shared FIFO [`Batcher`], each sized by the deadline-aware
//! [`BatchPolicy`] over the backend's lowered batch ladder.
//!
//! # Threading model
//!
//! The PJRT runtime is not `Send`, so a worker's backend (runtime +
//! sampler) must be *built inside* the worker's own thread; the router
//! only ever moves plain data across threads. Dispatch is work-stealing
//! by construction: every worker, when idle, locks the shared state and
//! consults the policy — whichever worker is free takes the oldest
//! work, and a slow worker never blocks a fast one. A policy `Wait`
//! (partial rung inside its linger window) parks the worker on the
//! condvar with the deadline as timeout, so a trickle request is
//! dispatched the moment its deadline expires, new work arrives, or
//! shutdown begins — never later.
//!
//! # Failure semantics
//!
//! * A worker that fails to initialize marks itself dead; the service
//!   keeps running on the survivors.
//! * A worker whose `generate` call fails sends a typed
//!   [`ServeError::WorkerFailed`] to every client with images in that
//!   batch, removes their remaining queued slots, and exits.
//! * When the *last* worker exits with requests still queued, every
//!   waiting client receives [`ServeError::AllWorkersDead`] and later
//!   submits are rejected with the same cause. Clients never hang and
//!   the process never panics on a dead worker.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::obs::hist::LatencyHist;
use crate::obs::trace::{self, SpanKind, SpanRec, TraceCtx};
use crate::serve::batcher::{Batcher, Slot};
use crate::serve::error::ServeError;
use crate::serve::policy::{BatchPlan, BatchPolicy, Ladder};

/// A client request: n images of one class.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub class: i32,
    pub n: usize,
}

/// The server's reply.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// Flat (n, H, W, C) pixels in ≈[-1, 1].
    pub images: Vec<f32>,
    /// Queue + compute time for the whole request.
    pub latency_s: f64,
}

/// What a client's response channel yields.
pub type GenResult = std::result::Result<GenResponse, ServeError>;

/// Per-rung dispatch counters: batches have different capacities once
/// the ladder is live, so padding and fill are only meaningful sliced
/// by the rung they were dispatched on.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RungStats {
    /// Lowered batch dim of this rung.
    pub rung: usize,
    pub batches: u64,
    /// Real (non-padding) image slots computed on this rung.
    pub images: u64,
    /// Class-0 padding slots burned on this rung.
    pub padded_slots: u64,
    /// Wall-clock spent inside `generate` on this rung.
    pub busy_s: f64,
}

impl RungStats {
    /// Mean fill of this rung's dispatches: occupied slots over
    /// dispatched capacity (occupied includes slots later dropped by a
    /// failing request — they were computed either way).
    pub fn fill(&self) -> f64 {
        let cap = (self.rung as u64 * self.batches) as f64;
        if cap == 0.0 {
            0.0
        } else {
            (cap - self.padded_slots as f64) / cap
        }
    }
}

/// Find or insert the stats slot for `rung`, kept sorted ascending.
pub(crate) fn rung_entry(rungs: &mut Vec<RungStats>, rung: usize)
                         -> &mut RungStats {
    let i = match rungs.binary_search_by_key(&rung, |r| r.rung) {
        Ok(i) => i,
        Err(i) => {
            rungs.insert(i, RungStats { rung, ..RungStats::default() });
            i
        }
    };
    &mut rungs[i]
}

/// Per-worker counters (reported inside [`ServerStats`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerStats {
    pub worker: usize,
    pub batches: u64,
    /// Real (non-padding) image slots computed.
    pub images: u64,
    /// Class-0 padding slots burned to fill dispatched rungs.
    pub padded_slots: u64,
    /// Wall-clock spent inside `generate`.
    pub busy_s: f64,
    /// Sampler steps whose ε̂ came from the step-reuse cache instead of
    /// a forward pass (zero for backends without a reuse layer).
    pub reuse_hits: u64,
    /// Forward passes the reuse policy avoided outright.
    pub steps_skipped: u64,
    /// Host→device uploads avoided by the device-resident trajectory
    /// (qparams, per-step `t` vectors) plus skipped-step traffic.
    pub uploads_saved: u64,
    /// The same counters sliced per dispatched ladder rung (ascending).
    pub rungs: Vec<RungStats>,
    /// The backend was built and entered service at some point
    /// (false means the worker never got past initialization).
    pub ready: bool,
    /// True if the worker exited on an error (init or generate).
    pub failed: bool,
}

/// Aggregate server statistics (reported on shutdown, or as a live
/// snapshot via `stats()`/the remote stats protocol). `PartialEq` (not
/// `Eq`: float fields) backs the wire-serde round-trip tests.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServerStats {
    pub requests: u64,
    /// Real images delivered (excludes padding).
    pub images: u64,
    pub batches: u64,
    /// Mean per-dispatch fill, each batch normalized by its *own*
    /// rung's capacity (batches of different rungs weigh equally).
    pub batch_fill: f64,
    /// Padding slots across all workers (wasted capacity).
    pub padded_slots: u64,
    /// Requests that received a [`ServeError`] instead of images.
    pub failed_requests: u64,
    /// Completed responses whose client had hung up its receiver.
    pub dropped_responses: u64,
    pub wall_s: f64,
    /// Queue depth observed at each batch dispatch.
    pub queue_depth_avg: f64,
    pub queue_depth_max: usize,
    /// Per-request latency percentiles (queue + compute), derived
    /// from [`ServerStats::latency`] — kept as plain fields so
    /// benches and reports read them without histogram math.
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    /// Full per-request latency distribution as a mergeable
    /// log-linear histogram: [`ServerStats::absorb`] and the cluster
    /// stats fold add these bucket-wise, so cross-shard percentiles
    /// are computed over the *merged* distribution instead of the
    /// old max-of-percentiles bound.
    pub latency: LatencyHist,
    /// Persistent-calibration-cache outcome for this run (filled in by
    /// the serve layer; both zero when calibration never resolved).
    pub calib_cache_hits: u64,
    pub calib_cache_misses: u64,
    /// Wall-clock of the one shared calibration resolution — cache
    /// load on a hit, the full MRQ/TGQ pipeline on a miss.
    pub calib_cold_start_ms: f64,
    /// Lifetime slot-flow counters from the batcher. Conservation
    /// invariant at any quiescent point (and after a drained
    /// shutdown, where `pending` is zero):
    /// `enqueued == dispatched + purged + pending`.
    pub enqueued: u64,
    pub dispatched: u64,
    pub purged: u64,
    /// Slots still queued when the stats were assembled (a live
    /// snapshot may be non-zero; a drained shutdown reports zero).
    pub pending: u64,
    /// Cluster-level counters (zero for a purely local service):
    /// requests re-queued onto a surviving shard after their node was
    /// lost, shard nodes declared dead, and recovered shard nodes
    /// re-admitted into placement.
    pub requeued: u64,
    pub nodes_lost: u64,
    pub nodes_readmitted: u64,
    /// Sampler steps served from the step-reuse cache across all
    /// workers (zero when the reuse layer is disabled, δ = 0).
    pub reuse_hits: u64,
    /// Forward passes the reuse policy skipped across all workers.
    pub steps_skipped: u64,
    /// Host→device uploads avoided by the device-resident trajectory
    /// across all workers.
    pub uploads_saved: u64,
    /// Dispatch counters sliced by ladder rung, aggregated over the
    /// workers (ascending by rung).
    pub rungs: Vec<RungStats>,
    pub workers: Vec<WorkerStats>,
}

impl ServerStats {
    pub fn throughput(&self) -> f64 {
        self.images as f64 / self.wall_s.max(1e-9)
    }

    pub fn print(&self) {
        println!(
            "served {} requests / {} images in {:.2}s  \
             ({:.2} img/s, {} batches, fill {:.0}%, {} padded slots)",
            self.requests, self.images, self.wall_s, self.throughput(),
            self.batches, self.batch_fill * 100.0, self.padded_slots
        );
        println!(
            "latency p50 {:.3}s p95 {:.3}s  queue depth avg {:.1} max {}  \
             failed {}  dropped {}",
            self.latency_p50_s, self.latency_p95_s, self.queue_depth_avg,
            self.queue_depth_max, self.failed_requests,
            self.dropped_responses
        );
        println!(
            "slots: {} enqueued = {} dispatched + {} purged + {} pending",
            self.enqueued, self.dispatched, self.purged, self.pending
        );
        if self.requeued > 0 || self.nodes_lost > 0
            || self.nodes_readmitted > 0
        {
            println!(
                "cluster: {} request(s) re-queued, {} node(s) lost, \
                 {} re-admitted",
                self.requeued, self.nodes_lost, self.nodes_readmitted
            );
        }
        if self.reuse_hits > 0 || self.steps_skipped > 0 {
            println!(
                "reuse: {} step(s) served from cache, {} forward pass(es) \
                 skipped, {} upload(s) saved",
                self.reuse_hits, self.steps_skipped, self.uploads_saved
            );
        }
        if self.calib_cache_hits + self.calib_cache_misses > 0 {
            println!(
                "calibration: cache {} ({:.0} ms cold start)",
                if self.calib_cache_hits > 0 { "hit" } else { "miss" },
                self.calib_cold_start_ms
            );
        }
        for r in &self.rungs {
            println!(
                "  rung {:>4}: {:>4} batches  {:>5} images  {:>4} padded  \
                 fill {:>3.0}%  busy {:.2}s",
                r.rung, r.batches, r.images, r.padded_slots,
                r.fill() * 100.0, r.busy_s
            );
        }
        for w in &self.workers {
            println!(
                "  worker {}: {:>4} batches  {:>5} images  {:>4} padded  \
                 busy {:.2}s{}",
                w.worker, w.batches, w.images, w.padded_slots, w.busy_s,
                if w.failed { "  (failed)" } else { "" }
            );
        }
    }

    /// Fold another service's stats into this one (cluster
    /// aggregation, or summing per-node shutdown stats in tests).
    ///
    /// Counters add, so the conservation invariant
    /// `enqueued == dispatched + purged + pending` survives the merge
    /// whenever it holds per input. Ratios (`batch_fill`,
    /// `queue_depth_avg`) merge weighted by batch count; `wall_s`
    /// takes the max (services ran concurrently). Latency histograms
    /// merge bucket-wise and the percentile fields are *recomputed*
    /// from the merged distribution — only when both sides carry an
    /// empty histogram (a stats report from a pre-histogram peer)
    /// does the old max-of-percentiles conservative bound remain.
    /// Worker rows are re-numbered so rows from different nodes never
    /// collide.
    pub fn absorb(&mut self, o: &ServerStats) {
        let (b0, b1) = (self.batches as f64, o.batches as f64);
        if b0 + b1 > 0.0 {
            self.batch_fill =
                (self.batch_fill * b0 + o.batch_fill * b1) / (b0 + b1);
            self.queue_depth_avg = (self.queue_depth_avg * b0
                                    + o.queue_depth_avg * b1)
                / (b0 + b1);
        }
        self.requests += o.requests;
        self.images += o.images;
        self.batches += o.batches;
        self.padded_slots += o.padded_slots;
        self.failed_requests += o.failed_requests;
        self.dropped_responses += o.dropped_responses;
        self.wall_s = self.wall_s.max(o.wall_s);
        self.queue_depth_max = self.queue_depth_max.max(o.queue_depth_max);
        self.latency.merge(&o.latency);
        if self.latency.count() > 0 {
            self.latency_p50_s = self.latency.quantile(0.50);
            self.latency_p95_s = self.latency.quantile(0.95);
        } else {
            // neither side shipped a histogram (old-wire peer):
            // max() stays the conservative cross-service bound
            self.latency_p50_s = self.latency_p50_s.max(o.latency_p50_s);
            self.latency_p95_s = self.latency_p95_s.max(o.latency_p95_s);
        }
        self.calib_cache_hits += o.calib_cache_hits;
        self.calib_cache_misses += o.calib_cache_misses;
        self.calib_cold_start_ms =
            self.calib_cold_start_ms.max(o.calib_cold_start_ms);
        self.enqueued += o.enqueued;
        self.dispatched += o.dispatched;
        self.purged += o.purged;
        self.pending += o.pending;
        self.requeued += o.requeued;
        self.nodes_lost += o.nodes_lost;
        self.nodes_readmitted += o.nodes_readmitted;
        self.reuse_hits += o.reuse_hits;
        self.steps_skipped += o.steps_skipped;
        self.uploads_saved += o.uploads_saved;
        for r in &o.rungs {
            let e = rung_entry(&mut self.rungs, r.rung);
            e.batches += r.batches;
            e.images += r.images;
            e.padded_slots += r.padded_slots;
            e.busy_s += r.busy_s;
        }
        for w in &o.workers {
            let mut w = w.clone();
            w.worker = self.workers.len();
            self.workers.push(w);
        }
    }
}

/// A per-worker generation backend. Backends are built inside the
/// worker's own thread (PJRT runtimes are not `Send`), so implementations
/// need not be `Send`.
pub trait GenBackend {
    /// Lowered batch dims this backend can execute (the batch ladder).
    /// Order and duplicates don't matter — the router validates and
    /// sorts; an empty or zero-rung ladder fails the worker's init.
    fn rungs(&self) -> Vec<usize>;
    /// Flat length of one image (H·W·C).
    fn img_len(&self) -> usize;
    /// Generate one batch for `labels`; `labels.len()` is always one
    /// of [`Self::rungs`] (the policy-chosen rung, padded with class-0
    /// slots).
    fn generate(&mut self, labels: &[i32]) -> Result<Vec<f32>>;
    /// Cumulative step-reuse counters over this backend's lifetime:
    /// `(reuse_hits, steps_skipped, uploads_saved)`. Polled after each
    /// successful batch; backends without a reuse layer keep the
    /// default all-zero report.
    fn reuse_counters(&self) -> (u64, u64, u64) {
        (0, 0, 0)
    }
}

/// Handed to each worker body on its own thread; [`WorkerHandle::serve`]
/// runs the dispatch loop with the backend the body built.
pub struct WorkerHandle {
    idx: usize,
    shared: Arc<Shared>,
}

impl WorkerHandle {
    /// This worker's index (stable, 0-based).
    pub fn index(&self) -> usize {
        self.idx
    }

    /// Run the dispatch loop with this worker's backend until shutdown
    /// (or until the backend fails a batch). `Err` only when the
    /// backend's ladder is invalid (caught before serving starts);
    /// generate failures are routed to the affected clients and return
    /// `Ok` after recording the worker dead.
    pub fn serve(&self, backend: &mut dyn GenBackend) -> Result<()> {
        worker_loop(self.idx, backend, &self.shared)
    }
}

/// Per-worker setup run on the worker's thread: build a backend on the
/// stack (runtime, sampler, rng, ...) and hand it to
/// [`WorkerHandle::serve`], which runs the dispatch loop until shutdown.
/// Returning `Err` *before* calling `serve` marks the worker
/// init-failed; the router keeps serving on the surviving workers.
pub type WorkerBody = dyn Fn(WorkerHandle) -> Result<()> + Send + Sync;

/// Router tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct RouterOpts {
    /// Worker threads (min 1).
    pub workers: usize,
    /// Backpressure: reject submits once this many image slots are
    /// queued (does not count slots already being computed).
    pub max_queue: usize,
    /// How long a partially-filled ladder rung may linger for more
    /// slots before dispatching padded. Zero (the default) dispatches
    /// immediately — with a one-rung ladder that is exactly the
    /// pre-ladder fixed-batch behavior.
    pub linger: Duration,
}

impl Default for RouterOpts {
    fn default() -> Self {
        RouterOpts {
            workers: 1,
            max_queue: 16384,
            linger: Duration::ZERO,
        }
    }
}

struct PendingReq {
    tx: Sender<GenResult>,
    /// Total images requested.
    n: usize,
    /// Lazily sized to n·img_len on first delivery; slots may complete
    /// out of order across workers, so each is written at `index`.
    images: Vec<f32>,
    remaining: usize,
    t0: Instant,
    /// This request's trace context: `trace.span` is the request root
    /// span every stage span parents under ([`TraceCtx::NONE`] when
    /// untraced).
    trace: TraceCtx,
    /// Span the request root itself parents under — the frontend's
    /// dispatch span when the request came over the wire, 0 locally.
    parent_span: u64,
    /// Submit time on the trace clock (0 when untraced).
    t0_ns: u64,
    /// The queue-wait span has been recorded (first dispatch of any
    /// of this request's slots closes it).
    queue_span_done: bool,
}

struct RouterState {
    open: bool,
    /// Workers that have not yet exited (includes ones still
    /// initializing, so early submits queue instead of failing).
    alive: usize,
    /// Workers whose backend is built and serving (readiness signal for
    /// benchmarks that want to time steady-state throughput only).
    ready: usize,
    batcher: Batcher,
    pending: HashMap<u64, PendingReq>,
    first_error: Option<ServeError>,
    requests: u64,
    failed_requests: u64,
    dropped_responses: u64,
    fill_sum: f64,
    /// Completed-request latency distribution (fixed-size buckets, so
    /// a long-lived server's memory stays flat).
    latency: LatencyHist,
    queue_depth_max: usize,
    depth_sum: f64,
    depth_samples: u64,
    workers: Vec<WorkerStats>,
}

impl RouterState {
    fn new(workers: usize) -> RouterState {
        RouterState {
            open: true,
            alive: workers,
            ready: 0,
            batcher: Batcher::new(),
            pending: HashMap::new(),
            first_error: None,
            requests: 0,
            failed_requests: 0,
            dropped_responses: 0,
            fill_sum: 0.0,
            latency: LatencyHist::new(),
            queue_depth_max: 0,
            depth_sum: 0.0,
            depth_samples: 0,
            workers: (0..workers)
                .map(|worker| WorkerStats { worker, ..WorkerStats::default() })
                .collect(),
        }
    }

    /// Route one computed batch (dispatched on a `rung`-slot artifact)
    /// back to its pending requests.
    fn deliver(&mut self, idx: usize, slots: &[Slot], imgs: &[f32],
               il: usize, rung: usize, busy_s: f64) {
        self.fill_sum += slots.len() as f64 / rung.max(1) as f64;
        let batch_ctx =
            slots.first().map(|s| s.trace).unwrap_or(TraceCtx::NONE);
        let encode_start = if batch_ctx.is_active() {
            trace::now_ns()
        } else {
            0
        };
        // counted per delivered slot, not per batch: slots computed for
        // requests that already failed elsewhere are not images
        let mut delivered = 0u64;
        // channel sends are deferred until every span of this batch
        // (including Encode, below) is in the ring: a shard node
        // snapshots `spans_for_trace` the moment the receiver wakes,
        // and must not race the tail of this very function
        let mut completed = Vec::new();
        for (i, s) in slots.iter().enumerate() {
            // a missing entry means the request already failed elsewhere
            let Some(p) = self.pending.get_mut(&s.req_id) else { continue };
            if p.images.is_empty() {
                p.images = vec![0.0; p.n * il];
            }
            p.images[s.index * il..(s.index + 1) * il]
                .copy_from_slice(&imgs[i * il..(i + 1) * il]);
            p.remaining -= 1;
            delivered += 1;
            if p.remaining == 0 {
                // the entry was live two lines up, so `remove` cannot
                // miss — but a protocol bug here must degrade one
                // request, not panic the worker thread that holds the
                // router lock
                let Some(done) = self.pending.remove(&s.req_id) else {
                    crate::warn_log!(
                        "serve: request {} completed with no pending \
                         entry (protocol bug); dropping its response",
                        s.req_id
                    );
                    self.failed_requests += 1;
                    continue;
                };
                let latency_s = done.t0.elapsed().as_secs_f64();
                self.latency.record(latency_s);
                if done.trace.is_active() {
                    // close the request root span under the parent
                    // the submitter supplied (the frontend's dispatch
                    // span for a clustered request, 0 locally)
                    trace::record(SpanRec {
                        trace: done.trace.trace,
                        span: done.trace.span,
                        parent: done.parent_span,
                        kind: SpanKind::Request,
                        start_ns: done.t0_ns,
                        dur_ns: trace::now_ns()
                            .saturating_sub(done.t0_ns),
                        a: 0,
                        b: done.n as u64,
                    });
                }
                let resp = GenResponse {
                    id: s.req_id,
                    images: done.images,
                    latency_s,
                };
                completed.push((done.tx, resp));
            }
        }
        if batch_ctx.is_active() {
            trace::record_span(batch_ctx, SpanKind::Encode,
                               encode_start, trace::now_ns(),
                               delivered, slots.len() as u64);
        }
        for (tx, resp) in completed {
            if tx.send(Ok(resp)).is_err() {
                // client hung up its receiver: drop cleanly
                self.dropped_responses += 1;
            }
        }
        let padded = (rung - slots.len()) as u64;
        let w = &mut self.workers[idx];
        w.batches += 1;
        w.padded_slots += padded;
        w.busy_s += busy_s;
        w.images += delivered;
        let r = rung_entry(&mut w.rungs, rung);
        r.batches += 1;
        r.padded_slots += padded;
        r.busy_s += busy_s;
        r.images += delivered;
    }

    /// Fail every request with a slot in this batch; purge their queued
    /// remainder so other workers don't burn capacity on them.
    fn fail_batch(&mut self, idx: usize, slots: &[Slot], cause: &str) {
        self.workers[idx].failed = true;
        for s in slots {
            if let Some(p) = self.pending.remove(&s.req_id) {
                self.failed_requests += 1;
                self.batcher.drop_request(s.req_id);
                let _ = p.tx.send(Err(ServeError::WorkerFailed {
                    worker: idx,
                    cause: cause.to_string(),
                }));
            }
        }
        if self.first_error.is_none() {
            self.first_error = Some(ServeError::WorkerFailed {
                worker: idx,
                cause: cause.to_string(),
            });
        }
    }

    fn note_depth(&mut self) {
        let depth = self.batcher.pending();
        self.queue_depth_max = self.queue_depth_max.max(depth);
        self.depth_sum += depth as f64;
        self.depth_samples += 1;
    }

    /// Record one `Queue` span per traced request whose *first* slots
    /// just left the batcher: submit → first dispatch, parented under
    /// that request's root span. A request split across batches only
    /// gets the span once (`queue_span_done`); later slots of the same
    /// request waited on compute, not the queue.
    fn note_dequeue_spans(&mut self, slots: &[Slot], now_ns: u64) {
        let mut prev_req = None;
        for s in slots {
            if prev_req == Some(s.req_id) || !s.trace.is_active() {
                continue;
            }
            prev_req = Some(s.req_id);
            let Some(p) = self.pending.get_mut(&s.req_id) else {
                continue;
            };
            if p.queue_span_done {
                continue;
            }
            p.queue_span_done = true;
            trace::record_span(p.trace, SpanKind::Queue, p.t0_ns,
                               now_ns, p.n as u64, 0);
        }
    }

    /// Fail and remove every pending request with a clone of `err`.
    fn fail_all_pending(&mut self, err: &ServeError) {
        let stranded: Vec<PendingReq> =
            self.pending.drain().map(|(_, p)| p).collect();
        self.failed_requests += stranded.len() as u64;
        for p in stranded {
            let _ = p.tx.send(Err(err.clone()));
        }
    }

    /// Cause attached to dead-service errors: the first recorded
    /// failure, or a generic note when workers exited cleanly.
    fn dead_cause(&self) -> String {
        self.first_error
            .as_ref()
            .map(|e| e.to_string())
            .unwrap_or_else(|| "all workers exited".into())
    }

    /// Build a [`ServerStats`] view of the current state (shared by
    /// the live snapshot and the post-drain shutdown path). The
    /// remote stats protocol calls this on every heartbeat; the
    /// quantile walk over the histogram's fixed bucket array is O(512)
    /// regardless of traffic, so computing percentiles under the state
    /// lock cannot stall submits, deliveries or the inline pong path
    /// the way sorting an unbounded sample window would.
    fn assemble_stats(&self, wall_s: f64) -> ServerStats {
        let batches: u64 = self.workers.iter().map(|w| w.batches).sum();
        let images: u64 = self.workers.iter().map(|w| w.images).sum();
        let padded: u64 =
            self.workers.iter().map(|w| w.padded_slots).sum();
        let reuse_hits: u64 =
            self.workers.iter().map(|w| w.reuse_hits).sum();
        let steps_skipped: u64 =
            self.workers.iter().map(|w| w.steps_skipped).sum();
        let uploads_saved: u64 =
            self.workers.iter().map(|w| w.uploads_saved).sum();
        let mut rungs: Vec<RungStats> = Vec::new();
        for w in &self.workers {
            for r in &w.rungs {
                let e = rung_entry(&mut rungs, r.rung);
                e.batches += r.batches;
                e.images += r.images;
                e.padded_slots += r.padded_slots;
                e.busy_s += r.busy_s;
            }
        }
        let counters = self.batcher.counters();
        let stats = ServerStats {
            requests: self.requests,
            images,
            batches,
            batch_fill: if batches > 0 {
                self.fill_sum / batches as f64
            } else {
                0.0
            },
            padded_slots: padded,
            failed_requests: self.failed_requests,
            dropped_responses: self.dropped_responses,
            wall_s,
            queue_depth_avg: if self.depth_samples > 0 {
                self.depth_sum / self.depth_samples as f64
            } else {
                0.0
            },
            queue_depth_max: self.queue_depth_max,
            latency_p50_s: self.latency.quantile(0.50),
            latency_p95_s: self.latency.quantile(0.95),
            latency: self.latency.clone(),
            calib_cache_hits: 0,
            calib_cache_misses: 0,
            calib_cold_start_ms: 0.0,
            enqueued: counters.enqueued,
            dispatched: counters.dispatched,
            purged: counters.purged,
            pending: self.batcher.pending() as u64,
            requeued: 0,
            nodes_lost: 0,
            nodes_readmitted: 0,
            reuse_hits,
            steps_skipped,
            uploads_saved,
            rungs,
            workers: self.workers.clone(),
        }
    }
}

struct Shared {
    state: Mutex<RouterState>,
    /// Signaled on submit, shutdown, and worker exit (lingering
    /// workers additionally wake on their own deadline timeout).
    work_ready: Condvar,
    /// Deadline-aware dispatch policy every worker consults.
    policy: BatchPolicy,
}

impl Shared {
    /// Lock the state, recovering from poisoning: a worker that
    /// panicked mid-update must not turn every later `submit` into a
    /// panic — the counters may be slightly stale, but clients keep
    /// getting typed errors instead.
    fn lock(&self) -> std::sync::MutexGuard<'_, RouterState> {
        crate::util::lock(&self.state)
    }

    /// Worker bookkeeping on thread exit; if this was the last worker,
    /// fail everything still queued so no client hangs.
    fn worker_exited(&self, idx: usize, init_err: Option<String>) {
        let mut st = self.lock();
        st.workers[idx].failed |= init_err.is_some();
        st.alive -= 1;
        if st.workers[idx].ready {
            // no longer serving (the per-worker flag stays set as the
            // historical "came up" marker)
            st.ready -= 1;
        }
        if let Some(cause) = init_err {
            crate::warn_log!("worker {idx} failed: {cause}");
            if st.first_error.is_none() {
                st.first_error =
                    Some(ServeError::WorkerInitFailed { worker: idx, cause });
            }
        }
        if st.alive == 0 && !st.pending.is_empty() {
            let err = ServeError::AllWorkersDead { cause: st.dead_cause() };
            st.batcher.clear();
            st.fail_all_pending(&err);
        }
        drop(st);
        self.work_ready.notify_all();
    }
}

/// Handle to the sharded generation service. `Sync`: any number of
/// client threads may `submit` through one shared reference.
pub struct Router {
    shared: Arc<Shared>,
    next_id: AtomicU64,
    handles: Vec<JoinHandle<()>>,
    t_start: Instant,
    max_queue: usize,
}

impl Router {
    /// Spawn `opts.workers` threads, each running `body` to build its
    /// backend and then serving batches until shutdown.
    pub fn start(opts: RouterOpts, body: Arc<WorkerBody>) -> Router {
        let workers = opts.workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(RouterState::new(workers)),
            work_ready: Condvar::new(),
            policy: BatchPolicy::new(opts.linger),
        });
        let mut handles = Vec::with_capacity(workers);
        for idx in 0..workers {
            let on_spawn_err = Arc::clone(&shared);
            let shared = Arc::clone(&shared);
            let body = Arc::clone(&body);
            let spawned = std::thread::Builder::new()
                .name(format!("gen-worker-{idx}"))
                .spawn(move || {
                    let handle = WorkerHandle {
                        idx,
                        shared: Arc::clone(&shared),
                    };
                    // a panicking body must still be recorded as a dead
                    // worker, or waiting clients would hang forever
                    let err = match std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| body(handle)),
                    ) {
                        Ok(Ok(())) => None,
                        Ok(Err(e)) => Some(format!("{e:#}")),
                        Err(p) => Some(panic_message(&p)),
                    };
                    shared.worker_exited(idx, err);
                });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // a worker that never got a thread is a dead worker
                    // with a typed cause (clients see WorkerInitFailed /
                    // AllWorkersDead), not a process abort
                    crate::warn_log!(
                        "router: spawning gen-worker-{idx} failed: {e}");
                    on_spawn_err.worker_exited(
                        idx,
                        Some(format!("thread spawn failed: {e}")),
                    );
                }
            }
        }
        Router {
            shared,
            next_id: AtomicU64::new(0),
            handles,
            t_start: Instant::now(),
            max_queue: opts.max_queue,
        }
    }

    /// Submit a request; returns (id, receiver yielding the response or
    /// a typed error). Rejects (instead of queuing forever) when the
    /// service is shutting down, dead, or over its queue cap. Mints a
    /// fresh trace for the request (a no-op id when `--trace` is off).
    pub fn submit(&self, req: GenRequest)
                  -> std::result::Result<(u64, Receiver<GenResult>),
                                         ServeError> {
        self.submit_traced(req, trace::mint())
    }

    /// [`Self::submit`] under an externally minted trace context:
    /// `parent.trace` keys the request's spans and `parent.span` is
    /// what its root `Request` span parents under (a shard node passes
    /// the frontend's `Dispatch` span, stitching both hosts into one
    /// timeline). The router pre-mints the root span id here so every
    /// stage span recorded while the request is in flight can hang off
    /// it; the root itself is recorded at completion in `deliver`.
    pub fn submit_traced(&self, req: GenRequest, parent: TraceCtx)
                         -> std::result::Result<(u64, Receiver<GenResult>),
                                                ServeError> {
        let ctx = if parent.is_active() {
            TraceCtx { trace: parent.trace, span: trace::next_id() }
        } else {
            TraceCtx::NONE
        };
        let mut st = self.shared.lock();
        if !st.open {
            return Err(ServeError::ShuttingDown);
        }
        if st.alive == 0 {
            return Err(ServeError::AllWorkersDead {
                cause: st.dead_cause(),
            });
        }
        if req.n > self.max_queue {
            // could never fit even in an empty queue — not transient
            return Err(ServeError::RequestTooLarge {
                n: req.n,
                cap: self.max_queue,
            });
        }
        let queued = st.batcher.pending();
        if queued + req.n > self.max_queue {
            return Err(ServeError::QueueFull {
                queued,
                cap: self.max_queue,
            });
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        st.requests += 1;
        let (tx, rx) = channel();
        if req.n == 0 {
            // nothing to compute: complete immediately
            let _ = tx.send(Ok(GenResponse {
                id,
                images: Vec::new(),
                latency_s: 0.0,
            }));
            return Ok((id, rx));
        }
        st.pending.insert(id, PendingReq {
            tx,
            n: req.n,
            images: Vec::new(),
            remaining: req.n,
            t0: Instant::now(),
            trace: ctx,
            parent_span: parent.span,
            t0_ns: if ctx.is_active() { trace::now_ns() } else { 0 },
            queue_span_done: false,
        });
        st.batcher.push_request_traced(id, req.class, req.n, ctx);
        drop(st);
        self.shared.work_ready.notify_all();
        Ok((id, rx))
    }

    /// Image slots currently queued (not yet picked up by a worker).
    pub fn queue_depth(&self) -> usize {
        self.shared.lock().batcher.pending()
    }

    /// Workers that have not exited.
    pub fn live_workers(&self) -> usize {
        self.shared.lock().alive
    }

    /// Workers whose backend is built and currently serving (exited
    /// workers no longer count). Benchmarks wait until
    /// `ready_workers() == live_workers()` before timing so startup
    /// cost stays out of steady-state numbers.
    pub fn ready_workers(&self) -> usize {
        self.shared.lock().ready
    }

    /// Live statistics snapshot (counters so far, latency percentiles
    /// over the completed-request window, current queue depth as
    /// `pending`). The remote stats protocol serves this without
    /// stopping the service.
    pub fn stats(&self) -> ServerStats {
        self.shared
            .lock()
            .assemble_stats(self.t_start.elapsed().as_secs_f64())
    }

    /// Stop accepting requests, drain the queue, join the workers and
    /// return aggregate + per-worker statistics.
    pub fn shutdown(mut self) -> ServerStats {
        {
            let mut st = self.shared.lock();
            st.open = false;
        }
        self.shared.work_ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let mut st = self.shared.lock();
        // belt & braces: nothing should survive the drain, but never
        // strand a client if it does
        if !st.pending.is_empty() {
            st.fail_all_pending(&ServeError::ShuttingDown);
        }
        st.assemble_stats(self.t_start.elapsed().as_secs_f64())
    }
}

impl crate::serve::dispatch::Dispatch for Router {
    fn submit(&self, req: GenRequest)
              -> std::result::Result<(u64, Receiver<GenResult>),
                                     ServeError> {
        Router::submit(self, req)
    }
    fn submit_traced(&self, req: GenRequest, parent: TraceCtx)
                     -> std::result::Result<(u64, Receiver<GenResult>),
                                            ServeError> {
        Router::submit_traced(self, req, parent)
    }
    fn queue_depth(&self) -> usize {
        Router::queue_depth(self)
    }
    fn live_workers(&self) -> usize {
        Router::live_workers(self)
    }
    fn ready_workers(&self) -> usize {
        Router::ready_workers(self)
    }
    fn stats(&self) -> ServerStats {
        Router::stats(self)
    }
    fn shutdown(self: Box<Self>) -> ServerStats {
        Router::shutdown(*self)
    }
}

impl Drop for Router {
    /// A router dropped without `shutdown` still stops and joins its
    /// workers (draining the queue first) so no thread spins forever.
    fn drop(&mut self) {
        self.shared.lock().open = false;
        self.shared.work_ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Best-effort panic payload → message.
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("worker panicked: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("worker panicked: {s}")
    } else {
        "worker panicked".to_string()
    }
}

/// The dispatch loop every worker runs: consult the batch policy for
/// the oldest work (wait for fill, or pop now and pad to the chosen
/// ladder rung), generate, route results (or typed errors) back.
/// Returns on shutdown-with-empty-queue or after a generate failure
/// (the worker is assumed poisoned); `Err` only for an invalid backend
/// ladder, surfaced before the worker ever marks itself ready.
fn worker_loop(idx: usize, backend: &mut dyn GenBackend, shared: &Shared)
               -> Result<()> {
    let ladder =
        Ladder::new(backend.rungs()).context("backend batch ladder")?;
    let il = backend.img_len();
    {
        let mut st = shared.lock();
        st.ready += 1;
        st.workers[idx].ready = true;
    }
    loop {
        let (slots, rung, batch_ctx) = {
            let mut st = shared.lock();
            // set at the first Wait so the dispatched batch can record
            // how long it lingered for fill (only stamped when tracing
            // is on — off, the whole path stays clock-call free)
            let mut linger_from: Option<u64> = None;
            loop {
                if st.batcher.is_empty() {
                    if !st.open {
                        return Ok(());
                    }
                    st = shared
                        .work_ready
                        .wait(st)
                        .unwrap_or_else(|p| p.into_inner());
                    continue;
                }
                let pending = st.batcher.pending();
                let waited = st
                    .batcher
                    .oldest_wait(Instant::now())
                    .unwrap_or_default();
                // draining (shutdown) flushes partial rungs immediately
                match shared.policy.plan(&ladder, pending, waited,
                                         !st.open) {
                    BatchPlan::Dispatch { rung, take } => {
                        st.note_depth();
                        let slots = st.batcher.take(take);
                        let ctx = slots
                            .first()
                            .map(|s| s.trace)
                            .unwrap_or(TraceCtx::NONE);
                        if ctx.is_active() {
                            let now = trace::now_ns();
                            st.note_dequeue_spans(&slots, now);
                            if let Some(from) = linger_from {
                                trace::record_span(
                                    ctx, SpanKind::Linger, from, now,
                                    pending as u64, 0);
                            }
                            trace::record_span(
                                ctx, SpanKind::RungPick, now, now,
                                rung as u64, slots.len() as u64);
                        }
                        break (slots, rung, ctx);
                    }
                    BatchPlan::Wait { remaining } => {
                        if linger_from.is_none() && trace::tracing_on() {
                            linger_from = Some(trace::now_ns());
                        }
                        // park until the linger deadline; new submits
                        // and shutdown notify the condvar to re-plan
                        // earlier
                        let (g, _) = shared
                            .work_ready
                            .wait_timeout(st, remaining)
                            .unwrap_or_else(|p| p.into_inner());
                        st = g;
                    }
                }
            }
        };
        debug_assert!(!slots.is_empty());

        // pad the chosen rung's artifact batch with class-0 slots
        let mut labels = vec![0i32; rung];
        for (i, s) in slots.iter().enumerate() {
            labels[i] = s.class;
        }
        // pre-mint the Generate span's id and publish it as the
        // thread's current context, so the sampler's per-group step
        // spans (recorded *during* the call) parent under it; the span
        // itself is recorded once the duration is known
        let gen_ctx = if batch_ctx.is_active() {
            TraceCtx { trace: batch_ctx.trace, span: trace::next_id() }
        } else {
            TraceCtx::NONE
        };
        let gen_start =
            if gen_ctx.is_active() { trace::now_ns() } else { 0 };
        let t0 = Instant::now();
        // a panicking backend fails its batch like an `Err` (then the
        // panic resumes and the worker is recorded dead) — the clients
        // in this batch must never be stranded
        let result = {
            let _cur = trace::CurrentGuard::enter(gen_ctx);
            std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| backend.generate(&labels)),
            )
        };
        let busy_s = t0.elapsed().as_secs_f64();
        if gen_ctx.is_active() {
            trace::record(SpanRec {
                trace: gen_ctx.trace,
                span: gen_ctx.span,
                parent: batch_ctx.span,
                kind: SpanKind::Generate,
                start_ns: gen_start,
                dur_ns: trace::now_ns().saturating_sub(gen_start),
                a: rung as u64,
                b: slots.len() as u64,
            });
        }

        let mut st = shared.lock();
        match result {
            // a backend returning a short/oversized buffer would panic
            // copy_from_slice mid-delivery and strand the whole batch;
            // treat the broken contract like a generate failure instead
            Ok(Ok(imgs)) if imgs.len() == rung * il => {
                st.deliver(idx, &slots, &imgs, il, rung, busy_s);
                // cumulative totals, stored absolute (not accumulated
                // here) so a re-poll can never double-count
                let (hits, skipped, saved) = backend.reuse_counters();
                let w = &mut st.workers[idx];
                w.reuse_hits = hits;
                w.steps_skipped = skipped;
                w.uploads_saved = saved;
            }
            Ok(Ok(imgs)) => {
                st.fail_batch(idx, &slots, &format!(
                    "backend returned {} pixels for a {rung}-slot batch \
                     (expected {})",
                    imgs.len(), rung * il));
                return Ok(());
            }
            Ok(Err(e)) => {
                st.fail_batch(idx, &slots, &format!("{e:#}"));
                return Ok(());
            }
            Err(p) => {
                st.fail_batch(idx, &slots, &panic_message(&p));
                drop(st);
                std::panic::resume_unwind(p);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::mpsc;
    use std::time::Duration;

    /// Backend whose pixels all equal the slot's class label, so tests
    /// can verify slot→request routing end to end.
    struct MockBackend {
        rungs: Vec<usize>,
        il: usize,
        calls: usize,
        fail_after: Option<usize>,
        panic_after: Option<usize>,
        /// Return a buffer one pixel short from this call on (contract
        /// violation).
        short_after: Option<usize>,
        log: Option<Arc<Mutex<Vec<i32>>>>,
        /// Log of dispatched rung sizes (labels.len() per call).
        rung_log: Option<Arc<Mutex<Vec<usize>>>>,
    }

    impl MockBackend {
        fn new(batch: usize, il: usize) -> MockBackend {
            MockBackend::ladder(vec![batch], il)
        }

        fn ladder(rungs: Vec<usize>, il: usize) -> MockBackend {
            MockBackend {
                rungs,
                il,
                calls: 0,
                fail_after: None,
                panic_after: None,
                short_after: None,
                log: None,
                rung_log: None,
            }
        }
    }

    impl GenBackend for MockBackend {
        fn rungs(&self) -> Vec<usize> {
            self.rungs.clone()
        }
        fn img_len(&self) -> usize {
            self.il
        }
        fn generate(&mut self, labels: &[i32]) -> Result<Vec<f32>> {
            assert!(
                self.rungs.contains(&labels.len()),
                "dispatched {} labels but the lowered rungs are {:?}",
                labels.len(), self.rungs
            );
            if let Some(after) = self.fail_after {
                if self.calls >= after {
                    anyhow::bail!("injected failure on call {}", self.calls);
                }
            }
            if let Some(after) = self.panic_after {
                if self.calls >= after {
                    panic!("injected panic on call {}", self.calls);
                }
            }
            if let Some(after) = self.short_after {
                if self.calls >= after {
                    self.calls += 1;
                    return Ok(vec![0.0; labels.len() * self.il - 1]);
                }
            }
            self.calls += 1;
            if let Some(log) = &self.log {
                log.lock().unwrap().extend_from_slice(labels);
            }
            if let Some(rl) = &self.rung_log {
                rl.lock().unwrap().push(labels.len());
            }
            Ok(labels
                .iter()
                .flat_map(|&c| std::iter::repeat(c as f32).take(self.il))
                .collect())
        }
    }

    fn mock_router(workers: usize, batch: usize, il: usize) -> Router {
        let body: Arc<WorkerBody> = Arc::new(move |h: WorkerHandle| -> Result<()> {
            let mut b = MockBackend::new(batch, il);
            h.serve(&mut b)
        });
        Router::start(RouterOpts { workers, ..RouterOpts::default() }, body)
    }

    fn mock_ladder_router(workers: usize, rungs: Vec<usize>, il: usize,
                          linger: Duration) -> Router {
        let body: Arc<WorkerBody> =
            Arc::new(move |h: WorkerHandle| -> Result<()> {
                let mut b = MockBackend::ladder(rungs.clone(), il);
                h.serve(&mut b)
            });
        Router::start(
            RouterOpts { workers, linger, ..RouterOpts::default() },
            body,
        )
    }

    #[test]
    fn single_worker_serves_one_request() {
        let router = mock_router(1, 4, 3);
        let (id, rx) = router.submit(GenRequest { class: 5, n: 2 }).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, id);
        assert_eq!(resp.images.len(), 2 * 3);
        assert!(resp.images.iter().all(|&v| v == 5.0));
        let stats = router.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.images, 2);
        assert_eq!(stats.failed_requests, 0);
    }

    #[test]
    fn zero_image_request_completes_immediately() {
        let router = mock_router(1, 4, 3);
        let (id, rx) = router.submit(GenRequest { class: 1, n: 0 }).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, id);
        assert!(resp.images.is_empty());
        let stats = router.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.images, 0);
    }

    #[test]
    fn concurrent_clients_get_exact_pixels_back() {
        let router = mock_router(4, 4, 3);
        let expected = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for c in 0..6usize {
                let router = &router;
                let expected = &expected;
                s.spawn(move || {
                    for i in 0..5usize {
                        let class = ((c + i) % 7) as i32;
                        let n = 1 + (c * 3 + i) % 9;
                        expected.fetch_add(n, Ordering::Relaxed);
                        let (_, rx) = router
                            .submit(GenRequest { class, n })
                            .unwrap();
                        let resp = rx.recv().unwrap().unwrap();
                        assert_eq!(resp.images.len(), n * 3);
                        assert!(
                            resp.images.iter().all(|&v| v == class as f32),
                            "cross-request pixel mixup for class {class}"
                        );
                        assert!(resp.latency_s >= 0.0);
                    }
                });
            }
        });
        let stats = router.shutdown();
        assert_eq!(stats.requests, 30);
        assert_eq!(stats.failed_requests, 0);
        assert_eq!(stats.images as usize,
                   expected.load(Ordering::Relaxed));
        assert_eq!(stats.workers.len(), 4);
    }

    #[test]
    fn fifo_order_holds_per_worker() {
        // batch=1 and one worker: dispatch order must equal submit order
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = Arc::clone(&log);
        let body: Arc<WorkerBody> = Arc::new(move |h: WorkerHandle| -> Result<()> {
            let mut b = MockBackend::new(1, 2);
            b.log = Some(Arc::clone(&log2));
            h.serve(&mut b)
        });
        let router =
            Router::start(RouterOpts { workers: 1, ..Default::default() },
                          body);
        let mut rxs = Vec::new();
        for class in 10..20 {
            rxs.push(router.submit(GenRequest { class, n: 1 }).unwrap().1);
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        router.shutdown();
        let seen = log.lock().unwrap().clone();
        assert_eq!(seen, (10..20).collect::<Vec<i32>>());
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        let router = mock_router(2, 3, 2);
        let mut rxs = Vec::new();
        let mut total = 0usize;
        for i in 0..10usize {
            let n = 1 + i % 5;
            total += n;
            rxs.push(
                router
                    .submit(GenRequest { class: i as i32, n })
                    .unwrap()
                    .1,
            );
        }
        // shut down immediately: the queue must still drain
        let stats = router.shutdown();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        assert_eq!(stats.images as usize, total);
        assert_eq!(stats.failed_requests, 0);
    }

    #[test]
    fn padding_is_accounted_separately_from_real_work() {
        let router = mock_router(1, 8, 2);
        let (_, rx) = router.submit(GenRequest { class: 2, n: 3 }).unwrap();
        rx.recv().unwrap().unwrap();
        let stats = router.shutdown();
        assert_eq!(stats.images, 3);
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.padded_slots, 5);
        assert!((stats.batch_fill - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn ladder_dispatch_picks_smallest_covering_rung() {
        let rung_log = Arc::new(Mutex::new(Vec::new()));
        let rl = Arc::clone(&rung_log);
        let body: Arc<WorkerBody> =
            Arc::new(move |h: WorkerHandle| -> Result<()> {
                let mut b = MockBackend::ladder(vec![1, 2, 4], 3);
                b.rung_log = Some(Arc::clone(&rl));
                h.serve(&mut b)
            });
        let router =
            Router::start(RouterOpts { workers: 1, ..Default::default() },
                          body);
        // serialize: wait for each response so every dispatch sees
        // exactly one queued request of known size
        for n in [1usize, 2, 3, 4] {
            let (_, rx) = router.submit(GenRequest { class: 5, n }).unwrap();
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.images.len(), n * 3);
            assert!(resp.images.iter().all(|&v| v == 5.0));
        }
        let stats = router.shutdown();
        // 1 and 2 ride their exact rungs; 3 pads the covering 4-rung;
        // 4 fills the top rung exactly
        assert_eq!(rung_log.lock().unwrap().clone(), vec![1, 2, 4, 4]);
        assert_eq!(stats.images, 10);
        assert_eq!(stats.padded_slots, 1);
        assert_eq!(stats.rungs.len(), 3);
        assert_eq!((stats.rungs[0].rung, stats.rungs[0].batches), (1, 1));
        assert_eq!((stats.rungs[1].rung, stats.rungs[1].batches), (2, 1));
        assert_eq!((stats.rungs[2].rung, stats.rungs[2].batches), (4, 2));
        assert_eq!(stats.rungs[2].padded_slots, 1);
        assert!((stats.rungs[2].fill() - 7.0 / 8.0).abs() < 1e-12);
        // fill is normalized per dispatched rung: mean of 1, 1, 3/4, 1
        assert!((stats.batch_fill - 3.75 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn mixed_rung_split_of_one_large_request() {
        // 7 slots over a 1/2/4 ladder, one worker: the top rung fills
        // first, then the remainder dispatches on its covering rung
        let router =
            mock_ladder_router(1, vec![1, 2, 4], 2, Duration::ZERO);
        let (_, rx) = router.submit(GenRequest { class: 3, n: 7 }).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.images.len(), 7 * 2);
        assert!(resp.images.iter().all(|&v| v == 3.0));
        let stats = router.shutdown();
        assert_eq!(stats.batches, 2);
        assert_eq!(stats.images, 7);
        assert_eq!(stats.padded_slots, 1);
    }

    #[test]
    fn linger_holds_partial_rung_until_burst_fills_it() {
        // long linger: a 3-slot request (no exact rung) holds; a 5-slot
        // burst completes the full top rung and releases it unpadded
        let router = mock_ladder_router(1, vec![2, 8], 2,
                                        Duration::from_secs(30));
        let (_, rx_a) = router.submit(GenRequest { class: 1, n: 3 }).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let (_, rx_b) = router.submit(GenRequest { class: 2, n: 5 }).unwrap();
        let resp_a = rx_a.recv().unwrap().unwrap();
        let resp_b = rx_b.recv().unwrap().unwrap();
        assert!(resp_a.images.iter().all(|&v| v == 1.0));
        assert!(resp_b.images.iter().all(|&v| v == 2.0));
        let stats = router.shutdown();
        assert_eq!(stats.batches, 1, "one full 8-rung dispatch");
        assert_eq!(stats.padded_slots, 0);
        assert_eq!(stats.rungs.len(), 1);
        assert_eq!(stats.rungs[0].rung, 8);
    }

    #[test]
    fn linger_deadline_dispatches_padded_rung() {
        // nothing else arrives, so the deadline pads the smallest
        // covering rung — but never before the linger has elapsed
        let linger = Duration::from_millis(40);
        let router = mock_ladder_router(1, vec![4, 8], 2, linger);
        let t0 = Instant::now();
        let (_, rx) = router.submit(GenRequest { class: 6, n: 3 }).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert!(t0.elapsed() >= linger, "dispatched before the deadline");
        assert_eq!(resp.images.len(), 3 * 2);
        let stats = router.shutdown();
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.padded_slots, 1);
        assert_eq!(stats.rungs.len(), 1);
        assert_eq!(stats.rungs[0].rung, 4);
    }

    #[test]
    fn shutdown_flushes_lingering_partial_rung() {
        // draining ignores the linger deadline: shutdown must not sit
        // out a 30s window to flush a partial rung
        let router =
            mock_ladder_router(1, vec![4], 2, Duration::from_secs(30));
        let (_, rx) = router.submit(GenRequest { class: 2, n: 3 }).unwrap();
        let stats = router.shutdown();
        assert!(rx.recv().unwrap().is_ok());
        assert_eq!(stats.images, 3);
        assert_eq!(stats.padded_slots, 1);
    }

    #[test]
    fn worker_failure_mid_rung_propagates_typed_errors() {
        // first (full-rung) dispatch delivers; the second, smaller rung
        // fails — its client gets a typed WorkerFailed, nothing hangs
        let body: Arc<WorkerBody> = Arc::new(|h: WorkerHandle| -> Result<()> {
            let mut b = MockBackend::ladder(vec![2, 4], 2);
            b.fail_after = Some(1);
            h.serve(&mut b)
        });
        let router =
            Router::start(RouterOpts { workers: 1, ..Default::default() },
                          body);
        let (_, rx_a) = router.submit(GenRequest { class: 1, n: 4 }).unwrap();
        let resp_a = rx_a.recv().unwrap().unwrap();
        assert_eq!(resp_a.images.len(), 4 * 2);
        let (_, rx_b) = router.submit(GenRequest { class: 2, n: 1 }).unwrap();
        match rx_b.recv().unwrap() {
            Err(ServeError::WorkerFailed { worker: 0, cause }) => {
                assert!(cause.contains("injected failure"), "{cause}");
            }
            other => panic!("expected WorkerFailed, got {other:?}"),
        }
        let stats = router.shutdown();
        assert!(stats.workers[0].failed);
        assert_eq!(stats.images, 4);
        assert_eq!(stats.failed_requests, 1);
    }

    #[test]
    fn invalid_backend_ladder_fails_worker_init() {
        let body: Arc<WorkerBody> = Arc::new(|h: WorkerHandle| -> Result<()> {
            let mut b = MockBackend::ladder(vec![], 2);
            h.serve(&mut b)
        });
        let router =
            Router::start(RouterOpts { workers: 1, ..Default::default() },
                          body);
        loop {
            match router.submit(GenRequest { class: 0, n: 1 }) {
                Err(ServeError::AllWorkersDead { cause }) => {
                    assert!(cause.contains("ladder"), "{cause}");
                    break;
                }
                Err(other) => panic!("unexpected reject: {other}"),
                Ok((_, rx)) => {
                    assert!(rx.recv().unwrap().is_err());
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        router.shutdown();
    }

    #[test]
    fn hung_up_client_is_dropped_cleanly() {
        let router = mock_router(1, 2, 2);
        let (_, rx) = router.submit(GenRequest { class: 1, n: 1 }).unwrap();
        drop(rx); // client goes away before its response lands
        let (_, rx2) = router.submit(GenRequest { class: 2, n: 1 }).unwrap();
        rx2.recv().unwrap().unwrap();
        let stats = router.shutdown();
        assert_eq!(stats.dropped_responses, 1);
        assert_eq!(stats.images, 2);
        assert_eq!(stats.failed_requests, 0);
    }

    #[test]
    fn generate_failure_propagates_and_kills_no_client() {
        let body: Arc<WorkerBody> = Arc::new(|h: WorkerHandle| -> Result<()> {
            let mut b = MockBackend::new(4, 2);
            b.fail_after = Some(0);
            h.serve(&mut b)
        });
        let router =
            Router::start(RouterOpts { workers: 1, ..Default::default() },
                          body);
        let (_, rx) = router.submit(GenRequest { class: 3, n: 2 }).unwrap();
        match rx.recv().unwrap() {
            Err(ServeError::WorkerFailed { worker: 0, cause }) => {
                assert!(cause.contains("injected failure"), "{cause}");
            }
            other => panic!("expected WorkerFailed, got {other:?}"),
        }
        // the lone worker is dead: submits must fail fast, not hang
        loop {
            match router.submit(GenRequest { class: 0, n: 1 }) {
                Err(ServeError::AllWorkersDead { .. }) => break,
                Err(other) => panic!("unexpected reject: {other}"),
                Ok((_, rx2)) => {
                    // raced the dying worker; the request must still fail
                    assert!(rx2.recv().unwrap().is_err());
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        let stats = router.shutdown();
        assert!(stats.failed_requests >= 1);
        assert!(stats.workers[0].failed);
    }

    #[test]
    fn init_failure_surfaces_typed_errors_not_hangs() {
        let body: Arc<WorkerBody> = Arc::new(|h: WorkerHandle| -> Result<()> {
            anyhow::bail!("worker {}: artifacts missing", h.index())
        });
        let router =
            Router::start(RouterOpts { workers: 2, ..Default::default() },
                          body);
        loop {
            match router.submit(GenRequest { class: 0, n: 1 }) {
                Err(ServeError::AllWorkersDead { cause }) => {
                    assert!(cause.contains("artifacts missing"), "{cause}");
                    break;
                }
                Err(other) => panic!("unexpected reject: {other}"),
                Ok((_, rx)) => {
                    // submitted before the workers finished dying: the
                    // queued request must be failed, not stranded
                    assert!(rx.recv().unwrap().is_err());
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        let stats = router.shutdown();
        assert!(stats.workers.iter().all(|w| w.failed));
    }

    #[test]
    fn one_dead_worker_does_not_stop_the_service() {
        let fails = Arc::new(AtomicUsize::new(0));
        let fails2 = Arc::clone(&fails);
        let body: Arc<WorkerBody> = Arc::new(move |h: WorkerHandle| -> Result<()> {
            if h.index() == 0 {
                fails2.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("worker 0 init exploded");
            }
            let mut b = MockBackend::new(2, 2);
            h.serve(&mut b)
        });
        let router =
            Router::start(RouterOpts { workers: 2, ..Default::default() },
                          body);
        for class in 0..8 {
            let (_, rx) =
                router.submit(GenRequest { class, n: 2 }).unwrap();
            let resp = rx.recv().unwrap().unwrap();
            assert!(resp.images.iter().all(|&v| v == class as f32));
        }
        let stats = router.shutdown();
        assert_eq!(stats.images, 16);
        assert_eq!(fails.load(Ordering::Relaxed), 1);
        assert!(stats.workers[0].failed && !stats.workers[1].failed);
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        // gate the worker so the queue fills deterministically
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate = Arc::new(Mutex::new(Some(gate_rx)));
        let body: Arc<WorkerBody> = Arc::new(move |h: WorkerHandle| -> Result<()> {
            let rx = gate.lock().unwrap().take().expect("one worker");
            let _ = rx.recv();
            let mut b = MockBackend::new(4, 2);
            h.serve(&mut b)
        });
        let router = Router::start(
            RouterOpts { workers: 1, max_queue: 8, ..RouterOpts::default() },
            body,
        );
        // a request bigger than the cap can never fit: distinct error
        let err = router.submit(GenRequest { class: 0, n: 9 }).unwrap_err();
        assert!(matches!(err, ServeError::RequestTooLarge { n: 9, cap: 8 }));
        let (_, rx1) = router.submit(GenRequest { class: 1, n: 8 }).unwrap();
        let err = router.submit(GenRequest { class: 2, n: 1 }).unwrap_err();
        assert!(matches!(err, ServeError::QueueFull { queued: 8, cap: 8 }));
        gate_tx.send(()).unwrap();
        assert!(rx1.recv().unwrap().is_ok());
        let stats = router.shutdown();
        assert_eq!(stats.images, 8);
    }

    #[test]
    fn panicking_backend_fails_clients_with_typed_errors() {
        let body: Arc<WorkerBody> =
            Arc::new(|h: WorkerHandle| -> Result<()> {
                let mut b = MockBackend::new(2, 2);
                b.panic_after = Some(0);
                h.serve(&mut b)
            });
        let router =
            Router::start(RouterOpts { workers: 1, ..Default::default() },
                          body);
        let (_, rx) = router.submit(GenRequest { class: 1, n: 1 }).unwrap();
        match rx.recv().unwrap() {
            Err(ServeError::WorkerFailed { cause, .. }) => {
                assert!(cause.contains("panic"), "{cause}");
            }
            other => panic!("expected WorkerFailed, got {other:?}"),
        }
        // the dead worker must be recorded — no hangs on later submits
        loop {
            match router.submit(GenRequest { class: 0, n: 1 }) {
                Err(_) => break,
                Ok((_, rx2)) => {
                    assert!(rx2.recv().unwrap().is_err());
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        let stats = router.shutdown();
        assert!(stats.workers[0].failed);
    }

    #[test]
    fn short_backend_buffer_fails_batch_with_typed_error() {
        // a buffer-length contract violation must become a typed error,
        // not a copy_from_slice panic that strands the batch's clients
        let body: Arc<WorkerBody> = Arc::new(|h: WorkerHandle| -> Result<()> {
            let mut b = MockBackend::new(4, 2);
            b.short_after = Some(0);
            h.serve(&mut b)
        });
        let router =
            Router::start(RouterOpts { workers: 1, ..Default::default() },
                          body);
        let (_, rx) = router.submit(GenRequest { class: 1, n: 2 }).unwrap();
        match rx.recv().unwrap() {
            Err(ServeError::WorkerFailed { cause, .. }) => {
                assert!(cause.contains("pixels"), "{cause}");
            }
            other => panic!("expected WorkerFailed, got {other:?}"),
        }
        let stats = router.shutdown();
        assert!(stats.workers[0].failed);
        assert_eq!(stats.images, 0);
    }

    #[test]
    fn panicking_worker_body_is_recorded_dead() {
        let body: Arc<WorkerBody> =
            Arc::new(|_h: WorkerHandle| -> Result<()> {
                panic!("init panic");
            });
        let router =
            Router::start(RouterOpts { workers: 1, ..Default::default() },
                          body);
        loop {
            match router.submit(GenRequest { class: 0, n: 1 }) {
                Err(ServeError::AllWorkersDead { cause }) => {
                    assert!(cause.contains("panic"), "{cause}");
                    break;
                }
                Err(other) => panic!("unexpected reject: {other}"),
                Ok((_, rx)) => {
                    assert!(rx.recv().unwrap().is_err());
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        router.shutdown();
    }

    #[test]
    fn stats_snapshot_and_shutdown_conserve_slots() {
        let router = mock_router(1, 4, 3);
        let (_, rx) = router.submit(GenRequest { class: 2, n: 6 }).unwrap();
        rx.recv().unwrap().unwrap();
        // live snapshot holds the conservation identity and does not
        // stop the service
        let snap = router.stats();
        assert_eq!(snap.enqueued,
                   snap.dispatched + snap.purged + snap.pending);
        assert_eq!(snap.requests, 1);
        let (_, rx2) = router.submit(GenRequest { class: 3, n: 2 }).unwrap();
        rx2.recv().unwrap().unwrap();
        let stats = router.shutdown();
        assert_eq!(stats.pending, 0, "drained shutdown leaves no slots");
        assert_eq!(stats.enqueued, 8);
        assert_eq!(stats.enqueued, stats.dispatched + stats.purged);
    }

    #[test]
    fn failed_batch_purge_shows_in_stats_counters() {
        let body: Arc<WorkerBody> = Arc::new(|h: WorkerHandle| -> Result<()> {
            let mut b = MockBackend::new(2, 2);
            b.fail_after = Some(0);
            h.serve(&mut b)
        });
        let router =
            Router::start(RouterOpts { workers: 1, ..Default::default() },
                          body);
        let (_, rx) = router.submit(GenRequest { class: 1, n: 5 }).unwrap();
        assert!(rx.recv().unwrap().is_err());
        let stats = router.shutdown();
        // 2 slots dispatched into the failing batch, 3 purged from the
        // queue when the request failed — conservation still holds
        assert_eq!(stats.enqueued, 5);
        assert_eq!(stats.enqueued,
                   stats.dispatched + stats.purged + stats.pending);
        assert!(stats.purged >= 3, "queued remainder must be purged");
    }

    #[test]
    fn absorb_sums_counters_and_renumbers_workers() {
        let mut a = {
            let router = mock_router(2, 4, 3);
            let (_, rx) =
                router.submit(GenRequest { class: 1, n: 5 }).unwrap();
            rx.recv().unwrap().unwrap();
            router.shutdown()
        };
        let mut b = {
            let router = mock_router(1, 2, 3);
            let (_, rx) =
                router.submit(GenRequest { class: 2, n: 2 }).unwrap();
            rx.recv().unwrap().unwrap();
            router.shutdown()
        };
        a.reuse_hits = 3;
        a.steps_skipped = 3;
        a.uploads_saved = 7;
        b.reuse_hits = 2;
        b.steps_skipped = 1;
        b.uploads_saved = 4;
        let (ra, rb) = (a.requests, b.requests);
        a.absorb(&b);
        assert_eq!(a.requests, ra + rb);
        assert_eq!(a.images, 7);
        assert_eq!(a.reuse_hits, 5);
        assert_eq!(a.steps_skipped, 4);
        assert_eq!(a.uploads_saved, 11);
        assert_eq!(a.enqueued, 7);
        assert_eq!(a.enqueued, a.dispatched + a.purged + a.pending);
        // worker rows from both services, re-numbered without collision
        assert_eq!(a.workers.len(), 3);
        let ids: Vec<usize> = a.workers.iter().map(|w| w.worker).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn empty_shutdown_reports_zero_stats() {
        let router = mock_router(2, 4, 2);
        let stats = router.shutdown();
        assert_eq!(stats.requests, 0);
        assert_eq!(stats.batches, 0);
        assert_eq!(stats.batch_fill, 0.0);
        assert_eq!(stats.latency_p50_s, 0.0);
    }

    #[test]
    fn shutdown_stats_carry_the_latency_histogram() {
        let router = mock_router(1, 2, 3);
        let (_, rx) = router.submit(GenRequest { class: 1, n: 2 }).unwrap();
        rx.recv().unwrap().unwrap();
        let stats = router.shutdown();
        assert_eq!(stats.latency.count(), 1);
        assert!(stats.latency_p95_s >= stats.latency_p50_s);
        assert!(stats.latency_p95_s <= stats.latency.max_s() + 1e-12);
    }

    #[test]
    fn absorb_recomputes_percentiles_from_merged_histograms() {
        // shard A: 90 fast requests; shard B: 10 slow ones. The old
        // fold took max() per percentile, reporting A∪B's p50 as 1s;
        // the merged-distribution fold keeps p50 fast and lets p95
        // see the tail.
        let mut a = ServerStats::default();
        for _ in 0..90 {
            a.latency.record(0.010);
        }
        a.latency_p50_s = a.latency.quantile(0.50);
        a.latency_p95_s = a.latency.quantile(0.95);
        let mut b = ServerStats::default();
        for _ in 0..10 {
            b.latency.record(1.0);
        }
        b.latency_p50_s = b.latency.quantile(0.50);
        b.latency_p95_s = b.latency.quantile(0.95);
        a.absorb(&b);
        assert_eq!(a.latency.count(), 100);
        assert!(a.latency_p50_s < 0.02,
                "merged p50 {} should track the fast mode",
                a.latency_p50_s);
        assert!((a.latency_p95_s - 1.0).abs() < 0.06,
                "merged p95 {} should see the slow tail",
                a.latency_p95_s);
    }

    #[test]
    fn absorb_keeps_max_bound_for_histogramless_peers() {
        // a stats report from a pre-histogram wire peer has percentile
        // fields but an empty histogram: the conservative max() fold
        // must survive as the fallback
        let mut a = ServerStats {
            latency_p50_s: 0.2,
            latency_p95_s: 0.4,
            ..ServerStats::default()
        };
        let b = ServerStats {
            latency_p50_s: 0.1,
            latency_p95_s: 0.9,
            ..ServerStats::default()
        };
        a.absorb(&b);
        assert_eq!(a.latency_p50_s, 0.2);
        assert_eq!(a.latency_p95_s, 0.9);
    }

    #[test]
    fn traced_request_produces_stitched_parented_spans() {
        trace::set_enabled(true);
        let router = mock_router(1, 4, 3);
        // the caller-supplied context a shard node would forward: its
        // span is the frontend's dispatch span
        let parent = TraceCtx {
            trace: trace::next_id(),
            span: trace::next_id(),
        };
        let (_, rx) = router
            .submit_traced(GenRequest { class: 1, n: 2 }, parent)
            .unwrap();
        rx.recv().unwrap().unwrap();
        router.shutdown();
        let spans = trace::spans_for_trace(parent.trace);
        let root = spans
            .iter()
            .find(|r| r.kind == SpanKind::Request)
            .expect("request root span");
        assert_eq!(root.parent, parent.span,
                   "request root must parent under the caller's span");
        assert_eq!(root.b, 2);
        for kind in [SpanKind::Queue, SpanKind::Generate,
                     SpanKind::Encode, SpanKind::RungPick]
        {
            let stage = spans
                .iter()
                .find(|r| r.kind == kind)
                .unwrap_or_else(|| panic!("missing {kind:?} span"));
            assert_eq!(stage.parent, root.span,
                       "{kind:?} must parent under the request root");
        }
        let rung = spans
            .iter()
            .find(|r| r.kind == SpanKind::RungPick)
            .expect("rung span");
        assert_eq!(rung.a, 4, "one-rung ladder always picks rung 4");
        assert_eq!(rung.b, 2, "two real slots taken");
    }

    #[test]
    fn untraced_submit_stays_spanless() {
        // per-request opt-out: a NONE parent context must not record
        // even while the global recorder is on for other requests
        trace::set_enabled(true);
        let router = mock_router(1, 2, 3);
        let before = trace::snapshot().len();
        let (_, rx) = router
            .submit_traced(GenRequest { class: 1, n: 1 }, TraceCtx::NONE)
            .unwrap();
        rx.recv().unwrap().unwrap();
        router.shutdown();
        // spans from concurrently running traced tests may land in the
        // meantime, so assert on this request's absence, not totals:
        // a NONE ctx has trace id 0, and no span carries it
        let zero_trace: Vec<_> = trace::spans_for_trace(0);
        assert!(zero_trace.is_empty(),
                "NONE ctx must never record (ring grew {} -> {})",
                before, trace::snapshot().len());
    }
}
