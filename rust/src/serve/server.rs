//! Generation server: a worker thread owns the (non-`Send`) PJRT
//! runtime and sampler; clients submit [`GenRequest`]s over a channel
//! and receive [`GenResponse`]s with their images and latency.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::pipeline::{Method, Pipeline};
use crate::sampler::Sampler;
use crate::serve::batcher::Batcher;
use crate::util::config::RunConfig;
use crate::util::rng::Rng;

/// A client request: n images of one class.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub class: i32,
    pub n: usize,
}

/// The server's reply.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// Flat (n, H, W, C) pixels in ≈[-1, 1].
    pub images: Vec<f32>,
    /// Queue + compute time for the whole request.
    pub latency_s: f64,
}

/// Aggregate server statistics (reported on shutdown).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub requests: u64,
    pub images: u64,
    pub batches: u64,
    /// Occupied slots / dispatched capacity.
    pub batch_fill: f64,
    pub wall_s: f64,
}

impl ServerStats {
    pub fn print(&self) {
        let thr = self.images as f64 / self.wall_s.max(1e-9);
        println!(
            "served {} requests / {} images in {:.2}s  \
             ({:.2} img/s, {} batches, fill {:.0}%)",
            self.requests, self.images, self.wall_s, thr, self.batches,
            self.batch_fill * 100.0
        );
    }
}

enum Msg {
    Submit(u64, GenRequest, Sender<GenResponse>),
    Shutdown(Sender<ServerStats>),
}

/// Handle to the generation service.
pub struct GenServer {
    tx: Sender<Msg>,
    next_id: std::cell::Cell<u64>,
    worker: Option<JoinHandle<()>>,
}

impl GenServer {
    /// Start the worker: it builds the pipeline, calibrates `method`
    /// once, then serves batches until shutdown.
    pub fn start(cfg: RunConfig, method: Method) -> GenServer {
        let (tx, rx) = channel::<Msg>();
        let worker = std::thread::spawn(move || {
            if let Err(e) = worker_loop(cfg, method, rx) {
                eprintln!("[serve] worker failed: {e:#}");
            }
        });
        GenServer {
            tx,
            next_id: std::cell::Cell::new(0),
            worker: Some(worker),
        }
    }

    /// Submit a request; returns (id, receiver for the response).
    pub fn submit(&self, req: GenRequest)
                  -> (u64, Receiver<GenResponse>) {
        let id = self.next_id.get();
        self.next_id.set(id + 1);
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Submit(id, req, rtx))
            .expect("server worker alive");
        (id, rrx)
    }

    /// Stop the worker and collect aggregate statistics.
    pub fn shutdown(mut self) -> ServerStats {
        let (stx, srx) = channel();
        let _ = self.tx.send(Msg::Shutdown(stx));
        let stats = srx.recv().unwrap_or_default();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        stats
    }
}

struct PendingReq {
    tx: Sender<GenResponse>,
    images: Vec<f32>,
    remaining: usize,
    t0: Instant,
}

fn worker_loop(cfg: RunConfig, method: Method, rx: Receiver<Msg>)
               -> Result<()> {
    let pipe = Pipeline::new(cfg)?;
    let mut rng = Rng::new(pipe.cfg.seed ^ 0x5e12e);
    let (qc, _) = pipe.calibrate(method, &mut rng)?;
    let sampler = Sampler::new(&pipe.rt, &pipe.weights, qc,
                               pipe.cfg.timesteps)?;
    let b = sampler.batch();
    let il = sampler.img_len();

    let mut batcher = Batcher::new();
    let mut pending: HashMap<u64, PendingReq> = HashMap::new();
    let mut stats = ServerStats::default();
    let mut fill_sum = 0.0f64;
    let t_start = Instant::now();
    let mut open = true;
    let mut shutdown_tx: Option<Sender<ServerStats>> = None;

    while open || !batcher.is_empty() {
        // drain the mailbox; block only when there is no work queued
        loop {
            let msg = if batcher.is_empty() && open {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        open = false;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Submit(id, req, tx) => {
                    stats.requests += 1;
                    batcher.push_request(id, req.class, req.n);
                    pending.insert(id, PendingReq {
                        tx,
                        images: Vec::with_capacity(req.n * il),
                        remaining: req.n,
                        t0: Instant::now(),
                    });
                }
                Msg::Shutdown(tx) => {
                    open = false;
                    shutdown_tx = Some(tx);
                }
            }
        }

        let slots = batcher.pop_batch(b);
        if slots.is_empty() {
            continue;
        }
        // pad labels to the fixed artifact batch with class 0
        let mut labels = vec![0i32; b];
        for (i, s) in slots.iter().enumerate() {
            labels[i] = s.class;
        }
        let (imgs, _) = sampler.sample(&labels, &mut rng)?;
        stats.batches += 1;
        fill_sum += slots.len() as f64 / b as f64;

        for (i, s) in slots.iter().enumerate() {
            let req = pending.get_mut(&s.req_id).expect("pending entry");
            req.images.extend_from_slice(&imgs[i * il..(i + 1) * il]);
            req.remaining -= 1;
            stats.images += 1;
            if req.remaining == 0 {
                let done = pending.remove(&s.req_id).unwrap();
                let _ = done.tx.send(GenResponse {
                    id: s.req_id,
                    images: done.images,
                    latency_s: done.t0.elapsed().as_secs_f64(),
                });
            }
        }
    }

    stats.wall_s = t_start.elapsed().as_secs_f64();
    stats.batch_fill = if stats.batches > 0 {
        fill_sum / stats.batches as f64
    } else {
        0.0
    };
    if let Some(tx) = shutdown_tx {
        let _ = tx.send(stats);
    }
    Ok(())
}
