//! Pipeline-backed generation service: [`GenServer`] wires the
//! multi-worker [`Router`] to the real PJRT sampling stack.
//!
//! Each worker thread builds its own [`Pipeline`] (the PJRT runtime is
//! not `Send`), but the expensive quantization calibration runs exactly
//! once: the first worker to finish constructing its pipeline resolves
//! the shared [`QuantConfig`] through a [`CalibCell`] — consulting the
//! persistent calibration cache first (`Pipeline::calibrate_cached`),
//! so a warm cold-start skips the MRQ/TGQ pipeline entirely — and
//! every other worker blocks on the cell and clones the published
//! qparams instead of recalibrating. The cell records whether the
//! config came from cache and how long resolution took; [`GenServer`]
//! surfaces both through [`ServerStats`]. Worker sampling RNGs are
//! derived from the run seed and the worker index so shards produce
//! distinct images.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::pipeline::{Method, Pipeline};
use crate::coordinator::QuantConfig;
use crate::obs::trace::TraceCtx;
use crate::sampler::Sampler;
use crate::serve::router::{
    GenBackend, GenRequest, GenResult, Router, RouterOpts, ServerStats,
    WorkerBody, WorkerHandle,
};
use crate::serve::ServeError;
use crate::util::config::RunConfig;
use crate::util::rng::Rng;

/// How the one shared calibration was resolved (for [`ServerStats`]).
#[derive(Clone, Copy, Debug)]
struct CalibRecord {
    /// `Some(true)` loaded from the persistent cache, `Some(false)`
    /// consulted but missed, `None` cache not consulted (disabled/FP).
    cache: Option<bool>,
    /// Wall-clock of the resolution (cache load or full calibration).
    cold_start_ms: f64,
}

/// Calibrate-once cell shared by the worker threads: the first caller
/// resolves the config (cache load or fresh calibration), everyone else
/// blocks for the published result (success *or* failure — a failed
/// calibration fails every worker with the same typed cause instead of
/// hanging the stragglers).
struct CalibCell {
    state: Mutex<CalibState>,
    ready: Condvar,
    record: Mutex<Option<CalibRecord>>,
}

enum CalibState {
    Empty,
    Running,
    Done(std::result::Result<QuantConfig, String>),
}

impl CalibCell {
    fn new() -> CalibCell {
        CalibCell {
            state: Mutex::new(CalibState::Empty),
            ready: Condvar::new(),
            record: Mutex::new(None),
        }
    }

    /// Resolve via `Pipeline::calibrate_cached`: warm cache → no
    /// calibration work at all; miss/corrupt/stale → fresh + persist.
    fn get_or_calibrate(&self, pipe: &Pipeline, method: Method)
                        -> Result<QuantConfig> {
        self.get_or_init(|| match pipe.calibrate_cached(method) {
            Ok((qc, _, outcome)) => (Ok(qc), outcome),
            Err(e) => (Err(format!("{e:#}")), None),
        })
    }

    /// Run `f` in exactly one caller; every other caller blocks for the
    /// published result. `f` returns (result, cache outcome); resolution
    /// wall-clock is measured here and recorded alongside the outcome.
    fn get_or_init<F>(&self, f: F) -> Result<QuantConfig>
    where
        F: FnOnce() -> (std::result::Result<QuantConfig, String>,
                        Option<bool>),
    {
        let mut st = crate::util::lock(&self.state);
        loop {
            let claim = match *st {
                CalibState::Done(ref res) => {
                    return res.clone().map_err(|e| {
                        anyhow::anyhow!("shared calibration failed: {e}")
                    });
                }
                CalibState::Running => false,
                CalibState::Empty => true,
            };
            if !claim {
                st = self.ready.wait(st).unwrap_or_else(|p| p.into_inner());
                continue;
            }
            // claim the calibration slot, run it unlocked, publish.
            // The guard publishes a failure if resolution *panics*, so
            // sibling workers blocked above never wait forever.
            *st = CalibState::Running;
            drop(st);
            let guard = CalibPanicGuard { cell: self };
            let t0 = Instant::now();
            let (res, cache) = f();
            *crate::util::lock(&self.record) =
                Some(CalibRecord {
                    cache,
                    cold_start_ms: t0.elapsed().as_secs_f64() * 1e3,
                });
            self.publish(res.clone());
            std::mem::forget(guard);
            return res
                .map_err(|e| anyhow::anyhow!("calibration failed: {e}"));
        }
    }

    /// The resolution record, once some caller has resolved.
    fn record(&self) -> Option<CalibRecord> {
        *crate::util::lock(&self.record)
    }

    fn publish(&self, res: std::result::Result<QuantConfig, String>) {
        let mut st = crate::util::lock(&self.state);
        *st = CalibState::Done(res);
        drop(st);
        self.ready.notify_all();
    }
}

/// Unwinding out of the claimed calibration (a panic inside the
/// pipeline) publishes a failure instead of leaving the cell `Running`.
struct CalibPanicGuard<'a> {
    cell: &'a CalibCell,
}

impl Drop for CalibPanicGuard<'_> {
    fn drop(&mut self) {
        self.cell.publish(Err("calibration panicked".into()));
    }
}

/// [`GenBackend`] over the real sampler ladder; one per worker thread.
/// Holds a sampler per served batch rung — all sharing one resident
/// upload of the quantized weights — and routes each dispatch to the
/// rung the batch policy planned it for. Step-reuse counters from each
/// trajectory accumulate here and surface through
/// [`GenBackend::reuse_counters`].
struct SamplerBackend<'a> {
    samplers: Vec<Sampler<'a>>,
    rng: Rng,
    /// Lifetime totals of the sampler's reuse counters
    /// (`reuse_hits`, `steps_skipped`, `uploads_saved`).
    reuse: (u64, u64, u64),
}

impl<'a> GenBackend for SamplerBackend<'a> {
    fn rungs(&self) -> Vec<usize> {
        self.samplers.iter().map(|s| s.batch()).collect()
    }

    fn img_len(&self) -> usize {
        self.samplers[0].img_len()
    }

    fn generate(&mut self, labels: &[i32]) -> Result<Vec<f32>> {
        let s = self
            .samplers
            .iter()
            .find(|s| s.batch() == labels.len())
            .ok_or_else(|| {
                anyhow::anyhow!("no sampler lowered for a {}-slot batch",
                                labels.len())
            })?;
        let (imgs, stats) = s.sample(labels, &mut self.rng)?;
        self.reuse.0 += stats.reuse_hits as u64;
        self.reuse.1 += stats.steps_skipped as u64;
        self.reuse.2 += stats.uploads_saved as u64;
        Ok(imgs)
    }

    fn reuse_counters(&self) -> (u64, u64, u64) {
        self.reuse
    }
}

/// Handle to the generation service (a [`Router`] whose workers drive
/// the quantized sampler).
pub struct GenServer {
    router: Router,
    calib: Arc<CalibCell>,
}

impl GenServer {
    /// Single-worker service (the original API shape).
    pub fn start(cfg: RunConfig, method: Method) -> GenServer {
        GenServer::with_workers(cfg, method, 1)
    }

    /// Sharded service: `workers` threads, each owning a pipeline +
    /// sampler ladder, sharing one calibration pass (cache-backed: a
    /// warm persistent cache makes cold-start skip calibration
    /// entirely). Each worker serves every batch rung the artifacts
    /// were lowered at — narrowed by `cfg.batch_ladder`, dispatched
    /// under the `cfg.linger_ms` deadline policy.
    pub fn with_workers(cfg: RunConfig, method: Method, workers: usize)
                        -> GenServer {
        let opts = RouterOpts {
            workers,
            linger: Duration::from_millis(cfg.linger_ms),
            ..RouterOpts::default()
        };
        let calib = Arc::new(CalibCell::new());
        let calib2 = Arc::clone(&calib);
        let body: Arc<WorkerBody> = Arc::new(move |h: WorkerHandle| -> Result<()> {
            let pipe = Pipeline::new(cfg.clone())?;
            let qc = calib2.get_or_calibrate(&pipe, method)?;
            let samplers =
                pipe.sampler_ladder(&qc, cfg.batch_ladder.as_deref())?;
            // distinct from the calibration stream (0x5eed) for every
            // worker, including index 0
            let mut backend = SamplerBackend {
                samplers,
                rng: Rng::new(pipe.cfg.seed
                              ^ 0x9e3779b97f4a7c15u64
                                    .wrapping_mul(h.index() as u64 + 1)),
                reuse: (0, 0, 0),
            };
            h.serve(&mut backend)
        });
        GenServer {
            router: Router::start(opts, body),
            calib,
        }
    }

    /// Submit a request; returns (id, receiver for the typed result).
    /// Errors instead of panicking when the service cannot take it.
    pub fn submit(&self, req: GenRequest)
                  -> std::result::Result<
                      (u64, std::sync::mpsc::Receiver<GenResult>),
                      ServeError,
                  > {
        self.router.submit(req)
    }

    /// [`Self::submit`] under an externally minted trace context (a
    /// shard node forwards the frontend's dispatch span here).
    pub fn submit_traced(&self, req: GenRequest, parent: TraceCtx)
                         -> std::result::Result<
                             (u64, std::sync::mpsc::Receiver<GenResult>),
                             ServeError,
                         > {
        self.router.submit_traced(req, parent)
    }

    /// Image slots queued but not yet picked up by a worker.
    pub fn queue_depth(&self) -> usize {
        self.router.queue_depth()
    }

    /// Workers that have not exited.
    pub fn live_workers(&self) -> usize {
        self.router.live_workers()
    }

    /// Workers whose pipeline + sampler are built and serving.
    pub fn ready_workers(&self) -> usize {
        self.router.ready_workers()
    }

    /// Overlay the shared-calibration outcome onto router stats.
    fn overlay_calib(calib: &CalibCell, mut stats: ServerStats)
                     -> ServerStats {
        if let Some(rec) = calib.record() {
            match rec.cache {
                Some(true) => stats.calib_cache_hits = 1,
                Some(false) => stats.calib_cache_misses = 1,
                // cache disabled / not applicable: report neither, so
                // stats never claim a cache was consulted
                None => {}
            }
            stats.calib_cold_start_ms = rec.cold_start_ms;
        }
        stats
    }

    /// Live statistics snapshot (the remote stats protocol serves this
    /// without stopping the service).
    pub fn stats(&self) -> ServerStats {
        GenServer::overlay_calib(&self.calib, self.router.stats())
    }

    /// Stop the workers, drain the queue and collect statistics
    /// (including the calibration-cache outcome for this run).
    pub fn shutdown(self) -> ServerStats {
        let GenServer { router, calib } = self;
        GenServer::overlay_calib(&calib, router.shutdown())
    }
}

impl crate::serve::dispatch::Dispatch for GenServer {
    fn submit(&self, req: GenRequest)
              -> std::result::Result<
                  (u64, std::sync::mpsc::Receiver<GenResult>),
                  ServeError,
              > {
        GenServer::submit(self, req)
    }
    fn submit_traced(&self, req: GenRequest, parent: TraceCtx)
                     -> std::result::Result<
                         (u64, std::sync::mpsc::Receiver<GenResult>),
                         ServeError,
                     > {
        GenServer::submit_traced(self, req, parent)
    }
    fn queue_depth(&self) -> usize {
        GenServer::queue_depth(self)
    }
    fn live_workers(&self) -> usize {
        GenServer::live_workers(self)
    }
    fn ready_workers(&self) -> usize {
        GenServer::ready_workers(self)
    }
    fn stats(&self) -> ServerStats {
        GenServer::stats(self)
    }
    fn shutdown(self: Box<Self>) -> ServerStats {
        GenServer::shutdown(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};

    use crate::coordinator::cache::{CacheKey, CalibCache};
    use crate::quant::{SiteParams, UniformQ};
    use crate::sched::TimeGroups;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "tqdit_cell_cache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn test_key() -> CacheKey {
        CacheKey::from_config(&RunConfig::default(), "tq-dit", 0x7e57)
    }

    fn cached_config() -> QuantConfig {
        let mut c = QuantConfig::new("tq-dit", 8, 8,
                                     TimeGroups::new(250, 10));
        c.sites.insert(
            "blk0.x".into(),
            SiteParams::Uniform(UniformQ { s: 0.125, z: 2.0,
                                           levels: 255.0 }),
        );
        c
    }

    fn fresh_config() -> QuantConfig {
        QuantConfig::new("tq-dit", 8, 8, TimeGroups::new(250, 10))
    }

    /// The `GenServer` resolution flow against a counting calibration
    /// hook: a warm cache must produce a ready config without invoking
    /// the (mock) quantization pipeline at all.
    #[test]
    fn warm_cache_resolves_without_calibrating() {
        let dir = tmp_dir("warm");
        let cache = CalibCache::new(&dir);
        let key = test_key();
        cache.store(&key, &cached_config()).unwrap();

        let calibrations = AtomicUsize::new(0);
        let cell = CalibCell::new();
        let qc = cell
            .get_or_init(|| {
                if let Some(qc) = cache.load(&key) {
                    return (Ok(qc), Some(true));
                }
                calibrations.fetch_add(1, Ordering::Relaxed);
                (Ok(fresh_config()), Some(false))
            })
            .unwrap();
        assert_eq!(calibrations.load(Ordering::Relaxed), 0,
                   "warm cache must skip calibration");
        assert_eq!(qc, cached_config());
        let rec = cell.record().unwrap();
        assert_eq!(rec.cache, Some(true));
        assert!(rec.cold_start_ms >= 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A corrupted cache entry must fall back to fresh calibration and
    /// serve its result — never a panic, never a half-read config.
    #[test]
    fn corrupt_cache_falls_back_to_fresh_calibration() {
        let dir = tmp_dir("corrupt");
        let cache = CalibCache::new(&dir);
        let key = test_key();
        cache.store(&key, &cached_config()).unwrap();
        std::fs::write(cache.path_for(&key), b"}{ torn write").unwrap();

        let calibrations = AtomicUsize::new(0);
        let cell = CalibCell::new();
        let qc = cell
            .get_or_init(|| {
                if let Some(qc) = cache.load(&key) {
                    return (Ok(qc), Some(true));
                }
                calibrations.fetch_add(1, Ordering::Relaxed);
                let qc = fresh_config();
                cache.store(&key, &qc).unwrap();
                (Ok(qc), Some(false))
            })
            .unwrap();
        assert_eq!(calibrations.load(Ordering::Relaxed), 1);
        assert_eq!(qc, fresh_config(), "must serve the fresh result");
        assert_eq!(cell.record().unwrap().cache, Some(false));
        // the fallback repaired the entry for the next cold start
        assert_eq!(cache.load(&key), Some(fresh_config()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Later callers get the published result without re-resolving.
    #[test]
    fn cell_publishes_one_resolution_to_all_callers() {
        let cell = CalibCell::new();
        let calls = AtomicUsize::new(0);
        let first = cell
            .get_or_init(|| {
                calls.fetch_add(1, Ordering::Relaxed);
                (Ok(fresh_config()), None)
            })
            .unwrap();
        let second = cell
            .get_or_init(|| {
                calls.fetch_add(1, Ordering::Relaxed);
                (Ok(cached_config()), Some(true))
            })
            .unwrap();
        assert_eq!(calls.load(Ordering::Relaxed), 1);
        assert_eq!(first, second);
    }

    /// A failed resolution is shared as the same typed cause.
    #[test]
    fn cell_shares_failure_with_all_callers() {
        let cell = CalibCell::new();
        let e1 = cell
            .get_or_init(|| (Err("no artifacts".into()), None))
            .unwrap_err();
        assert!(e1.to_string().contains("no artifacts"), "{e1}");
        let e2 = cell
            .get_or_init(|| panic!("must not re-run"))
            .unwrap_err();
        assert!(e2.to_string().contains("no artifacts"), "{e2}");
    }
}
