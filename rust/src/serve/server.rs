//! Pipeline-backed generation service: [`GenServer`] wires the
//! multi-worker [`Router`] to the real PJRT sampling stack.
//!
//! Each worker thread builds its own [`Pipeline`] (the PJRT runtime is
//! not `Send`), but the expensive quantization calibration runs exactly
//! once: the first worker to finish constructing its pipeline calibrates
//! and publishes the resulting [`QuantConfig`] through a [`CalibCell`];
//! every other worker blocks on the cell and clones the shared qparams
//! instead of recalibrating. Worker sampling RNGs are derived from the
//! run seed and the worker index so shards produce distinct images.

use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::coordinator::pipeline::{Method, Pipeline};
use crate::coordinator::QuantConfig;
use crate::sampler::Sampler;
use crate::serve::router::{
    GenBackend, GenRequest, GenResult, Router, RouterOpts, ServerStats,
    WorkerBody, WorkerHandle,
};
use crate::serve::ServeError;
use crate::util::config::RunConfig;
use crate::util::rng::Rng;

/// Calibrate-once cell shared by the worker threads: the first caller
/// runs calibration, everyone else blocks for the published result
/// (success *or* failure — a failed calibration fails every worker with
/// the same typed cause instead of hanging the stragglers).
struct CalibCell {
    state: Mutex<CalibState>,
    ready: Condvar,
}

enum CalibState {
    Empty,
    Running,
    Done(std::result::Result<QuantConfig, String>),
}

impl CalibCell {
    fn new() -> CalibCell {
        CalibCell { state: Mutex::new(CalibState::Empty),
                    ready: Condvar::new() }
    }

    fn get_or_calibrate(&self, pipe: &Pipeline, method: Method)
                        -> Result<QuantConfig> {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            let claim = match *st {
                CalibState::Done(ref res) => {
                    return res.clone().map_err(|e| {
                        anyhow::anyhow!("shared calibration failed: {e}")
                    });
                }
                CalibState::Running => false,
                CalibState::Empty => true,
            };
            if !claim {
                st = self.ready.wait(st).unwrap_or_else(|p| p.into_inner());
                continue;
            }
            // claim the calibration slot, run it unlocked, publish.
            // The guard publishes a failure if calibration *panics*, so
            // sibling workers blocked above never wait forever.
            *st = CalibState::Running;
            drop(st);
            let guard = CalibPanicGuard { cell: self };
            let mut rng = Rng::new(pipe.cfg.seed ^ 0x5e12e);
            let res = pipe
                .calibrate(method, &mut rng)
                .map(|(qc, _)| qc)
                .map_err(|e| format!("{e:#}"));
            self.publish(res.clone());
            std::mem::forget(guard);
            return res
                .map_err(|e| anyhow::anyhow!("calibration failed: {e}"));
        }
    }

    fn publish(&self, res: std::result::Result<QuantConfig, String>) {
        let mut st =
            self.state.lock().unwrap_or_else(|p| p.into_inner());
        *st = CalibState::Done(res);
        drop(st);
        self.ready.notify_all();
    }
}

/// Unwinding out of the claimed calibration (a panic inside the
/// pipeline) publishes a failure instead of leaving the cell `Running`.
struct CalibPanicGuard<'a> {
    cell: &'a CalibCell,
}

impl Drop for CalibPanicGuard<'_> {
    fn drop(&mut self) {
        self.cell.publish(Err("calibration panicked".into()));
    }
}

/// [`GenBackend`] over the real sampler; one per worker thread.
struct SamplerBackend<'a> {
    sampler: Sampler<'a>,
    rng: Rng,
}

impl<'a> GenBackend for SamplerBackend<'a> {
    fn batch(&self) -> usize {
        self.sampler.batch()
    }

    fn img_len(&self) -> usize {
        self.sampler.img_len()
    }

    fn generate(&mut self, labels: &[i32]) -> Result<Vec<f32>> {
        let (imgs, _) = self.sampler.sample(labels, &mut self.rng)?;
        Ok(imgs)
    }
}

/// Handle to the generation service (a [`Router`] whose workers drive
/// the quantized sampler).
pub struct GenServer {
    router: Router,
}

impl GenServer {
    /// Single-worker service (the original API shape).
    pub fn start(cfg: RunConfig, method: Method) -> GenServer {
        GenServer::with_workers(cfg, method, 1)
    }

    /// Sharded service: `workers` threads, each owning a pipeline +
    /// sampler, sharing one calibration pass.
    pub fn with_workers(cfg: RunConfig, method: Method, workers: usize)
                        -> GenServer {
        let calib = Arc::new(CalibCell::new());
        let body: Arc<WorkerBody> = Arc::new(move |h: WorkerHandle| -> Result<()> {
            let pipe = Pipeline::new(cfg.clone())?;
            let qc = calib.get_or_calibrate(&pipe, method)?;
            let sampler = pipe.sampler(&qc)?;
            // distinct from the calibration stream (0x5e12e) for every
            // worker, including index 0
            let mut backend = SamplerBackend {
                sampler,
                rng: Rng::new(pipe.cfg.seed
                              ^ 0x9e3779b97f4a7c15u64
                                    .wrapping_mul(h.index() as u64 + 1)),
            };
            h.serve(&mut backend);
            Ok(())
        });
        GenServer {
            router: Router::start(
                RouterOpts { workers, ..RouterOpts::default() },
                body,
            ),
        }
    }

    /// Submit a request; returns (id, receiver for the typed result).
    /// Errors instead of panicking when the service cannot take it.
    pub fn submit(&self, req: GenRequest)
                  -> std::result::Result<
                      (u64, std::sync::mpsc::Receiver<GenResult>),
                      ServeError,
                  > {
        self.router.submit(req)
    }

    /// Image slots queued but not yet picked up by a worker.
    pub fn queue_depth(&self) -> usize {
        self.router.queue_depth()
    }

    /// Workers that have not exited.
    pub fn live_workers(&self) -> usize {
        self.router.live_workers()
    }

    /// Workers whose pipeline + sampler are built and serving.
    pub fn ready_workers(&self) -> usize {
        self.router.ready_workers()
    }

    /// Stop the workers, drain the queue and collect statistics.
    pub fn shutdown(self) -> ServerStats {
        self.router.shutdown()
    }
}
