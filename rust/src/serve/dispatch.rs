//! The submit/stats surface every generation service exposes.
//!
//! [`Dispatch`] is the seam between "where requests come from" and
//! "where they run": the in-process [`Router`](crate::serve::Router)
//! (mock or sampler-backed), the pipeline-owning
//! [`GenServer`](crate::serve::GenServer), and the cross-node
//! [`Cluster`](crate::serve::net::Cluster) all implement it. A shard
//! node ([`crate::serve::net::NodeServer`]) serves *any* `Dispatch`
//! over its TCP listener, and the CLI drives local and clustered
//! serving through one `Box<dyn Dispatch>` — clients cannot tell (and
//! must not care) whether their batch ran in-process or three hosts
//! away.
//!
//! The contract mirrors the router's: `submit` returns typed
//! [`ServeError`]s instead of panicking, responses (or typed
//! failures) always arrive on the per-request channel — never a hang —
//! and `stats` is a live snapshot that does not disturb service.

use std::sync::mpsc::Receiver;

use crate::obs::trace::TraceCtx;
use crate::serve::error::ServeError;
use crate::serve::router::{GenRequest, GenResult, ServerStats};

/// A generation service: local router, pipeline server, or remote
/// cluster. `Send + Sync` so one boxed service can be shared across
/// connection-handler and client threads.
pub trait Dispatch: Send + Sync {
    /// Submit a request; returns (request id, receiver yielding the
    /// response or a typed error). Must reject — not queue forever —
    /// when the service cannot take the request.
    fn submit(&self, req: GenRequest)
              -> Result<(u64, Receiver<GenResult>), ServeError>;

    /// [`Dispatch::submit`] under an externally minted trace context
    /// (`parent.span` is the span the service's request span parents
    /// under — a shard node passes the frontend's dispatch span here
    /// so both sides stitch into one timeline). The default drops the
    /// context: implementations without a tracing path still serve
    /// the request, they just contribute no spans — the same graceful
    /// degradation a wire-version-skewed peer gets.
    fn submit_traced(&self, req: GenRequest, _parent: TraceCtx)
                     -> Result<(u64, Receiver<GenResult>), ServeError> {
        self.submit(req)
    }

    /// Image slots accepted but not yet computed (for this service's
    /// best local estimate — a cluster sums shard reports).
    fn queue_depth(&self) -> usize;

    /// Workers (local threads or remote shard workers) not yet exited.
    fn live_workers(&self) -> usize;

    /// Workers built and currently serving.
    fn ready_workers(&self) -> usize;

    /// Live statistics snapshot; serving continues undisturbed.
    fn stats(&self) -> ServerStats;

    /// Stop accepting, drain in-flight work, and return final
    /// statistics. (`Box<Self>` keeps the consuming shutdown
    /// object-safe.)
    fn shutdown(self: Box<Self>) -> ServerStats;
}
