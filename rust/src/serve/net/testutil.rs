//! Shared fixtures for the net-layer tests: loopback shard nodes over
//! a mock class-valued backend (pixels all equal the slot's class, so
//! cross-node routing is verifiable end to end), plus raw-socket
//! message helpers.

use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::serve::net::node::{NodeOpts, NodeServer};
use crate::serve::net::proto::Msg;
use crate::serve::net::wire::{read_frame, write_frame};
use crate::serve::router::{
    GenBackend, Router, RouterOpts, WorkerBody, WorkerHandle,
};

/// Backend whose pixels all equal the slot's class label; an optional
/// per-slot delay simulates compute so tests can hold work in flight.
struct ClassBackend {
    rungs: Vec<usize>,
    il: usize,
    delay: Duration,
}

impl GenBackend for ClassBackend {
    fn rungs(&self) -> Vec<usize> {
        self.rungs.clone()
    }
    fn img_len(&self) -> usize {
        self.il
    }
    fn generate(&mut self, labels: &[i32]) -> Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay * labels.len() as u32);
        }
        Ok(labels
            .iter()
            .flat_map(|&c| std::iter::repeat(c as f32).take(self.il))
            .collect())
    }
}

/// A mock single-worker router over [`ClassBackend`].
pub(crate) fn mock_router(rungs: Vec<usize>, il: usize, delay: Duration,
                          max_queue: usize) -> Router {
    let body: Arc<WorkerBody> =
        Arc::new(move |h: WorkerHandle| -> Result<()> {
            let mut b =
                ClassBackend { rungs: rungs.clone(), il, delay };
            h.serve(&mut b)
        });
    Router::start(
        RouterOpts { workers: 1, max_queue, ..RouterOpts::default() },
        body,
    )
}

/// A loopback shard node wrapping a mock router.
pub(crate) fn mock_node(rungs: Vec<usize>, il: usize, delay: Duration)
                        -> (NodeServer, SocketAddr) {
    mock_node_capped(rungs, il, delay, RouterOpts::default().max_queue)
}

/// A mock node bound to an explicit address (restart-a-dead-node
/// tests re-bind a known port, which can briefly race the old
/// listener's close — hence the `Result`).
pub(crate) fn mock_node_at(listen: &str, rungs: Vec<usize>, il: usize,
                           delay: Duration) -> Result<NodeServer> {
    let router =
        mock_router(rungs, il, delay, RouterOpts::default().max_queue);
    NodeServer::start(Box::new(router), listen, NodeOpts::default())
}

/// [`mock_node`] with an explicit queue cap (backpressure tests).
pub(crate) fn mock_node_capped(rungs: Vec<usize>, il: usize,
                               delay: Duration, max_queue: usize)
                               -> (NodeServer, SocketAddr) {
    let router = mock_router(rungs, il, delay, max_queue);
    let node = NodeServer::start(Box::new(router), "127.0.0.1:0",
                                 NodeOpts::default())
        .expect("start loopback node");
    let addr = node.addr();
    (node, addr)
}

/// [`mock_node`] with explicit [`NodeOpts`] (reactor-mode tests).
pub(crate) fn mock_node_opts(rungs: Vec<usize>, il: usize,
                             delay: Duration, opts: NodeOpts)
                             -> (NodeServer, SocketAddr) {
    let router =
        mock_router(rungs, il, delay, RouterOpts::default().max_queue);
    let node = NodeServer::start(Box::new(router), "127.0.0.1:0", opts)
        .expect("start loopback node");
    let addr = node.addr();
    (node, addr)
}

/// Write one protocol message (panics on failure — test plumbing).
pub(crate) fn send_msg(stream: &mut TcpStream, msg: &Msg) {
    write_frame(stream, &msg.encode()).expect("send message");
}

/// Read one protocol message (panics on failure — test plumbing).
pub(crate) fn read_msg(stream: &mut TcpStream) -> Msg {
    let payload = read_frame(stream).expect("read frame");
    Msg::decode(&payload).expect("decode message")
}
