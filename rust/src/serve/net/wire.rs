//! Length-prefixed binary frame codec for the cross-node wire.
//!
//! Every message on a shard connection travels as one or more
//! *frames*: a fixed 20-byte header followed by an opaque payload (the
//! canonical JSON of a [`crate::serve::net::proto::Msg`], but the
//! codec never looks inside). Big-endian header layout:
//!
//! ```text
//! offset  size  field
//!      0     4  magic      0x54514454 ("TQDT")
//!      4     2  version    WIRE_VERSION (readers reject any other)
//!      6     2  ctrl       chunk control bits (0 = standalone frame)
//!      8     4  payload length (bytes, <= MAX_FRAME_LEN)
//!     12     8  checksum   FNV-1a over header[0..12] ++ payload
//!     20     …  payload
//! ```
//!
//! # Chunking (v2)
//!
//! A message larger than [`CHUNK_LEN`] is split into a run of chunk
//! frames so no single write occupies the connection for long — the
//! sender can release its writer lock between chunks and let small
//! frames (heartbeat replies, typed errors) interleave, which is what
//! keeps liveness honest on a slow link. The `ctrl` field encodes it:
//!
//! ```text
//! bit 15  CHUNKED  this frame is one chunk of a larger message
//! bit 14  FIN      last chunk of its message
//! bits 0–13        chunk sequence number (0-based, contiguous)
//! ```
//!
//! `ctrl == 0` is a standalone frame (the entire message). Chunks of
//! one message must arrive in order and contiguously *relative to each
//! other*, but standalone frames may interleave between them — the
//! stateful [`MessageReader`] hands an interleaved standalone frame to
//! the caller immediately and keeps reassembling. Every chunk carries
//! its own checksum; [`MAX_FRAME_LEN`] caps both a single frame and
//! the reassembled message (a corrupt stream can never allocate
//! unboundedly).
//!
//! Decoding is total: every malformed input maps to a typed
//! [`WireError`] — bad magic, a version-skewed peer, an oversized
//! length (rejected *before* allocating), a flipped bit anywhere in
//! header or payload (the checksum covers both), a stream truncated
//! mid-frame, an out-of-order or truncated chunk run, or a clean close
//! at a message boundary ([`WireError::Closed`], the one non-error
//! exit). Nothing in this module panics on input bytes —
//! property-tested below in the `coordinator/store.rs` style.

use std::fmt;
use std::io::{Read, Write};

/// Frame magic: "TQDT" as a big-endian u32.
pub const WIRE_MAGIC: u32 = 0x5451_4454;
/// Protocol version; bumped on any incompatible message change.
/// Readers reject every other version with [`WireError::VersionSkew`].
/// v2: the reserved header bytes became the chunk `ctrl` field and the
/// `Hello{role}` handshake tags control-plane connections.
pub const WIRE_VERSION: u16 = 2;
/// Hard cap on one frame's payload *and* on a reassembled chunked
/// message. Generous for image responses while keeping a corrupted
/// length field from allocating gigabytes.
pub const MAX_FRAME_LEN: usize = 64 << 20;
/// Payload size above which a message is split into chunk frames (and
/// the per-chunk payload size the splitter produces). Small enough
/// that a writer releasing its lock between chunks never blocks a
/// heartbeat behind more than one chunk's transfer time.
pub const CHUNK_LEN: usize = 256 << 10;
/// Fixed header size (see module docs for the layout).
pub const HEADER_LEN: usize = 20;

/// `ctrl` bit: frame is one chunk of a larger message.
const CTRL_CHUNKED: u16 = 1 << 15;
/// `ctrl` bit: last chunk of its message.
const CTRL_FIN: u16 = 1 << 14;
/// `ctrl` mask: chunk sequence number.
const CTRL_SEQ_MASK: u16 = (1 << 14) - 1;

/// Typed wire-level failure. `Closed` is the clean-EOF signal every
/// reader loop must treat as "peer hung up", not as corruption.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The stream ended cleanly on a message boundary.
    Closed,
    /// The stream ended mid-frame (`got` of `want` bytes arrived).
    Truncated { got: usize, want: usize },
    /// The first four bytes were not the frame magic.
    BadMagic { got: u32 },
    /// The peer speaks a different protocol version.
    VersionSkew { got: u16, want: u16 },
    /// The `ctrl` field is inconsistent (e.g. FIN or a sequence number
    /// without the CHUNKED bit) — header corruption or a buggy peer.
    BadControl { got: u16 },
    /// Declared payload length exceeds [`MAX_FRAME_LEN`].
    TooLarge { len: usize, max: usize },
    /// Checksum mismatch: a bit flipped in header or payload.
    Corrupt { want: u64, got: u64 },
    /// A chunk arrived out of sequence (dropped or reordered frame).
    ChunkOutOfOrder { want: u16, got: u16 },
    /// The stream ended cleanly mid-chunk-run (`chunks` arrived, no
    /// FIN) — the peer died between chunks of one message.
    ChunkTruncated { chunks: u16 },
    /// Underlying I/O failure (connection reset, …).
    Io(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Closed => write!(f, "connection closed"),
            WireError::Truncated { got, want } => {
                write!(f, "frame truncated ({got} of {want} bytes)")
            }
            WireError::BadMagic { got } => {
                write!(f, "bad frame magic {got:#010x} \
                           (expected {WIRE_MAGIC:#010x})")
            }
            WireError::VersionSkew { got, want } => {
                write!(f, "wire version skew: peer speaks v{got}, \
                           this build speaks v{want}")
            }
            WireError::BadControl { got } => {
                write!(f, "inconsistent frame control bits ({got:#06x})")
            }
            WireError::TooLarge { len, max } => {
                write!(f, "frame payload of {len} bytes exceeds the \
                           {max}-byte cap")
            }
            WireError::Corrupt { want, got } => {
                write!(f, "frame checksum mismatch \
                           (header says {want:#018x}, computed {got:#018x})")
            }
            WireError::ChunkOutOfOrder { want, got } => {
                write!(f, "chunk out of order (expected seq {want}, \
                           got {got})")
            }
            WireError::ChunkTruncated { chunks } => {
                write!(f, "stream ended mid-message ({chunks} chunk(s) \
                           arrived, no final chunk)")
            }
            WireError::Io(msg) => write!(f, "wire i/o error: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

/// FNV-1a over `chunks` in order (64-bit).
fn fnv1a(chunks: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for chunk in chunks {
        for &b in *chunk {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Encode one frame (header + payload) with explicit control bits.
pub(crate) fn encode_frame_ctrl(payload: &[u8], ctrl: u16)
                                -> Result<Vec<u8>, WireError> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(WireError::TooLarge {
            len: payload.len(),
            max: MAX_FRAME_LEN,
        });
    }
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    buf.extend_from_slice(&WIRE_MAGIC.to_be_bytes());
    buf.extend_from_slice(&WIRE_VERSION.to_be_bytes());
    buf.extend_from_slice(&ctrl.to_be_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    let sum = fnv1a(&[&buf[..12], payload]);
    buf.extend_from_slice(&sum.to_be_bytes());
    buf.extend_from_slice(payload);
    Ok(buf)
}

/// Encode one standalone frame (header + payload) into a fresh buffer.
pub fn encode_frame(payload: &[u8]) -> Result<Vec<u8>, WireError> {
    encode_frame_ctrl(payload, 0)
}

/// Frame layout for a message of `len` payload bytes: one `(byte
/// range, ctrl)` entry per frame — a single standalone frame when it
/// fits [`CHUNK_LEN`], a run of chunk entries (sequence numbers + FIN
/// on the last) otherwise. Callers encode each frame *just before*
/// writing it (`encode_frame_ctrl`, as the net layer's `send_message`
/// does), so a multi-MiB message is never materialized a second
/// time; chunks of *different* messages
/// must not interleave, so multi-frame writers serialize on a
/// per-connection bulk lock while releasing the frame lock between
/// chunks.
pub fn chunk_plan(len: usize)
                  -> Result<Vec<(std::ops::Range<usize>, u16)>,
                            WireError> {
    if len > MAX_FRAME_LEN {
        return Err(WireError::TooLarge { len, max: MAX_FRAME_LEN });
    }
    if len <= CHUNK_LEN {
        return Ok(vec![(0..len, 0)]);
    }
    let n_chunks = (len + CHUNK_LEN - 1) / CHUNK_LEN;
    debug_assert!(n_chunks <= CTRL_SEQ_MASK as usize,
                  "MAX_FRAME_LEN / CHUNK_LEN must fit the seq field");
    let mut out = Vec::with_capacity(n_chunks);
    for seq in 0..n_chunks {
        let start = seq * CHUNK_LEN;
        let end = (start + CHUNK_LEN).min(len);
        let mut ctrl = CTRL_CHUNKED | (seq as u16 & CTRL_SEQ_MASK);
        if seq + 1 == n_chunks {
            ctrl |= CTRL_FIN;
        }
        out.push((start..end, ctrl));
    }
    Ok(out)
}

/// Encode one message as ready-to-write frame buffers (the eager
/// convenience over [`chunk_plan`] — fine for tests and single-writer
/// streams; lock-sharing writers use the plan directly to avoid
/// buffering every chunk at once).
pub fn encode_chunks(payload: &[u8]) -> Result<Vec<Vec<u8>>, WireError> {
    chunk_plan(payload.len())?
        .into_iter()
        .map(|(range, ctrl)| encode_frame_ctrl(&payload[range], ctrl))
        .collect()
}

// -- total byte-field reads ----------------------------------------------
//
// Decode paths must never panic on peer bytes (the `no-panic-paths`
// lint rule enforces it), so header fields are read through these
// *total* helpers instead of `slice[a..b].try_into().unwrap()`:
// out-of-range bytes read as zero. Every caller checks the buffer
// length before parsing, and a genuinely short buffer surfaces as a
// magic/checksum mismatch — a typed error, never an index panic.

/// Big-endian `u16` at `at`; missing bytes read as zero.
pub(crate) fn be_u16(b: &[u8], at: usize) -> u16 {
    let mut a = [0u8; 2];
    for (d, s) in a.iter_mut().zip(b.iter().skip(at)) {
        *d = *s;
    }
    u16::from_be_bytes(a)
}

/// Big-endian `u32` at `at`; missing bytes read as zero.
pub(crate) fn be_u32(b: &[u8], at: usize) -> u32 {
    let mut a = [0u8; 4];
    for (d, s) in a.iter_mut().zip(b.iter().skip(at)) {
        *d = *s;
    }
    u32::from_be_bytes(a)
}

/// Big-endian `u64` at `at`; missing bytes read as zero.
pub(crate) fn be_u64(b: &[u8], at: usize) -> u64 {
    let mut a = [0u8; 8];
    for (d, s) in a.iter_mut().zip(b.iter().skip(at)) {
        *d = *s;
    }
    u64::from_be_bytes(a)
}

/// Little-endian `f32` from (up to) the first four bytes of `c`.
pub(crate) fn le_f32(c: &[u8]) -> f32 {
    let mut a = [0u8; 4];
    for (d, s) in a.iter_mut().zip(c.iter()) {
        *d = *s;
    }
    f32::from_le_bytes(a)
}

/// Write one pre-encoded frame buffer (from [`encode_frame`] /
/// [`encode_chunks`]) to `w` and flush.
pub fn write_encoded<W: Write>(w: &mut W, frame: &[u8])
                               -> Result<(), WireError> {
    w.write_all(frame).map_err(|e| WireError::Io(e.to_string()))?;
    w.flush().map_err(|e| WireError::Io(e.to_string()))
}

/// Write one message as a single standalone frame (no chunking; errors
/// `TooLarge` past [`MAX_FRAME_LEN`]). Single-writer convenience —
/// concurrent writers with large payloads use [`encode_chunks`] and
/// their own locking.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8])
                             -> Result<(), WireError> {
    let buf = encode_frame(payload)?;
    write_encoded(w, &buf)
}

/// Write one message, chunking oversized payloads (single-writer
/// convenience over [`encode_chunks`]).
pub fn write_message<W: Write>(w: &mut W, payload: &[u8])
                               -> Result<(), WireError> {
    for frame in encode_chunks(payload)? {
        write_encoded(w, &frame)?;
    }
    Ok(())
}

/// Fill `buf` from `r`; distinguishes clean close (zero bytes at
/// `already + 0`) from mid-frame truncation.
fn read_full<R: Read>(r: &mut R, buf: &mut [u8], already: usize,
                      want: usize) -> Result<(), WireError> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(if already + got == 0 {
                    WireError::Closed
                } else {
                    WireError::Truncated { got: already + got, want }
                });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
    Ok(())
}

/// Read one raw frame from `r`, validating magic, version, length cap
/// and checksum (in that order); returns its control bits + payload.
fn read_frame_raw<R: Read>(r: &mut R)
                           -> Result<(u16, Vec<u8>), WireError> {
    let mut hdr = [0u8; HEADER_LEN];
    // the payload length is unknown until the header is parsed, so
    // `want` for a header-stage truncation is the header itself
    read_full(r, &mut hdr, 0, HEADER_LEN)?;
    let magic = be_u32(&hdr, 0);
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic { got: magic });
    }
    let version = be_u16(&hdr, 4);
    if version != WIRE_VERSION {
        return Err(WireError::VersionSkew {
            got: version,
            want: WIRE_VERSION,
        });
    }
    let ctrl = be_u16(&hdr, 6);
    if ctrl != 0 && ctrl & CTRL_CHUNKED == 0 {
        // FIN or a seq number on a non-chunk frame: corruption
        return Err(WireError::BadControl { got: ctrl });
    }
    let len = be_u32(&hdr, 8) as usize;
    if len > MAX_FRAME_LEN {
        return Err(WireError::TooLarge { len, max: MAX_FRAME_LEN });
    }
    let want_sum = be_u64(&hdr, 12);
    let mut payload = vec![0u8; len];
    read_full(r, &mut payload, HEADER_LEN, HEADER_LEN + len)?;
    let got_sum = fnv1a(&[&hdr[..12], &payload]);
    if got_sum != want_sum {
        return Err(WireError::Corrupt { want: want_sum, got: got_sum });
    }
    Ok((ctrl, payload))
}

/// Stateful message reader: reassembles chunk runs, hands interleaved
/// standalone frames (heartbeats, typed errors) to the caller
/// *immediately* — mid-reassembly state survives across calls, so a
/// pong never waits behind a multi-chunk response. One per connection;
/// any error poisons the partial state (the caller closes the stream
/// on error anyway).
#[derive(Default)]
pub struct MessageReader {
    /// In-progress reassembly: next expected seq + accumulated bytes.
    partial: Option<(u16, Vec<u8>)>,
}

impl MessageReader {
    pub fn new() -> MessageReader {
        MessageReader { partial: None }
    }

    /// Read the next complete message from `r` (standalone frame, or
    /// the final chunk completing a run — possibly started on an
    /// earlier call).
    pub fn read<R: Read>(&mut self, r: &mut R)
                         -> Result<Vec<u8>, WireError> {
        loop {
            let (ctrl, payload) = match read_frame_raw(r) {
                Ok(fp) => fp,
                Err(WireError::Closed) => {
                    // clean close is only clean on a message boundary
                    return Err(match self.partial.take() {
                        Some((next_seq, _)) => {
                            WireError::ChunkTruncated { chunks: next_seq }
                        }
                        None => WireError::Closed,
                    });
                }
                Err(e) => {
                    self.partial = None;
                    return Err(e);
                }
            };
            if ctrl == 0 {
                // standalone frames pass through even mid-reassembly
                return Ok(payload);
            }
            let seq = ctrl & CTRL_SEQ_MASK;
            let fin = ctrl & CTRL_FIN != 0;
            let (next_seq, mut buf) = match self.partial.take() {
                None => {
                    if seq != 0 {
                        return Err(WireError::ChunkOutOfOrder {
                            want: 0,
                            got: seq,
                        });
                    }
                    (0u16, Vec::new())
                }
                Some((next_seq, buf)) => {
                    if seq != next_seq {
                        return Err(WireError::ChunkOutOfOrder {
                            want: next_seq,
                            got: seq,
                        });
                    }
                    (next_seq, buf)
                }
            };
            if buf.len() + payload.len() > MAX_FRAME_LEN {
                return Err(WireError::TooLarge {
                    len: buf.len() + payload.len(),
                    max: MAX_FRAME_LEN,
                });
            }
            buf.extend_from_slice(&payload);
            if fin {
                return Ok(buf);
            }
            self.partial = Some((next_seq + 1, buf));
        }
    }
}

/// Read one message from `r` (standalone or a full chunk run) with a
/// throwaway [`MessageReader`] — for callers that own the whole stream
/// (tests, handshakes). Long-lived connection loops keep their own
/// `MessageReader` so partial chunk state survives interleaved frames.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>, WireError> {
    MessageReader::new().read(r)
}

/// Incremental, non-blocking counterpart of [`MessageReader`] for
/// readiness-driven readers (the reactor): bytes are `push`ed in
/// whatever sizes the socket yields, complete messages are pulled out
/// with `next`. Validation is byte-for-byte the same as the blocking
/// path — magic, then version, then control-bit consistency, then the
/// length cap (all from the header alone, *before* the payload is
/// awaited), then the checksum once the payload is complete — and
/// chunk reassembly follows the same rules: contiguous sequence
/// numbers, standalone frames delivered immediately mid-run, the
/// [`MAX_FRAME_LEN`] cap on the reassembled message. Any error poisons
/// in-progress reassembly (the caller closes the connection on error).
#[derive(Default)]
pub struct FrameDecoder {
    /// Raw bytes not yet consumed; `off` marks the parse cursor so a
    /// burst of frames costs one compaction, not one drain per frame.
    buf: Vec<u8>,
    off: usize,
    /// In-progress chunk reassembly: next expected seq + bytes so far.
    partial: Option<(u16, Vec<u8>)>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Feed bytes read off the socket (any split, including one byte
    /// at a time).
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a complete frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.off
    }

    /// Reclaim consumed bytes once the cursor has moved far enough
    /// that the memmove is worth it (or everything was consumed).
    fn compact(&mut self) {
        if self.off == self.buf.len() {
            self.buf.clear();
            self.off = 0;
        } else if self.off >= CHUNK_LEN {
            self.buf.drain(..self.off);
            self.off = 0;
        }
    }

    /// Pull the next complete message, if the buffered bytes contain
    /// one. `Ok(None)` means "need more bytes"; call again after every
    /// `push` until it returns `None` (a single push can complete
    /// several messages).
    pub fn next(&mut self) -> Result<Option<Vec<u8>>, WireError> {
        loop {
            let avail = self.buf.len() - self.off;
            if avail < HEADER_LEN {
                self.compact();
                return Ok(None);
            }
            let hdr = &self.buf[self.off..self.off + HEADER_LEN];
            let magic = be_u32(hdr, 0);
            if magic != WIRE_MAGIC {
                self.partial = None;
                return Err(WireError::BadMagic { got: magic });
            }
            let version = be_u16(hdr, 4);
            if version != WIRE_VERSION {
                self.partial = None;
                return Err(WireError::VersionSkew {
                    got: version,
                    want: WIRE_VERSION,
                });
            }
            let ctrl = be_u16(hdr, 6);
            if ctrl != 0 && ctrl & CTRL_CHUNKED == 0 {
                self.partial = None;
                return Err(WireError::BadControl { got: ctrl });
            }
            let len = be_u32(hdr, 8) as usize;
            if len > MAX_FRAME_LEN {
                self.partial = None;
                return Err(WireError::TooLarge {
                    len,
                    max: MAX_FRAME_LEN,
                });
            }
            if avail < HEADER_LEN + len {
                self.compact();
                return Ok(None);
            }
            let want_sum = be_u64(hdr, 12);
            let start = self.off + HEADER_LEN;
            let payload = &self.buf[start..start + len];
            let got_sum =
                fnv1a(&[&self.buf[self.off..self.off + 12], payload]);
            if got_sum != want_sum {
                self.partial = None;
                return Err(WireError::Corrupt {
                    want: want_sum,
                    got: got_sum,
                });
            }
            let payload = payload.to_vec();
            self.off += HEADER_LEN + len;
            self.compact();
            if ctrl == 0 {
                // standalone frames pass through even mid-reassembly
                return Ok(Some(payload));
            }
            let seq = ctrl & CTRL_SEQ_MASK;
            let fin = ctrl & CTRL_FIN != 0;
            let (next_seq, mut msg) = match self.partial.take() {
                None => {
                    if seq != 0 {
                        return Err(WireError::ChunkOutOfOrder {
                            want: 0,
                            got: seq,
                        });
                    }
                    (0u16, Vec::new())
                }
                Some((next_seq, msg)) => {
                    if seq != next_seq {
                        return Err(WireError::ChunkOutOfOrder {
                            want: next_seq,
                            got: seq,
                        });
                    }
                    (next_seq, msg)
                }
            };
            if msg.len() + payload.len() > MAX_FRAME_LEN {
                return Err(WireError::TooLarge {
                    len: msg.len() + payload.len(),
                    max: MAX_FRAME_LEN,
                });
            }
            msg.extend_from_slice(&payload);
            if fin {
                return Ok(Some(msg));
            }
            self.partial = Some((next_seq + 1, msg));
        }
    }

    /// What a peer close means *right now*: [`WireError::Closed`] on a
    /// clean message boundary, [`WireError::ChunkTruncated`] mid-run,
    /// [`WireError::Truncated`] mid-frame — the same trichotomy the
    /// blocking reader reports.
    pub fn close_error(&self) -> WireError {
        if let Some((next_seq, _)) = &self.partial {
            return WireError::ChunkTruncated { chunks: *next_seq };
        }
        let avail = self.buf.len() - self.off;
        if avail == 0 {
            WireError::Closed
        } else if avail < HEADER_LEN {
            WireError::Truncated { got: avail, want: HEADER_LEN }
        } else {
            let len = be_u32(&self.buf, self.off + 8) as usize;
            WireError::Truncated { got: avail, want: HEADER_LEN + len }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, Gen};
    use std::io::Cursor;

    fn random_payload(g: &mut Gen) -> Vec<u8> {
        let n = g.usize_in(0, 300);
        (0..n).map(|_| g.usize_in(0, 255) as u8).collect()
    }

    #[test]
    fn empty_and_small_frames_roundtrip() {
        for payload in [&b""[..], b"x", b"{\"type\":\"ping\",\"seq\":1}"] {
            let buf = encode_frame(payload).unwrap();
            assert_eq!(buf.len(), HEADER_LEN + payload.len());
            let back = read_frame(&mut Cursor::new(&buf)).unwrap();
            assert_eq!(back, payload);
        }
    }

    #[test]
    fn back_to_back_frames_keep_boundaries() {
        let mut stream = Vec::new();
        write_frame(&mut stream, b"first").unwrap();
        write_frame(&mut stream, b"").unwrap();
        write_frame(&mut stream, b"third frame").unwrap();
        let mut c = Cursor::new(&stream);
        assert_eq!(read_frame(&mut c).unwrap(), b"first");
        assert_eq!(read_frame(&mut c).unwrap(), b"");
        assert_eq!(read_frame(&mut c).unwrap(), b"third frame");
        // clean EOF at the boundary is Closed, not Truncated
        assert_eq!(read_frame(&mut c).unwrap_err(), WireError::Closed);
    }

    #[test]
    fn oversized_message_chunks_and_reassembles() {
        // deterministic non-constant payload spanning several chunks,
        // ending mid-chunk (the last chunk is shorter)
        let n = 2 * CHUNK_LEN + CHUNK_LEN / 3 + 7;
        let payload: Vec<u8> = (0..n).map(|i| (i * 31 % 251) as u8).collect();
        let frames = encode_chunks(&payload).unwrap();
        assert_eq!(frames.len(), 3);
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend_from_slice(f);
        }
        let back = read_frame(&mut Cursor::new(&stream)).unwrap();
        assert_eq!(back, payload);
        // write_message produces the same stream
        let mut via_write = Vec::new();
        write_message(&mut via_write, &payload).unwrap();
        assert_eq!(via_write, stream);
    }

    #[test]
    fn small_message_stays_one_frame() {
        let frames = encode_chunks(b"small").unwrap();
        assert_eq!(frames.len(), 1);
        assert_eq!(frames[0], encode_frame(b"small").unwrap());
    }

    #[test]
    fn standalone_frame_interleaves_between_chunks() {
        // a pong squeezed between chunk 0 and chunk 1 must be
        // delivered *first*, and the chunked message must still
        // reassemble afterwards — this is the liveness property the
        // chunking exists for
        let big: Vec<u8> = vec![0xCD; CHUNK_LEN + 100];
        let frames = encode_chunks(&big).unwrap();
        assert_eq!(frames.len(), 2);
        let mut stream = Vec::new();
        stream.extend_from_slice(&frames[0]);
        write_frame(&mut stream, b"pong!").unwrap();
        stream.extend_from_slice(&frames[1]);
        let mut c = Cursor::new(&stream);
        let mut mr = MessageReader::new();
        assert_eq!(mr.read(&mut c).unwrap(), b"pong!");
        assert_eq!(mr.read(&mut c).unwrap(), big);
        assert_eq!(mr.read(&mut c).unwrap_err(), WireError::Closed);
    }

    #[test]
    fn chunk_out_of_order_is_typed() {
        let big: Vec<u8> = vec![7; 2 * CHUNK_LEN + 10];
        let frames = encode_chunks(&big).unwrap();
        assert_eq!(frames.len(), 3);
        // drop the middle chunk
        let mut stream = Vec::new();
        stream.extend_from_slice(&frames[0]);
        stream.extend_from_slice(&frames[2]);
        match read_frame(&mut Cursor::new(&stream)) {
            Err(WireError::ChunkOutOfOrder { want: 1, got: 2 }) => {}
            other => panic!("expected ChunkOutOfOrder, got {other:?}"),
        }
        // a run starting mid-sequence is equally typed
        let mut stream = Vec::new();
        stream.extend_from_slice(&frames[1]);
        match read_frame(&mut Cursor::new(&stream)) {
            Err(WireError::ChunkOutOfOrder { want: 0, got: 1 }) => {}
            other => panic!("expected ChunkOutOfOrder, got {other:?}"),
        }
    }

    #[test]
    fn chunk_run_cut_clean_is_chunk_truncated() {
        let big: Vec<u8> = vec![9; CHUNK_LEN + 50];
        let frames = encode_chunks(&big).unwrap();
        // stream ends cleanly after chunk 0 — a peer that died between
        // chunks, not a clean message boundary
        match read_frame(&mut Cursor::new(&frames[0])) {
            Err(WireError::ChunkTruncated { chunks: 1 }) => {}
            other => panic!("expected ChunkTruncated, got {other:?}"),
        }
    }

    #[test]
    fn encode_rejects_past_the_reassembly_cap() {
        // the chunker refuses to build a message the reader would
        // reject; a zeroed vec keeps this cheap
        assert!(matches!(
            encode_chunks(&vec![0u8; MAX_FRAME_LEN + 1]),
            Err(WireError::TooLarge { .. })
        ));
    }

    #[test]
    fn prop_arbitrary_payload_roundtrips() {
        check("wire frame roundtrip", 300, |g: &mut Gen| {
            let payload = random_payload(g);
            let buf = encode_frame(&payload)
                .map_err(|e| e.to_string())?;
            let back = read_frame(&mut Cursor::new(&buf))
                .map_err(|e| e.to_string())?;
            if back != payload {
                return Err("payload mutated in transit".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_truncation_is_typed_never_a_panic() {
        check("wire truncation rejected", 300, |g: &mut Gen| {
            let payload = random_payload(g);
            let buf = encode_frame(&payload).unwrap();
            // any strict prefix must fail typed: Closed only for the
            // empty prefix, Truncated for everything else
            let cut = g.usize_in(0, buf.len() - 1);
            match read_frame(&mut Cursor::new(&buf[..cut])) {
                Err(WireError::Closed) if cut == 0 => Ok(()),
                Err(WireError::Truncated { got, want }) => {
                    if got == cut && want > got {
                        Ok(())
                    } else {
                        Err(format!("bad accounting: got {got} want \
                                     {want} at cut {cut}"))
                    }
                }
                Err(other) => {
                    Err(format!("cut {cut}: unexpected {other}"))
                }
                Ok(_) => Err(format!("cut {cut}: accepted a truncated \
                                      frame")),
            }
        });
    }

    #[test]
    fn prop_single_byte_corruption_is_rejected() {
        check("wire corruption rejected", 300, |g: &mut Gen| {
            let payload = random_payload(g);
            let mut buf = encode_frame(&payload).unwrap();
            let at = g.usize_in(0, buf.len() - 1);
            // guaranteed-different byte so the frame really changed
            buf[at] ^= (g.usize_in(1, 255) as u8).max(1);
            match read_frame(&mut Cursor::new(&buf)) {
                // which typed error depends on the field hit: magic,
                // version, control bits, a length now pointing past
                // the buffer (Truncated) or over the cap (TooLarge),
                // a ctrl flip that fakes a chunk run (ChunkOutOfOrder/
                // ChunkTruncated), or the checksum catch-all.
                // Accepting the frame with the original payload can
                // only happen if corruption made the length *smaller*
                // and the checksum still matched — the checksum covers
                // the length bytes, so never.
                Err(_) => Ok(()),
                Ok(back) => Err(format!(
                    "corrupt byte {at} accepted ({} bytes back)",
                    back.len()
                )),
            }
        });
    }

    #[test]
    fn version_skew_is_named_before_checksum() {
        let mut buf = encode_frame(b"hello").unwrap();
        // patch the version field (bytes 4..6) to a foreign version
        buf[4..6].copy_from_slice(&9u16.to_be_bytes());
        match read_frame(&mut Cursor::new(&buf)) {
            Err(WireError::VersionSkew { got: 9, want }) => {
                assert_eq!(want, WIRE_VERSION);
            }
            other => panic!("expected VersionSkew, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_is_named() {
        let mut buf = encode_frame(b"hello").unwrap();
        buf[0] = b'X';
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(WireError::BadMagic { .. })
        ));
    }

    #[test]
    fn oversized_length_rejected_without_allocating() {
        let mut buf = encode_frame(b"tiny").unwrap();
        // patch the length field to 3 GiB; the reader must reject from
        // the header alone (a vec![0; 3<<30] here would OOM the test)
        buf[8..12].copy_from_slice(&(3u32 << 30).to_be_bytes());
        match read_frame(&mut Cursor::new(&buf)) {
            Err(WireError::TooLarge { len, max }) => {
                assert_eq!(len, (3usize) << 30);
                assert_eq!(max, MAX_FRAME_LEN);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // and the encoder refuses to build one in the first place
        assert!(matches!(
            encode_frame(&vec![0u8; MAX_FRAME_LEN + 1]),
            Err(WireError::TooLarge { .. })
        ));
    }

    #[test]
    fn stray_control_bits_without_chunked_are_rejected() {
        // FIN (bit 14) or a seq number set on a standalone frame is
        // corruption, not a chunk
        let mut buf = encode_frame(b"hello").unwrap();
        buf[6..8].copy_from_slice(&CTRL_FIN.to_be_bytes());
        // re-checksum so only the ctrl inconsistency can trip
        let sum = fnv1a(&[&buf[..12], b"hello"]);
        buf[12..20].copy_from_slice(&sum.to_be_bytes());
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(WireError::BadControl { .. })
        ));
    }

    #[test]
    fn flipped_payload_bit_hits_the_checksum() {
        let mut buf = encode_frame(b"payload bytes").unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x01;
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(WireError::Corrupt { .. })
        ));
    }

    /// Drain every message the decoder can currently produce.
    fn drain(d: &mut FrameDecoder) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        while let Some(m) = d.next().expect("decode") {
            out.push(m);
        }
        out
    }

    #[test]
    fn decoder_handles_byte_at_a_time_feeds() {
        let mut stream = Vec::new();
        write_frame(&mut stream, b"first").unwrap();
        write_frame(&mut stream, b"").unwrap();
        write_frame(&mut stream, b"third frame").unwrap();
        let mut d = FrameDecoder::new();
        let mut got = Vec::new();
        for b in &stream {
            d.push(&[*b]);
            got.extend(drain(&mut d));
        }
        assert_eq!(got, vec![b"first".to_vec(), b"".to_vec(),
                             b"third frame".to_vec()]);
        assert_eq!(d.close_error(), WireError::Closed);
        assert_eq!(d.buffered(), 0);
    }

    #[test]
    fn decoder_delivers_interleaved_standalone_mid_run() {
        // same liveness property as the blocking reader: a pong
        // between chunks surfaces *before* the chunked message, even
        // when the bytes arrive in awkward splits
        let big: Vec<u8> = (0..CHUNK_LEN + 100)
            .map(|i| (i * 17 % 251) as u8)
            .collect();
        let frames = encode_chunks(&big).unwrap();
        assert_eq!(frames.len(), 2);
        let mut stream = Vec::new();
        stream.extend_from_slice(&frames[0]);
        write_frame(&mut stream, b"pong!").unwrap();
        stream.extend_from_slice(&frames[1]);
        check("decoder interleave under splits", 60, |g: &mut Gen| {
            let mut d = FrameDecoder::new();
            let mut got = Vec::new();
            let mut at = 0usize;
            while at < stream.len() {
                let take = g.usize_in(1, 4096).min(stream.len() - at);
                d.push(&stream[at..at + take]);
                at += take;
                got.extend(
                    drain(&mut d).into_iter().map(|m| m.len()),
                );
            }
            if got != vec![5, big.len()] {
                return Err(format!("messages out of order: {got:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn decoder_close_error_is_position_aware() {
        let mut d = FrameDecoder::new();
        assert_eq!(d.close_error(), WireError::Closed);
        // mid-header
        d.push(&[0x54, 0x51]);
        assert_eq!(d.close_error(),
                   WireError::Truncated { got: 2, want: HEADER_LEN });
        // header complete, payload pending
        let frame = encode_frame(b"abcdef").unwrap();
        let mut d = FrameDecoder::new();
        d.push(&frame[..HEADER_LEN + 2]);
        assert_eq!(d.next().unwrap(), None);
        assert_eq!(d.close_error(),
                   WireError::Truncated { got: HEADER_LEN + 2,
                                          want: HEADER_LEN + 6 });
        // mid-chunk-run: one full chunk arrived, no FIN
        let big: Vec<u8> = vec![3; CHUNK_LEN + 9];
        let frames = encode_chunks(&big).unwrap();
        let mut d = FrameDecoder::new();
        d.push(&frames[0]);
        assert_eq!(d.next().unwrap(), None);
        assert_eq!(d.close_error(),
                   WireError::ChunkTruncated { chunks: 1 });
    }

    #[test]
    fn decoder_rejects_what_the_blocking_reader_rejects() {
        // corruption surfaces as the same typed errors (spot checks;
        // full coverage rides on the shared validation order)
        let mut bad = encode_frame(b"x").unwrap();
        bad[0] = b'Z';
        let mut d = FrameDecoder::new();
        d.push(&bad);
        assert!(matches!(d.next(), Err(WireError::BadMagic { .. })));

        let mut skew = encode_frame(b"x").unwrap();
        skew[4..6].copy_from_slice(&7u16.to_be_bytes());
        let mut d = FrameDecoder::new();
        d.push(&skew);
        assert!(matches!(d.next(), Err(WireError::VersionSkew { .. })));

        // an oversized length is rejected from the header alone —
        // no waiting for (and no allocating) 3 GiB of payload
        let mut huge = encode_frame(b"x").unwrap();
        huge[8..12].copy_from_slice(&(3u32 << 30).to_be_bytes());
        let mut d = FrameDecoder::new();
        d.push(&huge[..HEADER_LEN]);
        assert!(matches!(d.next(), Err(WireError::TooLarge { .. })));
    }

    #[test]
    fn prop_decoder_matches_blocking_reader_on_message_streams() {
        check("decoder equivalence", 80, |g: &mut Gen| {
            // a random run of messages, some big enough to chunk
            let n_msgs = g.usize_in(1, 5);
            let msgs: Vec<Vec<u8>> = (0..n_msgs)
                .map(|_| {
                    let n = if g.usize_in(0, 3) == 0 {
                        g.usize_in(CHUNK_LEN, CHUNK_LEN * 2 + 50)
                    } else {
                        g.usize_in(0, 300)
                    };
                    (0..n).map(|i| (i * 13 % 251) as u8).collect()
                })
                .collect();
            let mut stream = Vec::new();
            for m in &msgs {
                write_message(&mut stream, m).unwrap();
            }
            let mut d = FrameDecoder::new();
            let mut got = Vec::new();
            let mut at = 0usize;
            while at < stream.len() {
                let take =
                    g.usize_in(1, 100_000).min(stream.len() - at);
                d.push(&stream[at..at + take]);
                at += take;
                got.extend(drain(&mut d));
            }
            if got != msgs {
                return Err("decoded stream diverged".into());
            }
            if d.close_error() != WireError::Closed {
                return Err("clean boundary misreported".into());
            }
            Ok(())
        });
    }
}
