//! Length-prefixed binary frame codec for the cross-node wire.
//!
//! Every message on a shard connection travels as one *frame*: a
//! fixed 20-byte header followed by an opaque payload (the canonical
//! JSON of a [`crate::serve::net::proto::Msg`], but the codec never
//! looks inside). Big-endian header layout:
//!
//! ```text
//! offset  size  field
//!      0     4  magic      0x54514454 ("TQDT")
//!      4     2  version    WIRE_VERSION (readers reject any other)
//!      6     2  reserved   must be zero
//!      8     4  payload length (bytes, <= MAX_FRAME_LEN)
//!     12     8  checksum   FNV-1a over header[0..12] ++ payload
//!     20     …  payload
//! ```
//!
//! Decoding is total: every malformed input maps to a typed
//! [`WireError`] — bad magic, a version-skewed peer, an oversized
//! length (rejected *before* allocating), a flipped bit anywhere in
//! header or payload (the checksum covers both), a stream truncated
//! mid-frame, or a clean close at a frame boundary ([`WireError::Closed`],
//! the one non-error exit). Nothing in this module panics on input
//! bytes — property-tested below in the `coordinator/store.rs` style.

use std::fmt;
use std::io::{Read, Write};

/// Frame magic: "TQDT" as a big-endian u32.
pub const WIRE_MAGIC: u32 = 0x5451_4454;
/// Protocol version; bumped on any incompatible message change.
/// Readers reject every other version with [`WireError::VersionSkew`].
pub const WIRE_VERSION: u16 = 1;
/// Hard cap on one frame's payload. Generous for image responses
/// (a 16-slot rung of 64x64x3 f32 images serializes well under 16 MiB)
/// while keeping a corrupted length field from allocating gigabytes.
pub const MAX_FRAME_LEN: usize = 64 << 20;
/// Fixed header size (see module docs for the layout).
pub const HEADER_LEN: usize = 20;

/// Typed wire-level failure. `Closed` is the clean-EOF signal every
/// reader loop must treat as "peer hung up", not as corruption.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The stream ended cleanly on a frame boundary.
    Closed,
    /// The stream ended mid-frame (`got` of `want` bytes arrived).
    Truncated { got: usize, want: usize },
    /// The first four bytes were not the frame magic.
    BadMagic { got: u32 },
    /// The peer speaks a different protocol version.
    VersionSkew { got: u16, want: u16 },
    /// Reserved header bytes were non-zero (header corruption).
    BadReserved { got: u16 },
    /// Declared payload length exceeds [`MAX_FRAME_LEN`].
    TooLarge { len: usize, max: usize },
    /// Checksum mismatch: a bit flipped in header or payload.
    Corrupt { want: u64, got: u64 },
    /// Underlying I/O failure (connection reset, …).
    Io(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Closed => write!(f, "connection closed"),
            WireError::Truncated { got, want } => {
                write!(f, "frame truncated ({got} of {want} bytes)")
            }
            WireError::BadMagic { got } => {
                write!(f, "bad frame magic {got:#010x} \
                           (expected {WIRE_MAGIC:#010x})")
            }
            WireError::VersionSkew { got, want } => {
                write!(f, "wire version skew: peer speaks v{got}, \
                           this build speaks v{want}")
            }
            WireError::BadReserved { got } => {
                write!(f, "reserved frame header bytes set ({got:#06x})")
            }
            WireError::TooLarge { len, max } => {
                write!(f, "frame payload of {len} bytes exceeds the \
                           {max}-byte cap")
            }
            WireError::Corrupt { want, got } => {
                write!(f, "frame checksum mismatch \
                           (header says {want:#018x}, computed {got:#018x})")
            }
            WireError::Io(msg) => write!(f, "wire i/o error: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

/// FNV-1a over `chunks` in order (64-bit).
fn fnv1a(chunks: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for chunk in chunks {
        for &b in *chunk {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Encode one frame (header + payload) into a fresh buffer.
pub fn encode_frame(payload: &[u8]) -> Result<Vec<u8>, WireError> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(WireError::TooLarge {
            len: payload.len(),
            max: MAX_FRAME_LEN,
        });
    }
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    buf.extend_from_slice(&WIRE_MAGIC.to_be_bytes());
    buf.extend_from_slice(&WIRE_VERSION.to_be_bytes());
    buf.extend_from_slice(&0u16.to_be_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    let sum = fnv1a(&[&buf[..12], payload]);
    buf.extend_from_slice(&sum.to_be_bytes());
    buf.extend_from_slice(payload);
    Ok(buf)
}

/// Write one frame to `w` (single `write_all` + flush, so frames from
/// different threads stay atomic as long as callers serialize on the
/// writer — the node/cluster writer mutex does).
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8])
                             -> Result<(), WireError> {
    let buf = encode_frame(payload)?;
    w.write_all(&buf).map_err(|e| WireError::Io(e.to_string()))?;
    w.flush().map_err(|e| WireError::Io(e.to_string()))
}

/// Fill `buf` from `r`; distinguishes clean close (zero bytes at
/// `already + 0`) from mid-frame truncation.
fn read_full<R: Read>(r: &mut R, buf: &mut [u8], already: usize,
                      want: usize) -> Result<(), WireError> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(if already + got == 0 {
                    WireError::Closed
                } else {
                    WireError::Truncated { got: already + got, want }
                });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
    Ok(())
}

/// Read one frame's payload from `r`, validating magic, version,
/// reserved bytes, length cap and checksum (in that order).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>, WireError> {
    let mut hdr = [0u8; HEADER_LEN];
    // the payload length is unknown until the header is parsed, so
    // `want` for a header-stage truncation is the header itself
    read_full(r, &mut hdr, 0, HEADER_LEN)?;
    let magic = u32::from_be_bytes(hdr[0..4].try_into().unwrap());
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic { got: magic });
    }
    let version = u16::from_be_bytes(hdr[4..6].try_into().unwrap());
    if version != WIRE_VERSION {
        return Err(WireError::VersionSkew {
            got: version,
            want: WIRE_VERSION,
        });
    }
    let reserved = u16::from_be_bytes(hdr[6..8].try_into().unwrap());
    if reserved != 0 {
        return Err(WireError::BadReserved { got: reserved });
    }
    let len = u32::from_be_bytes(hdr[8..12].try_into().unwrap()) as usize;
    if len > MAX_FRAME_LEN {
        return Err(WireError::TooLarge { len, max: MAX_FRAME_LEN });
    }
    let want_sum = u64::from_be_bytes(hdr[12..20].try_into().unwrap());
    let mut payload = vec![0u8; len];
    read_full(r, &mut payload, HEADER_LEN, HEADER_LEN + len)?;
    let got_sum = fnv1a(&[&hdr[..12], &payload]);
    if got_sum != want_sum {
        return Err(WireError::Corrupt { want: want_sum, got: got_sum });
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, Gen};
    use std::io::Cursor;

    fn random_payload(g: &mut Gen) -> Vec<u8> {
        let n = g.usize_in(0, 300);
        (0..n).map(|_| g.usize_in(0, 255) as u8).collect()
    }

    #[test]
    fn empty_and_small_frames_roundtrip() {
        for payload in [&b""[..], b"x", b"{\"type\":\"ping\",\"seq\":1}"] {
            let buf = encode_frame(payload).unwrap();
            assert_eq!(buf.len(), HEADER_LEN + payload.len());
            let back = read_frame(&mut Cursor::new(&buf)).unwrap();
            assert_eq!(back, payload);
        }
    }

    #[test]
    fn back_to_back_frames_keep_boundaries() {
        let mut stream = Vec::new();
        write_frame(&mut stream, b"first").unwrap();
        write_frame(&mut stream, b"").unwrap();
        write_frame(&mut stream, b"third frame").unwrap();
        let mut c = Cursor::new(&stream);
        assert_eq!(read_frame(&mut c).unwrap(), b"first");
        assert_eq!(read_frame(&mut c).unwrap(), b"");
        assert_eq!(read_frame(&mut c).unwrap(), b"third frame");
        // clean EOF at the boundary is Closed, not Truncated
        assert_eq!(read_frame(&mut c).unwrap_err(), WireError::Closed);
    }

    #[test]
    fn prop_arbitrary_payload_roundtrips() {
        check("wire frame roundtrip", 300, |g: &mut Gen| {
            let payload = random_payload(g);
            let buf = encode_frame(&payload)
                .map_err(|e| e.to_string())?;
            let back = read_frame(&mut Cursor::new(&buf))
                .map_err(|e| e.to_string())?;
            if back != payload {
                return Err("payload mutated in transit".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_truncation_is_typed_never_a_panic() {
        check("wire truncation rejected", 300, |g: &mut Gen| {
            let payload = random_payload(g);
            let buf = encode_frame(&payload).unwrap();
            // any strict prefix must fail typed: Closed only for the
            // empty prefix, Truncated for everything else
            let cut = g.usize_in(0, buf.len() - 1);
            match read_frame(&mut Cursor::new(&buf[..cut])) {
                Err(WireError::Closed) if cut == 0 => Ok(()),
                Err(WireError::Truncated { got, want }) => {
                    if got == cut && want > got {
                        Ok(())
                    } else {
                        Err(format!("bad accounting: got {got} want \
                                     {want} at cut {cut}"))
                    }
                }
                Err(other) => {
                    Err(format!("cut {cut}: unexpected {other}"))
                }
                Ok(_) => Err(format!("cut {cut}: accepted a truncated \
                                      frame")),
            }
        });
    }

    #[test]
    fn prop_single_byte_corruption_is_rejected() {
        check("wire corruption rejected", 300, |g: &mut Gen| {
            let payload = random_payload(g);
            let mut buf = encode_frame(&payload).unwrap();
            let at = g.usize_in(0, buf.len() - 1);
            // guaranteed-different byte so the frame really changed
            buf[at] ^= (g.usize_in(1, 255) as u8).max(1);
            match read_frame(&mut Cursor::new(&buf)) {
                // which typed error depends on the field hit: magic,
                // version, reserved, a length now pointing past the
                // buffer (Truncated) or over the cap (TooLarge), or
                // the checksum catch-all. Accepting the frame with the
                // original payload can only happen if corruption made
                // the length *smaller* and the checksum still matched —
                // the checksum covers the length bytes, so never.
                Err(_) => Ok(()),
                Ok(back) => Err(format!(
                    "corrupt byte {at} accepted ({} bytes back)",
                    back.len()
                )),
            }
        });
    }

    #[test]
    fn version_skew_is_named_before_checksum() {
        let mut buf = encode_frame(b"hello").unwrap();
        // patch the version field (bytes 4..6) to v2
        buf[4..6].copy_from_slice(&2u16.to_be_bytes());
        match read_frame(&mut Cursor::new(&buf)) {
            Err(WireError::VersionSkew { got: 2, want }) => {
                assert_eq!(want, WIRE_VERSION);
            }
            other => panic!("expected VersionSkew, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_is_named() {
        let mut buf = encode_frame(b"hello").unwrap();
        buf[0] = b'X';
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(WireError::BadMagic { .. })
        ));
    }

    #[test]
    fn oversized_length_rejected_without_allocating() {
        let mut buf = encode_frame(b"tiny").unwrap();
        // patch the length field to 3 GiB; the reader must reject from
        // the header alone (a vec![0; 3<<30] here would OOM the test)
        buf[8..12].copy_from_slice(&(3u32 << 30).to_be_bytes());
        match read_frame(&mut Cursor::new(&buf)) {
            Err(WireError::TooLarge { len, max }) => {
                assert_eq!(len, (3usize) << 30);
                assert_eq!(max, MAX_FRAME_LEN);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // and the encoder refuses to build one in the first place
        assert!(matches!(
            encode_frame(&vec![0u8; MAX_FRAME_LEN + 1]),
            Err(WireError::TooLarge { .. })
        ));
    }

    #[test]
    fn reserved_bytes_must_be_zero() {
        let mut buf = encode_frame(b"hello").unwrap();
        buf[6] = 0xAB;
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(WireError::BadReserved { .. })
        ));
    }

    #[test]
    fn flipped_payload_bit_hits_the_checksum() {
        let mut buf = encode_frame(b"payload bytes").unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x01;
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(WireError::Corrupt { .. })
        ));
    }
}
