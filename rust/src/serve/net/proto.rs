//! Cross-node protocol messages, serialized inside
//! [`wire`](crate::serve::net::wire) frames — canonical JSON for
//! control traffic, an optional raw binary encoding for image tensors.
//!
//! One [`Msg`] enum covers both directions of a shard connection:
//!
//! * frontend → node: `Hello` (optional first message tagging the
//!   connection's [`Role`] — `control` connections carry only
//!   ping/pong/stats so liveness never queues behind response bytes;
//!   an untagged connection is `data`, the pre-handshake behavior —
//!   and advertising the sender's highest supported wire feature
//!   level, `max_wire`), `Submit` (one generation request, carrying
//!   the *frontend's* request id — the node echoes it back, so each
//!   connection is its own id namespace), `Ping`, `StatsReq`;
//! * node → frontend: `HelloAck` (the negotiated feature level; only
//!   sent when the hello advertised more than the v2 baseline),
//!   `Response` / `ErrorResp` (terminal, exactly one per submitted
//!   id), `Reject` (connection-level typed refusal, e.g. the node
//!   cannot take another connection), `Pong` (queue depth + worker
//!   counts, the load-balancing signal), `Stats` (a live
//!   [`ServerStats`] snapshot, answering `StatsReq`), `StatsDelta`
//!   (reactor mode: stats *pushed* on the control connection —
//!   additive counters carry the increment since the previous push on
//!   this connection, gauges carry current absolute values).
//!
//! # Wire feature negotiation (`max_wire`)
//!
//! The frame header version stays [`wire::WIRE_VERSION`] = 2 — framing
//! and chunking are unchanged. On top of it, peers negotiate a
//! *feature level*: a frontend advertising [`WIRE_BINARY`] (= 3) in
//! its hello tells the node it may answer `Submit`s with the binary
//! response payload below; the node confirms with `HelloAck`. Either
//! side omitting the field (or advertising 2) pins the connection to
//! all-JSON — old and new peers interoperate in both directions.
//!
//! # Binary response payload
//!
//! JSON-encoding a multi-MiB `f32` tensor costs ~10 bytes and a float
//! parse per pixel. The binary response encodes the same message as
//! raw little-endian `f32` frame bytes behind a 22-byte header:
//!
//! ```text
//! offset  size  field
//!      0     1  0x00        (binary marker; JSON always starts '{')
//!      1     1  'R'         (payload kind: response)
//!      2     8  id          u64 big-endian
//!     10     8  latency_s   f64 big-endian
//!     18     4  n_pixels    u32 big-endian
//!     22  4×n   pixels      f32 little-endian (native GPU layout,
//!                            bit-for-bit — no text roundtrip)
//! ```
//!
//! [`Msg::decode`] accepts both encodings unconditionally (the marker
//! byte disambiguates); *emitting* binary requires the negotiated
//! feature level, so a v2 peer never sees it. Control messages stay
//! JSON at every feature level.
//!
//! Serde follows the `coordinator/store.rs` conventions: the canonical
//! serializer in [`crate::util::json`] (sorted keys, shortest-roundtrip
//! floats, so every `f32` image pixel survives the wire bit-for-bit),
//! and decoding validates everything — counts must be whole
//! non-negative numbers, floats finite, kinds known, binary payloads
//! exactly sized — returning typed errors, never panicking on peer
//! bytes.

use anyhow::{bail, Context, Result};

use crate::obs::hist::LatencyHist;
use crate::obs::trace::{SpanRec, TraceCtx};
use crate::serve::error::ServeError;
use crate::serve::net::wire;
use crate::serve::net::wire::WIRE_VERSION;
use crate::serve::router::{RungStats, ServerStats, WorkerStats};
use crate::util::json::Json;

/// Wire feature level that unlocks binary tensor payloads. Negotiated
/// per connection via `Hello::max_wire` + `HelloAck`; the frame-header
/// version stays [`WIRE_VERSION`] regardless.
pub const WIRE_BINARY: u16 = 3;

/// Wire feature level that unlocks trace propagation: `Submit` may
/// carry a trace context (`tr`/`sp` hex ids) and `Response` may carry
/// the node's spans for that trace. Implies [`WIRE_BINARY`]. A peer
/// pinned below this level simply never sees the fields — the request
/// still serves, it just contributes no node-side spans (graceful
/// version-skew degradation); decoding is tolerant at *every* level,
/// so a mid-negotiation message with trace fields never kills a
/// connection.
pub const WIRE_TRACE: u16 = 4;

/// Marker byte opening every binary payload (JSON starts with `{`).
const BIN_MARKER: u8 = 0x00;
/// Binary payload kind: response.
const BIN_RESPONSE: u8 = b'R';
/// Binary response header length (marker + kind + id + latency + n).
const BIN_RESP_HEADER: usize = 22;

/// What a shard connection is for. The frontend opens one `Data`
/// connection (submits out, responses back) and — unless the control
/// plane is disabled — one `Control` connection (ping/pong/stats
/// only), so a pong can never queue behind a multi-MiB response frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Data,
    Control,
}

impl Role {
    pub fn name(&self) -> &'static str {
        match self {
            Role::Data => "data",
            Role::Control => "control",
        }
    }

    pub fn parse(s: &str) -> Option<Role> {
        match s {
            "data" => Some(Role::Data),
            "control" => Some(Role::Control),
            _ => None,
        }
    }
}

/// One frame's payload, either direction of a shard connection.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Frontend → node, first message on a connection: what this
    /// connection carries, plus the sender's highest supported wire
    /// feature level (see [`WIRE_BINARY`]; absent on the wire means
    /// the v2 all-JSON baseline). Nodes treat a connection without a
    /// hello as `data` at the baseline level (raw clients,
    /// pre-handshake frontends).
    Hello { role: Role, max_wire: u16 },
    /// Node → frontend: the feature level this connection will use
    /// (`min` of both sides). Only sent when the hello advertised past
    /// the baseline, so baseline peers never see it.
    HelloAck { wire: u16 },
    /// Frontend → node: run `n` images of `class`; the node answers
    /// with a `Response`/`ErrorResp` echoing `id`. `trace` carries the
    /// frontend's trace id plus its dispatch span for this request
    /// ([`TraceCtx::NONE`] — nothing on the wire — when untraced or
    /// below [`WIRE_TRACE`]).
    Submit { id: u64, class: i32, n: usize, trace: TraceCtx },
    /// Node → frontend: the completed request (flat pixels, node-side
    /// queue+compute latency). JSON at the baseline level, raw binary
    /// (see module docs) once [`WIRE_BINARY`] is negotiated — except a
    /// traced response (`spans` non-empty, the node's spans for the
    /// request's trace, re-based by the ingesting frontend), which
    /// stays JSON at every level so the span list has somewhere to
    /// ride.
    Response {
        id: u64,
        latency_s: f64,
        images: Vec<f32>,
        spans: Vec<SpanRec>,
    },
    /// Node → frontend: the request failed with a typed error.
    ErrorResp { id: u64, err: ServeError },
    /// Node → frontend: connection-level typed refusal — no request id
    /// (nothing was submitted); the node closes after sending. The
    /// accept path uses it when it cannot take the connection at all.
    Reject { err: ServeError },
    /// Frontend → node heartbeat probe.
    Ping { seq: u64 },
    /// Node → frontend heartbeat reply: the dispatch signal (queued
    /// slots) plus worker liveness.
    Pong {
        seq: u64,
        queue_depth: usize,
        live_workers: usize,
        ready_workers: usize,
    },
    /// Frontend → node: request a live stats snapshot.
    StatsReq { seq: u64 },
    /// Node → frontend: the snapshot (absolute values).
    Stats { seq: u64, stats: ServerStats },
    /// Node → frontend, reactor mode: stats pushed on the control
    /// connection. Additive counters carry the increment since the
    /// previous push on this connection (the first push since connect
    /// is the full cumulative value); gauges and the rung/worker
    /// breakdowns carry current absolute values. Summing deltas per
    /// connection epoch reconstructs the node's cumulative counters —
    /// including the conservation identity.
    StatsDelta { stats: ServerStats },
}

impl Msg {
    /// The message's type tag (log lines naming skipped messages).
    pub fn kind(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::HelloAck { .. } => "hello_ack",
            Msg::Submit { .. } => "submit",
            Msg::Response { .. } => "response",
            Msg::ErrorResp { .. } => "error",
            Msg::Reject { .. } => "reject",
            Msg::Ping { .. } => "ping",
            Msg::Pong { .. } => "pong",
            Msg::StatsReq { .. } => "stats_req",
            Msg::Stats { .. } => "stats",
            Msg::StatsDelta { .. } => "stats_delta",
        }
    }

    /// Canonical JSON bytes (the baseline wire frame payload).
    pub fn encode(&self) -> Vec<u8> {
        self.to_json().dump().into_bytes()
    }

    /// Encode at a negotiated feature level: responses go binary at
    /// [`WIRE_BINARY`] and above, everything else (and every message
    /// at the baseline) stays canonical JSON.
    pub fn encode_at(&self, wire: u16) -> Vec<u8> {
        match self {
            Msg::Response { id, latency_s, images, spans }
                if wire >= WIRE_BINARY && spans.is_empty() =>
            {
                encode_response_binary(*id, *latency_s, images)
            }
            _ => self.encode(),
        }
    }

    /// Decode a frame payload (either encoding — the marker byte
    /// disambiguates); every malformed input is a typed error.
    pub fn decode(bytes: &[u8]) -> Result<Msg> {
        if bytes.first() == Some(&BIN_MARKER) {
            return decode_binary(bytes);
        }
        let text = std::str::from_utf8(bytes)
            .context("message payload is not UTF-8")?;
        let j = Json::parse(text).context("message payload is not JSON")?;
        Msg::from_json(&j)
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        match self {
            Msg::Hello { role, max_wire } => {
                m.insert("type".into(), Json::Str("hello".into()));
                m.insert("role".into(), Json::Str(role.name().into()));
                // baseline hellos omit the field: byte-identical to
                // the v2 hello, so old peers see exactly what their
                // own frontends send
                if *max_wire > WIRE_VERSION {
                    m.insert("max_wire".into(),
                             Json::Num(*max_wire as f64));
                }
            }
            Msg::HelloAck { wire } => {
                m.insert("type".into(), Json::Str("hello_ack".into()));
                m.insert("wire".into(), Json::Num(*wire as f64));
            }
            Msg::Submit { id, class, n, trace } => {
                m.insert("type".into(), Json::Str("submit".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("class".into(), Json::Num(*class as f64));
                m.insert("n".into(), Json::Num(*n as f64));
                // untraced submits stay byte-identical to the old wire
                if trace.is_active() {
                    m.insert("tr".into(),
                             Json::Str(format!("{:016x}", trace.trace)));
                    m.insert("sp".into(),
                             Json::Str(format!("{:016x}", trace.span)));
                }
            }
            Msg::Response { id, latency_s, images, spans } => {
                m.insert("type".into(), Json::Str("response".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("latency_s".into(), Json::Num(*latency_s));
                m.insert(
                    "images".into(),
                    Json::Arr(images
                        .iter()
                        .map(|&p| Json::Num(p as f64))
                        .collect()),
                );
                if !spans.is_empty() {
                    m.insert(
                        "spans".into(),
                        Json::Arr(spans
                            .iter()
                            .map(SpanRec::to_json)
                            .collect()),
                    );
                }
            }
            Msg::ErrorResp { id, err } => {
                m.insert("type".into(), Json::Str("error".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("err".into(), serve_error_to_json(err));
            }
            Msg::Reject { err } => {
                m.insert("type".into(), Json::Str("reject".into()));
                m.insert("err".into(), serve_error_to_json(err));
            }
            Msg::Ping { seq } => {
                m.insert("type".into(), Json::Str("ping".into()));
                m.insert("seq".into(), Json::Num(*seq as f64));
            }
            Msg::Pong { seq, queue_depth, live_workers, ready_workers } => {
                m.insert("type".into(), Json::Str("pong".into()));
                m.insert("seq".into(), Json::Num(*seq as f64));
                m.insert("queue_depth".into(),
                         Json::Num(*queue_depth as f64));
                m.insert("live_workers".into(),
                         Json::Num(*live_workers as f64));
                m.insert("ready_workers".into(),
                         Json::Num(*ready_workers as f64));
            }
            Msg::StatsReq { seq } => {
                m.insert("type".into(), Json::Str("stats_req".into()));
                m.insert("seq".into(), Json::Num(*seq as f64));
            }
            Msg::Stats { seq, stats } => {
                m.insert("type".into(), Json::Str("stats".into()));
                m.insert("seq".into(), Json::Num(*seq as f64));
                m.insert("stats".into(), stats_to_json(stats));
            }
            Msg::StatsDelta { stats } => {
                m.insert("type".into(),
                         Json::Str("stats_delta".into()));
                m.insert("stats".into(), stats_to_json(stats));
            }
        }
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Msg> {
        let ty = str_field(j, "type")?;
        match ty {
            "hello" => {
                let role = str_field(j, "role")?;
                // absent max_wire = the v2 baseline (old peers)
                let max_wire = match j.get("max_wire") {
                    None => WIRE_VERSION,
                    Some(_) => count_field(j, "max_wire")?
                        .try_into()
                        .context("hello `max_wire` out of u16 range")?,
                };
                Ok(Msg::Hello {
                    role: Role::parse(role).with_context(|| {
                        format!("unknown connection role `{role}`")
                    })?,
                    max_wire,
                })
            }
            "hello_ack" => Ok(Msg::HelloAck {
                wire: count_field(j, "wire")?
                    .try_into()
                    .context("hello_ack `wire` out of u16 range")?,
            }),
            "submit" => Ok(Msg::Submit {
                id: count_field(j, "id")?,
                class: int_field(j, "class")?
                    .try_into()
                    .context("submit `class` out of i32 range")?,
                n: count_field(j, "n")? as usize,
                // optional, tolerant: a malformed context degrades to
                // untraced rather than failing the request
                trace: trace_ctx_from_json(j),
            }),
            "response" => {
                let arr = j
                    .get("images")
                    .and_then(Json::as_arr)
                    .context("response missing `images` array")?;
                let mut images = Vec::with_capacity(arr.len());
                for (i, p) in arr.iter().enumerate() {
                    let x = p.as_f64().with_context(|| {
                        format!("response pixel {i} is not a finite \
                                 number")
                    })?;
                    if !x.is_finite() {
                        bail!("response pixel {i} is not finite");
                    }
                    images.push(x as f32);
                }
                // optional span list; entries this build can't parse
                // are skipped (forward-compatible), never fatal
                let spans = j
                    .get("spans")
                    .and_then(Json::as_arr)
                    .map(|arr| {
                        arr.iter()
                            .filter_map(SpanRec::from_json)
                            .collect()
                    })
                    .unwrap_or_default();
                Ok(Msg::Response {
                    id: count_field(j, "id")?,
                    latency_s: num_field(j, "latency_s")?,
                    images,
                    spans,
                })
            }
            "error" => Ok(Msg::ErrorResp {
                id: count_field(j, "id")?,
                err: serve_error_from_json(
                    j.get("err").context("error message missing `err`")?,
                )?,
            }),
            "reject" => Ok(Msg::Reject {
                err: serve_error_from_json(
                    j.get("err")
                        .context("reject message missing `err`")?,
                )?,
            }),
            "ping" => Ok(Msg::Ping { seq: count_field(j, "seq")? }),
            "pong" => Ok(Msg::Pong {
                seq: count_field(j, "seq")?,
                queue_depth: count_field(j, "queue_depth")? as usize,
                live_workers: count_field(j, "live_workers")? as usize,
                ready_workers: count_field(j, "ready_workers")? as usize,
            }),
            "stats_req" => {
                Ok(Msg::StatsReq { seq: count_field(j, "seq")? })
            }
            "stats" => Ok(Msg::Stats {
                seq: count_field(j, "seq")?,
                stats: stats_from_json(
                    j.get("stats")
                        .context("stats message missing `stats`")?,
                )?,
            }),
            "stats_delta" => Ok(Msg::StatsDelta {
                stats: stats_from_json(
                    j.get("stats")
                        .context("stats_delta message missing `stats`")?,
                )?,
            }),
            other => bail!("unknown message type `{other}`"),
        }
    }
}

// -- binary payload encoding (see module docs for the layout) ------------

/// Encode a `Response` as the raw binary payload: 22-byte header, then
/// the pixels as little-endian `f32` — bit-for-bit, no text roundtrip.
fn encode_response_binary(
    id: u64,
    latency_s: f64,
    images: &[f32],
) -> Vec<u8> {
    let mut out = Vec::with_capacity(BIN_RESP_HEADER + 4 * images.len());
    out.push(BIN_MARKER);
    out.push(BIN_RESPONSE);
    out.extend_from_slice(&id.to_be_bytes());
    out.extend_from_slice(&latency_s.to_be_bytes());
    out.extend_from_slice(&(images.len() as u32).to_be_bytes());
    for &p in images {
        out.extend_from_slice(&p.to_le_bytes());
    }
    out
}

/// Decode a binary payload (first byte already seen as [`BIN_MARKER`]).
/// Validates the kind byte, the exact length, and latency finiteness —
/// typed errors, never a panic on peer bytes.
fn decode_binary(bytes: &[u8]) -> Result<Msg> {
    if bytes.len() < BIN_RESP_HEADER {
        bail!(
            "binary payload truncated: {} bytes, header needs {}",
            bytes.len(),
            BIN_RESP_HEADER
        );
    }
    let kind = bytes.get(1).copied().unwrap_or(0);
    if kind != BIN_RESPONSE {
        bail!("unknown binary payload kind 0x{kind:02x}");
    }
    let id = wire::be_u64(bytes, 2);
    let latency_s = f64::from_bits(wire::be_u64(bytes, 10));
    if !latency_s.is_finite() {
        bail!("binary response `latency_s` is not finite");
    }
    let n = wire::be_u32(bytes, 18) as usize;
    let want = BIN_RESP_HEADER + 4 * n;
    if bytes.len() != want {
        bail!(
            "binary response length mismatch: {} bytes for {} pixels \
             (want {})",
            bytes.len(),
            n,
            want
        );
    }
    let images = bytes
        .get(BIN_RESP_HEADER..)
        .unwrap_or(&[])
        .chunks_exact(4)
        .map(wire::le_f32)
        .collect();
    Ok(Msg::Response { id, latency_s, images, spans: Vec::new() })
}

/// Optional trace context on a message (`tr`/`sp` hex id strings);
/// absent or malformed fields mean "untraced" — version skew and
/// garbage degrade service observability, never service itself.
fn trace_ctx_from_json(j: &Json) -> TraceCtx {
    let hex = |key: &str| -> Option<u64> {
        u64::from_str_radix(j.get(key)?.as_str()?, 16).ok()
    };
    match (hex("tr"), hex("sp")) {
        (Some(trace), Some(span)) if trace != 0 => {
            TraceCtx { trace, span }
        }
        _ => TraceCtx::NONE,
    }
}

// -- field accessors (typed errors naming the key) -----------------------

fn str_field<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing or non-string `{key}`"))
}

/// Finite float field.
fn num_field(j: &Json, key: &str) -> Result<f64> {
    let x = j
        .get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing or non-numeric `{key}`"))?;
    if !x.is_finite() {
        bail!("`{key}` is not finite");
    }
    Ok(x)
}

/// Whole non-negative count field (u64; rejects fractions, negatives,
/// and values f64 cannot represent exactly).
fn count_field(j: &Json, key: &str) -> Result<u64> {
    j.get(key)
        .and_then(Json::as_exact_usize)
        .map(|v| v as u64)
        .with_context(|| {
            format!("missing or non-count `{key}` (whole number >= 0)")
        })
}

/// Whole (possibly negative) integer field.
fn int_field(j: &Json, key: &str) -> Result<i64> {
    let x = j
        .get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing or non-numeric `{key}`"))?;
    if !x.is_finite() || x.fract() != 0.0 || x.abs() >= 9.007_199_254_740_992e15
    {
        bail!("`{key}` is not an exact integer");
    }
    Ok(x as i64)
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// -- ServeError serde ----------------------------------------------------

/// Serialize a [`ServeError`] for the wire.
pub fn serve_error_to_json(e: &ServeError) -> Json {
    match e {
        ServeError::ShuttingDown => {
            obj(vec![("kind", Json::Str("shutting_down".into()))])
        }
        ServeError::QueueFull { queued, cap } => obj(vec![
            ("kind", Json::Str("queue_full".into())),
            ("queued", Json::Num(*queued as f64)),
            ("cap", Json::Num(*cap as f64)),
        ]),
        ServeError::RequestTooLarge { n, cap } => obj(vec![
            ("kind", Json::Str("request_too_large".into())),
            ("n", Json::Num(*n as f64)),
            ("cap", Json::Num(*cap as f64)),
        ]),
        ServeError::WorkerInitFailed { worker, cause } => obj(vec![
            ("kind", Json::Str("worker_init_failed".into())),
            ("worker", Json::Num(*worker as f64)),
            ("cause", Json::Str(cause.clone())),
        ]),
        ServeError::WorkerFailed { worker, cause } => obj(vec![
            ("kind", Json::Str("worker_failed".into())),
            ("worker", Json::Num(*worker as f64)),
            ("cause", Json::Str(cause.clone())),
        ]),
        ServeError::AllWorkersDead { cause } => obj(vec![
            ("kind", Json::Str("all_workers_dead".into())),
            ("cause", Json::Str(cause.clone())),
        ]),
        ServeError::NodeLost { cause } => obj(vec![
            ("kind", Json::Str("node_lost".into())),
            ("cause", Json::Str(cause.clone())),
        ]),
        ServeError::Protocol { cause } => obj(vec![
            ("kind", Json::Str("protocol".into())),
            ("cause", Json::Str(cause.clone())),
        ]),
        ServeError::Deadline { after_ms } => obj(vec![
            ("kind", Json::Str("deadline".into())),
            ("after_ms", Json::Num(*after_ms as f64)),
        ]),
    }
}

/// Parse a wire [`ServeError`]; unknown kinds are a protocol error.
pub fn serve_error_from_json(j: &Json) -> Result<ServeError> {
    let kind = str_field(j, "kind")?;
    let cause = || {
        str_field(j, "cause").map(str::to_string)
    };
    Ok(match kind {
        "shutting_down" => ServeError::ShuttingDown,
        "queue_full" => ServeError::QueueFull {
            queued: count_field(j, "queued")? as usize,
            cap: count_field(j, "cap")? as usize,
        },
        "request_too_large" => ServeError::RequestTooLarge {
            n: count_field(j, "n")? as usize,
            cap: count_field(j, "cap")? as usize,
        },
        "worker_init_failed" => ServeError::WorkerInitFailed {
            worker: count_field(j, "worker")? as usize,
            cause: cause()?,
        },
        "worker_failed" => ServeError::WorkerFailed {
            worker: count_field(j, "worker")? as usize,
            cause: cause()?,
        },
        "all_workers_dead" => {
            ServeError::AllWorkersDead { cause: cause()? }
        }
        "node_lost" => ServeError::NodeLost { cause: cause()? },
        "protocol" => ServeError::Protocol { cause: cause()? },
        "deadline" => {
            ServeError::Deadline { after_ms: count_field(j, "after_ms")? }
        }
        other => bail!("unknown serve error kind `{other}`"),
    })
}

// -- ServerStats serde ---------------------------------------------------

fn rung_to_json(r: &RungStats) -> Json {
    obj(vec![
        ("rung", Json::Num(r.rung as f64)),
        ("batches", Json::Num(r.batches as f64)),
        ("images", Json::Num(r.images as f64)),
        ("padded_slots", Json::Num(r.padded_slots as f64)),
        ("busy_s", Json::Num(r.busy_s)),
    ])
}

fn rung_from_json(j: &Json) -> Result<RungStats> {
    Ok(RungStats {
        rung: count_field(j, "rung")? as usize,
        batches: count_field(j, "batches")?,
        images: count_field(j, "images")?,
        padded_slots: count_field(j, "padded_slots")?,
        busy_s: num_field(j, "busy_s")?,
    })
}

fn worker_to_json(w: &WorkerStats) -> Json {
    obj(vec![
        ("worker", Json::Num(w.worker as f64)),
        ("batches", Json::Num(w.batches as f64)),
        ("images", Json::Num(w.images as f64)),
        ("padded_slots", Json::Num(w.padded_slots as f64)),
        ("busy_s", Json::Num(w.busy_s)),
        ("reuse_hits", Json::Num(w.reuse_hits as f64)),
        ("steps_skipped", Json::Num(w.steps_skipped as f64)),
        ("uploads_saved", Json::Num(w.uploads_saved as f64)),
        ("rungs", Json::Arr(w.rungs.iter().map(rung_to_json).collect())),
        ("ready", Json::Bool(w.ready)),
        ("failed", Json::Bool(w.failed)),
    ])
}

fn worker_from_json(j: &Json) -> Result<WorkerStats> {
    let rungs = j
        .get("rungs")
        .and_then(Json::as_arr)
        .context("worker stats missing `rungs`")?
        .iter()
        .map(rung_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok(WorkerStats {
        worker: count_field(j, "worker")? as usize,
        batches: count_field(j, "batches")?,
        images: count_field(j, "images")?,
        padded_slots: count_field(j, "padded_slots")?,
        busy_s: num_field(j, "busy_s")?,
        reuse_hits: count_field(j, "reuse_hits")?,
        steps_skipped: count_field(j, "steps_skipped")?,
        uploads_saved: count_field(j, "uploads_saved")?,
        rungs,
        ready: j
            .get("ready")
            .and_then(Json::as_bool)
            .context("worker stats missing `ready`")?,
        failed: j
            .get("failed")
            .and_then(Json::as_bool)
            .context("worker stats missing `failed`")?,
    })
}

/// Serialize a full [`ServerStats`] (the `--stats-json` dump and the
/// remote `Stats` message both use this).
pub fn stats_to_json(s: &ServerStats) -> Json {
    obj(vec![
        ("requests", Json::Num(s.requests as f64)),
        ("images", Json::Num(s.images as f64)),
        ("batches", Json::Num(s.batches as f64)),
        ("batch_fill", Json::Num(s.batch_fill)),
        ("padded_slots", Json::Num(s.padded_slots as f64)),
        ("failed_requests", Json::Num(s.failed_requests as f64)),
        ("dropped_responses", Json::Num(s.dropped_responses as f64)),
        ("wall_s", Json::Num(s.wall_s)),
        ("queue_depth_avg", Json::Num(s.queue_depth_avg)),
        ("queue_depth_max", Json::Num(s.queue_depth_max as f64)),
        ("latency_p50_s", Json::Num(s.latency_p50_s)),
        ("latency_p95_s", Json::Num(s.latency_p95_s)),
        ("calib_cache_hits", Json::Num(s.calib_cache_hits as f64)),
        ("calib_cache_misses", Json::Num(s.calib_cache_misses as f64)),
        ("calib_cold_start_ms", Json::Num(s.calib_cold_start_ms)),
        ("enqueued", Json::Num(s.enqueued as f64)),
        ("dispatched", Json::Num(s.dispatched as f64)),
        ("purged", Json::Num(s.purged as f64)),
        ("pending", Json::Num(s.pending as f64)),
        ("requeued", Json::Num(s.requeued as f64)),
        ("nodes_lost", Json::Num(s.nodes_lost as f64)),
        ("nodes_readmitted", Json::Num(s.nodes_readmitted as f64)),
        ("reuse_hits", Json::Num(s.reuse_hits as f64)),
        ("steps_skipped", Json::Num(s.steps_skipped as f64)),
        ("uploads_saved", Json::Num(s.uploads_saved as f64)),
        ("rungs", Json::Arr(s.rungs.iter().map(rung_to_json).collect())),
        (
            "workers",
            Json::Arr(s.workers.iter().map(worker_to_json).collect()),
        ),
        // sparse histogram; old decoders ignore the unknown key
        ("latency", s.latency.to_json()),
    ])
}

/// Parse a [`ServerStats`]; validates every field with typed errors.
pub fn stats_from_json(j: &Json) -> Result<ServerStats> {
    let rungs = j
        .get("rungs")
        .and_then(Json::as_arr)
        .context("stats missing `rungs`")?
        .iter()
        .map(rung_from_json)
        .collect::<Result<Vec<_>>>()?;
    let workers = j
        .get("workers")
        .and_then(Json::as_arr)
        .context("stats missing `workers`")?
        .iter()
        .map(worker_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok(ServerStats {
        requests: count_field(j, "requests")?,
        images: count_field(j, "images")?,
        batches: count_field(j, "batches")?,
        batch_fill: num_field(j, "batch_fill")?,
        padded_slots: count_field(j, "padded_slots")?,
        failed_requests: count_field(j, "failed_requests")?,
        dropped_responses: count_field(j, "dropped_responses")?,
        wall_s: num_field(j, "wall_s")?,
        queue_depth_avg: num_field(j, "queue_depth_avg")?,
        queue_depth_max: count_field(j, "queue_depth_max")? as usize,
        latency_p50_s: num_field(j, "latency_p50_s")?,
        latency_p95_s: num_field(j, "latency_p95_s")?,
        calib_cache_hits: count_field(j, "calib_cache_hits")?,
        calib_cache_misses: count_field(j, "calib_cache_misses")?,
        calib_cold_start_ms: num_field(j, "calib_cold_start_ms")?,
        enqueued: count_field(j, "enqueued")?,
        dispatched: count_field(j, "dispatched")?,
        purged: count_field(j, "purged")?,
        pending: count_field(j, "pending")?,
        requeued: count_field(j, "requeued")?,
        nodes_lost: count_field(j, "nodes_lost")?,
        nodes_readmitted: count_field(j, "nodes_readmitted")?,
        reuse_hits: count_field(j, "reuse_hits")?,
        steps_skipped: count_field(j, "steps_skipped")?,
        uploads_saved: count_field(j, "uploads_saved")?,
        rungs,
        workers,
        // absent on old wires → empty histogram (absorb then falls
        // back to the conservative max-of-percentiles bound)
        latency: j
            .get("latency")
            .map(LatencyHist::from_json)
            .unwrap_or_default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, Gen};

    fn roundtrip(msg: &Msg) -> Msg {
        Msg::decode(&msg.encode()).expect("decode what we encoded")
    }

    fn random_stats(g: &mut Gen) -> ServerStats {
        let mut s = ServerStats {
            requests: g.usize_in(0, 1000) as u64,
            images: g.usize_in(0, 10_000) as u64,
            batches: g.usize_in(0, 500) as u64,
            batch_fill: g.f32_in(0.0, 1.0) as f64,
            padded_slots: g.usize_in(0, 100) as u64,
            failed_requests: g.usize_in(0, 10) as u64,
            dropped_responses: g.usize_in(0, 10) as u64,
            wall_s: g.f32_in(0.0, 100.0) as f64,
            queue_depth_avg: g.f32_in(0.0, 50.0) as f64,
            queue_depth_max: g.usize_in(0, 200),
            latency_p50_s: g.f32_in(0.0, 2.0) as f64,
            latency_p95_s: g.f32_in(0.0, 5.0) as f64,
            calib_cache_hits: g.usize_in(0, 1) as u64,
            calib_cache_misses: g.usize_in(0, 1) as u64,
            calib_cold_start_ms: g.f32_in(0.0, 5e3) as f64,
            enqueued: g.usize_in(0, 10_000) as u64,
            dispatched: g.usize_in(0, 10_000) as u64,
            purged: g.usize_in(0, 100) as u64,
            pending: g.usize_in(0, 100) as u64,
            requeued: g.usize_in(0, 20) as u64,
            nodes_lost: g.usize_in(0, 3) as u64,
            nodes_readmitted: g.usize_in(0, 3) as u64,
            reuse_hits: g.usize_in(0, 500) as u64,
            steps_skipped: g.usize_in(0, 500) as u64,
            uploads_saved: g.usize_in(0, 2000) as u64,
            rungs: Vec::new(),
            workers: Vec::new(),
            latency: {
                let mut h = LatencyHist::new();
                for _ in 0..g.usize_in(0, 20) {
                    // strictly positive: 0.0 is legal to record but
                    // its min does not survive the sparse wire form
                    h.record(g.f32_in(1e-4, 5.0) as f64);
                }
                h
            },
        };
        for i in 0..g.usize_in(0, 4) {
            s.rungs.push(RungStats {
                rung: 1 << i,
                batches: g.usize_in(0, 50) as u64,
                images: g.usize_in(0, 500) as u64,
                padded_slots: g.usize_in(0, 50) as u64,
                busy_s: g.f32_in(0.0, 10.0) as f64,
            });
        }
        for w in 0..g.usize_in(0, 3) {
            s.workers.push(WorkerStats {
                worker: w,
                batches: g.usize_in(0, 50) as u64,
                images: g.usize_in(0, 500) as u64,
                padded_slots: g.usize_in(0, 50) as u64,
                busy_s: g.f32_in(0.0, 10.0) as f64,
                reuse_hits: g.usize_in(0, 200) as u64,
                steps_skipped: g.usize_in(0, 200) as u64,
                uploads_saved: g.usize_in(0, 800) as u64,
                rungs: vec![RungStats {
                    rung: 4,
                    batches: g.usize_in(0, 10) as u64,
                    images: g.usize_in(0, 40) as u64,
                    padded_slots: g.usize_in(0, 8) as u64,
                    busy_s: g.f32_in(0.0, 2.0) as f64,
                }],
                ready: g.bool(),
                failed: g.bool(),
            });
        }
        s
    }

    fn random_error(g: &mut Gen) -> ServeError {
        match g.usize_in(0, 8) {
            0 => ServeError::ShuttingDown,
            1 => ServeError::QueueFull {
                queued: g.usize_in(0, 999),
                cap: g.usize_in(1, 999),
            },
            2 => ServeError::RequestTooLarge {
                n: g.usize_in(1, 999),
                cap: g.usize_in(1, 999),
            },
            3 => ServeError::WorkerInitFailed {
                worker: g.usize_in(0, 7),
                cause: "artifacts \"missing\"\n(line 2)".into(),
            },
            4 => ServeError::WorkerFailed {
                worker: g.usize_in(0, 7),
                cause: "execute: OOM".into(),
            },
            5 => ServeError::AllWorkersDead { cause: "init".into() },
            6 => ServeError::NodeLost { cause: "timeout".into() },
            7 => ServeError::Deadline {
                after_ms: g.usize_in(1, 60_000) as u64,
            },
            8 => ServeError::Protocol { cause: "bad frame".into() },
            // usize_in(0, 8) is inclusive on both ends; a ninth value
            // can only mean a Gen bug, and a new variant added to the
            // roundtrip must get its own arm here
            out_of_range => {
                unreachable!("usize_in(0, 8) returned {out_of_range}")
            }
        }
    }

    #[test]
    fn prop_messages_roundtrip() {
        check("proto message roundtrip", 200, |g: &mut Gen| {
            let msg = match g.usize_in(0, 10) {
                6 => Msg::Hello {
                    role: if g.bool() { Role::Data } else { Role::Control },
                    max_wire: if g.bool() { WIRE_VERSION } else { WIRE_BINARY },
                },
                7 => Msg::HelloAck {
                    wire: if g.bool() { WIRE_VERSION } else { WIRE_BINARY },
                },
                8 => Msg::Reject { err: random_error(g) },
                9 => Msg::StatsDelta { stats: random_stats(g) },
                0 => Msg::Submit {
                    id: g.usize_in(0, 1 << 30) as u64,
                    class: g.usize_in(0, 2000) as i32 - 1000,
                    n: g.usize_in(0, 64),
                    trace: if g.bool() {
                        TraceCtx {
                            trace: g.usize_in(1, 1 << 30) as u64,
                            span: g.usize_in(0, 1 << 30) as u64,
                        }
                    } else {
                        TraceCtx::NONE
                    },
                },
                1 => {
                    let n = g.usize_in(0, 48);
                    Msg::Response {
                        id: g.usize_in(0, 1 << 30) as u64,
                        latency_s: g.f32_in(0.0, 10.0) as f64,
                        // f32 pixels must survive the wire bit-for-bit
                        images: g.vec_normal(n),
                        spans: (0..g.usize_in(0, 3))
                            .map(|i| SpanRec {
                                trace: g.usize_in(1, 1 << 30) as u64,
                                span: i as u64 + 1,
                                parent: g.usize_in(0, 9) as u64,
                                kind: crate::obs::trace::SpanKind::Queue,
                                start_ns: g.usize_in(0, 1 << 30) as u64,
                                dur_ns: g.usize_in(0, 1 << 20) as u64,
                                a: g.usize_in(0, 9) as u64,
                                b: g.usize_in(0, 9) as u64,
                            })
                            .collect(),
                    }
                }
                2 => Msg::ErrorResp {
                    id: g.usize_in(0, 1 << 30) as u64,
                    err: random_error(g),
                },
                3 => Msg::Ping { seq: g.usize_in(0, 1 << 20) as u64 },
                4 => Msg::Pong {
                    seq: g.usize_in(0, 1 << 20) as u64,
                    queue_depth: g.usize_in(0, 4096),
                    live_workers: g.usize_in(0, 16),
                    ready_workers: g.usize_in(0, 16),
                },
                5 => Msg::StatsReq { seq: g.usize_in(0, 99) as u64 },
                _ => Msg::Stats {
                    seq: g.usize_in(0, 99) as u64,
                    stats: random_stats(g),
                },
            };
            let back = Msg::decode(&msg.encode())
                .map_err(|e| format!("{e:#}"))?;
            if back != msg {
                return Err(format!(
                    "roundtrip mismatch:\n  sent {msg:?}\n  got  {back:?}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn pixels_survive_the_wire_bit_for_bit() {
        let images = vec![0.1f32, -17.125, f32::MIN_POSITIVE, 0.0, 255.0];
        let msg = Msg::Response {
            id: 7,
            latency_s: 0.25,
            images: images.clone(),
            spans: Vec::new(),
        };
        match roundtrip(&msg) {
            Msg::Response { images: back, .. } => {
                for (a, b) in images.iter().zip(&back) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn binary_response_roundtrips_bit_for_bit() {
        let images = vec![0.1f32, -17.125, f32::MIN_POSITIVE, 0.0, 255.0];
        let msg = Msg::Response {
            id: u64::MAX - 3,
            latency_s: 0.25,
            images: images.clone(),
            spans: Vec::new(),
        };
        let bytes = msg.encode_at(WIRE_BINARY);
        assert_eq!(bytes[0], 0x00, "binary marker");
        assert_eq!(bytes.len(), 22 + 4 * images.len());
        match Msg::decode(&bytes).unwrap() {
            Msg::Response { id, latency_s, images: back } => {
                assert_eq!(id, u64::MAX - 3);
                assert_eq!(latency_s, 0.25);
                for (a, b) in images.iter().zip(&back) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn encode_at_baseline_stays_json() {
        let msg = Msg::Response {
            id: 1,
            latency_s: 0.1,
            images: vec![1.0],
            spans: Vec::new(),
        };
        let bytes = msg.encode_at(WIRE_VERSION);
        assert_eq!(bytes, msg.encode(), "baseline must emit JSON");
        assert_eq!(bytes[0], b'{');
        // control messages stay JSON even past the baseline
        let ping = Msg::Ping { seq: 9 };
        assert_eq!(ping.encode_at(WIRE_BINARY), ping.encode());
    }

    #[test]
    fn decode_rejects_malformed_binary_payloads() {
        let good = Msg::Response {
            id: 3,
            latency_s: 0.5,
            images: vec![1.0, 2.0],
            spans: Vec::new(),
        }
        .encode_at(WIRE_BINARY);
        // short header
        assert!(Msg::decode(&good[..10]).is_err());
        // unknown payload kind
        let mut bad = good.clone();
        bad[1] = b'Z';
        assert!(Msg::decode(&bad).is_err());
        // length disagrees with the pixel count
        let mut bad = good.clone();
        bad.push(0);
        assert!(Msg::decode(&bad).is_err());
        assert!(Msg::decode(&good[..good.len() - 1]).is_err());
        // non-finite latency
        let mut bad = good.clone();
        bad[10..18].copy_from_slice(&f64::NAN.to_be_bytes());
        assert!(Msg::decode(&bad).is_err());
        // the untouched original still parses
        assert!(Msg::decode(&good).is_ok());
    }

    #[test]
    fn baseline_hello_is_byte_identical_to_v2() {
        // a baseline hello must not grow new fields — old nodes parse
        // it with strict field checks
        let h = Msg::Hello { role: Role::Data, max_wire: WIRE_VERSION };
        assert_eq!(h.encode(), br#"{"role":"data","type":"hello"}"#);
        // and a v2 hello (no max_wire on the wire) decodes as baseline
        match Msg::decode(br#"{"role":"control","type":"hello"}"#).unwrap()
        {
            Msg::Hello { role: Role::Control, max_wire } => {
                assert_eq!(max_wire, WIRE_VERSION)
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn every_error_variant_roundtrips() {
        for err in [
            ServeError::ShuttingDown,
            ServeError::QueueFull { queued: 9, cap: 8 },
            ServeError::RequestTooLarge { n: 99, cap: 8 },
            ServeError::WorkerInitFailed { worker: 1, cause: "x".into() },
            ServeError::WorkerFailed { worker: 2, cause: "y".into() },
            ServeError::AllWorkersDead { cause: "z".into() },
            ServeError::NodeLost { cause: "gone".into() },
            ServeError::Protocol { cause: "junk".into() },
            ServeError::Deadline { after_ms: 1500 },
        ] {
            let back =
                serve_error_from_json(&serve_error_to_json(&err)).unwrap();
            assert_eq!(back, err);
        }
    }

    #[test]
    fn decode_rejects_malformed_inputs_typed() {
        // not UTF-8
        assert!(Msg::decode(&[0xff, 0xfe, 0x00]).is_err());
        // not JSON
        assert!(Msg::decode(b"{not json").is_err());
        // unknown type
        assert!(Msg::decode(br#"{"type":"warp","id":1}"#).is_err());
        // unknown connection role
        assert!(Msg::decode(br#"{"type":"hello","role":"warp"}"#).is_err());
        // hello without a role
        assert!(Msg::decode(br#"{"type":"hello"}"#).is_err());
        // missing field
        assert!(Msg::decode(br#"{"type":"submit","id":1,"n":2}"#).is_err());
        // fractional count
        assert!(
            Msg::decode(br#"{"type":"ping","seq":1.5}"#).is_err()
        );
        // negative count
        assert!(Msg::decode(
            br#"{"type":"submit","id":-1,"class":0,"n":1}"#
        )
        .is_err());
        // non-finite pixel (null after canonical dump)
        assert!(Msg::decode(
            br#"{"type":"response","id":1,"latency_s":0.1,"images":[1,null]}"#
        )
        .is_err());
        // unknown error kind
        assert!(serve_error_from_json(
            &Json::parse(r#"{"kind":"mystery","cause":"?"}"#).unwrap()
        )
        .is_err());
        // stats with a fractional counter
        let stats =
            ServerStats { requests: 3, ..ServerStats::default() };
        let text = stats_to_json(&stats)
            .dump()
            .replace("\"requests\":3", "\"requests\":3.5");
        assert!(stats_from_json(&Json::parse(&text).unwrap()).is_err());
    }

    #[test]
    fn submit_class_may_be_negative() {
        // padding uses class 0, but the protocol must not mangle
        // negative conditioning labels
        let msg = Msg::Submit {
            id: 1,
            class: -3,
            n: 2,
            trace: TraceCtx::NONE,
        };
        match roundtrip(&msg) {
            Msg::Submit { class: -3, .. } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn untraced_submit_is_byte_identical_to_old_wire() {
        // trace fields ride only on traced submits: a NONE context
        // must produce exactly the pre-WIRE_TRACE encoding so old
        // nodes with strict field expectations see nothing new
        let msg = Msg::Submit {
            id: 3,
            class: 7,
            n: 2,
            trace: TraceCtx::NONE,
        };
        assert_eq!(
            msg.encode(),
            br#"{"class":7,"id":3,"n":2,"type":"submit"}"#
        );
        // and an old-wire submit (no trace fields) decodes as untraced
        match Msg::decode(br#"{"class":7,"id":3,"n":2,"type":"submit"}"#)
            .unwrap()
        {
            Msg::Submit { trace, .. } => assert_eq!(trace, TraceCtx::NONE),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn traced_submit_carries_full_64_bit_ids() {
        let trace = TraceCtx { trace: u64::MAX - 5, span: 1 << 60 };
        let msg = Msg::Submit { id: 9, class: 0, n: 1, trace };
        match roundtrip(&msg) {
            Msg::Submit { trace: back, .. } => assert_eq!(back, trace),
            other => panic!("{other:?}"),
        }
        // a malformed context degrades to untraced, never an error
        let garbled =
            br#"{"class":0,"id":9,"n":1,"sp":"zz","tr":"3","type":"submit"}"#;
        match Msg::decode(garbled).unwrap() {
            Msg::Submit { trace, .. } => assert_eq!(trace, TraceCtx::NONE),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn traced_response_stays_json_even_on_a_binary_wire() {
        let spans = vec![SpanRec {
            trace: 5,
            span: 6,
            parent: 7,
            kind: crate::obs::trace::SpanKind::Generate,
            start_ns: 100,
            dur_ns: 50,
            a: 4,
            b: 2,
        }];
        let msg = Msg::Response {
            id: 1,
            latency_s: 0.1,
            images: vec![1.0, 2.0],
            spans: spans.clone(),
        };
        let bytes = msg.encode_at(WIRE_BINARY);
        assert_eq!(bytes[0], b'{', "span-carrying response must be JSON");
        match Msg::decode(&bytes).unwrap() {
            Msg::Response { spans: back, .. } => assert_eq!(back, spans),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats_histogram_survives_the_wire() {
        let mut s = ServerStats::default();
        for v in [0.01, 0.02, 0.02, 1.5] {
            s.latency.record(v);
        }
        s.latency_p50_s = s.latency.quantile(0.50);
        s.latency_p95_s = s.latency.quantile(0.95);
        let back = stats_from_json(&stats_to_json(&s)).unwrap();
        assert_eq!(back, s);
        // an old-wire stats payload (no `latency` key) parses to an
        // empty histogram rather than failing
        let mut m = match stats_to_json(&ServerStats::default()) {
            Json::Obj(m) => m,
            other => panic!("{other:?}"),
        };
        m.remove("latency");
        let old = stats_from_json(&Json::Obj(m)).unwrap();
        assert_eq!(old.latency.count(), 0);
    }
}
