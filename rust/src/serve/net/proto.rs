//! Cross-node protocol messages, serialized as canonical JSON inside
//! [`wire`](crate::serve::net::wire) frames.
//!
//! One [`Msg`] enum covers both directions of a shard connection:
//!
//! * frontend → node: `Hello` (optional first message tagging the
//!   connection's [`Role`] — `control` connections carry only
//!   ping/pong/stats so liveness never queues behind response bytes;
//!   an untagged connection is `data`, the pre-handshake behavior),
//!   `Submit` (one generation request, carrying the *frontend's*
//!   request id — the node echoes it back, so each connection is its
//!   own id namespace), `Ping`, `StatsReq`;
//! * node → frontend: `Response` / `ErrorResp` (terminal, exactly one
//!   per submitted id), `Pong` (queue depth + worker counts, the
//!   load-balancing signal), `Stats` (a live [`ServerStats`]
//!   snapshot).
//!
//! Serde follows the `coordinator/store.rs` conventions: the canonical
//! serializer in [`crate::util::json`] (sorted keys, shortest-roundtrip
//! floats, so every `f32` image pixel survives the wire bit-for-bit),
//! and decoding validates everything — counts must be whole
//! non-negative numbers, floats finite, kinds known — returning typed
//! errors, never panicking on peer bytes.

use anyhow::{bail, Context, Result};

use crate::serve::error::ServeError;
use crate::serve::router::{RungStats, ServerStats, WorkerStats};
use crate::util::json::Json;

/// What a shard connection is for. The frontend opens one `Data`
/// connection (submits out, responses back) and — unless the control
/// plane is disabled — one `Control` connection (ping/pong/stats
/// only), so a pong can never queue behind a multi-MiB response frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Data,
    Control,
}

impl Role {
    pub fn name(&self) -> &'static str {
        match self {
            Role::Data => "data",
            Role::Control => "control",
        }
    }

    pub fn parse(s: &str) -> Option<Role> {
        match s {
            "data" => Some(Role::Data),
            "control" => Some(Role::Control),
            _ => None,
        }
    }
}

/// One frame's payload, either direction of a shard connection.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Frontend → node, first message on a connection: what this
    /// connection carries. Nodes treat a connection without a hello as
    /// `data` (raw clients, pre-handshake frontends).
    Hello { role: Role },
    /// Frontend → node: run `n` images of `class`; the node answers
    /// with a `Response`/`ErrorResp` echoing `id`.
    Submit { id: u64, class: i32, n: usize },
    /// Node → frontend: the completed request (flat pixels, node-side
    /// queue+compute latency).
    Response { id: u64, latency_s: f64, images: Vec<f32> },
    /// Node → frontend: the request failed with a typed error.
    ErrorResp { id: u64, err: ServeError },
    /// Frontend → node heartbeat probe.
    Ping { seq: u64 },
    /// Node → frontend heartbeat reply: the dispatch signal (queued
    /// slots) plus worker liveness.
    Pong {
        seq: u64,
        queue_depth: usize,
        live_workers: usize,
        ready_workers: usize,
    },
    /// Frontend → node: request a live stats snapshot.
    StatsReq { seq: u64 },
    /// Node → frontend: the snapshot.
    Stats { seq: u64, stats: ServerStats },
}

impl Msg {
    /// The message's type tag (log lines naming skipped messages).
    pub fn kind(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::Submit { .. } => "submit",
            Msg::Response { .. } => "response",
            Msg::ErrorResp { .. } => "error",
            Msg::Ping { .. } => "ping",
            Msg::Pong { .. } => "pong",
            Msg::StatsReq { .. } => "stats_req",
            Msg::Stats { .. } => "stats",
        }
    }

    /// Canonical JSON bytes (the wire frame payload).
    pub fn encode(&self) -> Vec<u8> {
        self.to_json().dump().into_bytes()
    }

    /// Decode a frame payload; every malformed input is a typed error.
    pub fn decode(bytes: &[u8]) -> Result<Msg> {
        let text = std::str::from_utf8(bytes)
            .context("message payload is not UTF-8")?;
        let j = Json::parse(text).context("message payload is not JSON")?;
        Msg::from_json(&j)
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        match self {
            Msg::Hello { role } => {
                m.insert("type".into(), Json::Str("hello".into()));
                m.insert("role".into(), Json::Str(role.name().into()));
            }
            Msg::Submit { id, class, n } => {
                m.insert("type".into(), Json::Str("submit".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("class".into(), Json::Num(*class as f64));
                m.insert("n".into(), Json::Num(*n as f64));
            }
            Msg::Response { id, latency_s, images } => {
                m.insert("type".into(), Json::Str("response".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("latency_s".into(), Json::Num(*latency_s));
                m.insert(
                    "images".into(),
                    Json::Arr(images
                        .iter()
                        .map(|&p| Json::Num(p as f64))
                        .collect()),
                );
            }
            Msg::ErrorResp { id, err } => {
                m.insert("type".into(), Json::Str("error".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("err".into(), serve_error_to_json(err));
            }
            Msg::Ping { seq } => {
                m.insert("type".into(), Json::Str("ping".into()));
                m.insert("seq".into(), Json::Num(*seq as f64));
            }
            Msg::Pong { seq, queue_depth, live_workers, ready_workers } => {
                m.insert("type".into(), Json::Str("pong".into()));
                m.insert("seq".into(), Json::Num(*seq as f64));
                m.insert("queue_depth".into(),
                         Json::Num(*queue_depth as f64));
                m.insert("live_workers".into(),
                         Json::Num(*live_workers as f64));
                m.insert("ready_workers".into(),
                         Json::Num(*ready_workers as f64));
            }
            Msg::StatsReq { seq } => {
                m.insert("type".into(), Json::Str("stats_req".into()));
                m.insert("seq".into(), Json::Num(*seq as f64));
            }
            Msg::Stats { seq, stats } => {
                m.insert("type".into(), Json::Str("stats".into()));
                m.insert("seq".into(), Json::Num(*seq as f64));
                m.insert("stats".into(), stats_to_json(stats));
            }
        }
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Msg> {
        let ty = str_field(j, "type")?;
        match ty {
            "hello" => {
                let role = str_field(j, "role")?;
                Ok(Msg::Hello {
                    role: Role::parse(role).with_context(|| {
                        format!("unknown connection role `{role}`")
                    })?,
                })
            }
            "submit" => Ok(Msg::Submit {
                id: count_field(j, "id")?,
                class: int_field(j, "class")?
                    .try_into()
                    .context("submit `class` out of i32 range")?,
                n: count_field(j, "n")? as usize,
            }),
            "response" => {
                let arr = j
                    .get("images")
                    .and_then(Json::as_arr)
                    .context("response missing `images` array")?;
                let mut images = Vec::with_capacity(arr.len());
                for (i, p) in arr.iter().enumerate() {
                    let x = p.as_f64().with_context(|| {
                        format!("response pixel {i} is not a finite \
                                 number")
                    })?;
                    if !x.is_finite() {
                        bail!("response pixel {i} is not finite");
                    }
                    images.push(x as f32);
                }
                Ok(Msg::Response {
                    id: count_field(j, "id")?,
                    latency_s: num_field(j, "latency_s")?,
                    images,
                })
            }
            "error" => Ok(Msg::ErrorResp {
                id: count_field(j, "id")?,
                err: serve_error_from_json(
                    j.get("err").context("error message missing `err`")?,
                )?,
            }),
            "ping" => Ok(Msg::Ping { seq: count_field(j, "seq")? }),
            "pong" => Ok(Msg::Pong {
                seq: count_field(j, "seq")?,
                queue_depth: count_field(j, "queue_depth")? as usize,
                live_workers: count_field(j, "live_workers")? as usize,
                ready_workers: count_field(j, "ready_workers")? as usize,
            }),
            "stats_req" => {
                Ok(Msg::StatsReq { seq: count_field(j, "seq")? })
            }
            "stats" => Ok(Msg::Stats {
                seq: count_field(j, "seq")?,
                stats: stats_from_json(
                    j.get("stats")
                        .context("stats message missing `stats`")?,
                )?,
            }),
            other => bail!("unknown message type `{other}`"),
        }
    }
}

// -- field accessors (typed errors naming the key) -----------------------

fn str_field<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing or non-string `{key}`"))
}

/// Finite float field.
fn num_field(j: &Json, key: &str) -> Result<f64> {
    let x = j
        .get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing or non-numeric `{key}`"))?;
    if !x.is_finite() {
        bail!("`{key}` is not finite");
    }
    Ok(x)
}

/// Whole non-negative count field (u64; rejects fractions, negatives,
/// and values f64 cannot represent exactly).
fn count_field(j: &Json, key: &str) -> Result<u64> {
    j.get(key)
        .and_then(Json::as_exact_usize)
        .map(|v| v as u64)
        .with_context(|| {
            format!("missing or non-count `{key}` (whole number >= 0)")
        })
}

/// Whole (possibly negative) integer field.
fn int_field(j: &Json, key: &str) -> Result<i64> {
    let x = j
        .get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing or non-numeric `{key}`"))?;
    if !x.is_finite() || x.fract() != 0.0 || x.abs() >= 9.007_199_254_740_992e15
    {
        bail!("`{key}` is not an exact integer");
    }
    Ok(x as i64)
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// -- ServeError serde ----------------------------------------------------

/// Serialize a [`ServeError`] for the wire.
pub fn serve_error_to_json(e: &ServeError) -> Json {
    match e {
        ServeError::ShuttingDown => {
            obj(vec![("kind", Json::Str("shutting_down".into()))])
        }
        ServeError::QueueFull { queued, cap } => obj(vec![
            ("kind", Json::Str("queue_full".into())),
            ("queued", Json::Num(*queued as f64)),
            ("cap", Json::Num(*cap as f64)),
        ]),
        ServeError::RequestTooLarge { n, cap } => obj(vec![
            ("kind", Json::Str("request_too_large".into())),
            ("n", Json::Num(*n as f64)),
            ("cap", Json::Num(*cap as f64)),
        ]),
        ServeError::WorkerInitFailed { worker, cause } => obj(vec![
            ("kind", Json::Str("worker_init_failed".into())),
            ("worker", Json::Num(*worker as f64)),
            ("cause", Json::Str(cause.clone())),
        ]),
        ServeError::WorkerFailed { worker, cause } => obj(vec![
            ("kind", Json::Str("worker_failed".into())),
            ("worker", Json::Num(*worker as f64)),
            ("cause", Json::Str(cause.clone())),
        ]),
        ServeError::AllWorkersDead { cause } => obj(vec![
            ("kind", Json::Str("all_workers_dead".into())),
            ("cause", Json::Str(cause.clone())),
        ]),
        ServeError::NodeLost { cause } => obj(vec![
            ("kind", Json::Str("node_lost".into())),
            ("cause", Json::Str(cause.clone())),
        ]),
        ServeError::Protocol { cause } => obj(vec![
            ("kind", Json::Str("protocol".into())),
            ("cause", Json::Str(cause.clone())),
        ]),
    }
}

/// Parse a wire [`ServeError`]; unknown kinds are a protocol error.
pub fn serve_error_from_json(j: &Json) -> Result<ServeError> {
    let kind = str_field(j, "kind")?;
    let cause = || {
        str_field(j, "cause").map(str::to_string)
    };
    Ok(match kind {
        "shutting_down" => ServeError::ShuttingDown,
        "queue_full" => ServeError::QueueFull {
            queued: count_field(j, "queued")? as usize,
            cap: count_field(j, "cap")? as usize,
        },
        "request_too_large" => ServeError::RequestTooLarge {
            n: count_field(j, "n")? as usize,
            cap: count_field(j, "cap")? as usize,
        },
        "worker_init_failed" => ServeError::WorkerInitFailed {
            worker: count_field(j, "worker")? as usize,
            cause: cause()?,
        },
        "worker_failed" => ServeError::WorkerFailed {
            worker: count_field(j, "worker")? as usize,
            cause: cause()?,
        },
        "all_workers_dead" => {
            ServeError::AllWorkersDead { cause: cause()? }
        }
        "node_lost" => ServeError::NodeLost { cause: cause()? },
        "protocol" => ServeError::Protocol { cause: cause()? },
        other => bail!("unknown serve error kind `{other}`"),
    })
}

// -- ServerStats serde ---------------------------------------------------

fn rung_to_json(r: &RungStats) -> Json {
    obj(vec![
        ("rung", Json::Num(r.rung as f64)),
        ("batches", Json::Num(r.batches as f64)),
        ("images", Json::Num(r.images as f64)),
        ("padded_slots", Json::Num(r.padded_slots as f64)),
        ("busy_s", Json::Num(r.busy_s)),
    ])
}

fn rung_from_json(j: &Json) -> Result<RungStats> {
    Ok(RungStats {
        rung: count_field(j, "rung")? as usize,
        batches: count_field(j, "batches")?,
        images: count_field(j, "images")?,
        padded_slots: count_field(j, "padded_slots")?,
        busy_s: num_field(j, "busy_s")?,
    })
}

fn worker_to_json(w: &WorkerStats) -> Json {
    obj(vec![
        ("worker", Json::Num(w.worker as f64)),
        ("batches", Json::Num(w.batches as f64)),
        ("images", Json::Num(w.images as f64)),
        ("padded_slots", Json::Num(w.padded_slots as f64)),
        ("busy_s", Json::Num(w.busy_s)),
        ("rungs", Json::Arr(w.rungs.iter().map(rung_to_json).collect())),
        ("ready", Json::Bool(w.ready)),
        ("failed", Json::Bool(w.failed)),
    ])
}

fn worker_from_json(j: &Json) -> Result<WorkerStats> {
    let rungs = j
        .get("rungs")
        .and_then(Json::as_arr)
        .context("worker stats missing `rungs`")?
        .iter()
        .map(rung_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok(WorkerStats {
        worker: count_field(j, "worker")? as usize,
        batches: count_field(j, "batches")?,
        images: count_field(j, "images")?,
        padded_slots: count_field(j, "padded_slots")?,
        busy_s: num_field(j, "busy_s")?,
        rungs,
        ready: j
            .get("ready")
            .and_then(Json::as_bool)
            .context("worker stats missing `ready`")?,
        failed: j
            .get("failed")
            .and_then(Json::as_bool)
            .context("worker stats missing `failed`")?,
    })
}

/// Serialize a full [`ServerStats`] (the `--stats-json` dump and the
/// remote `Stats` message both use this).
pub fn stats_to_json(s: &ServerStats) -> Json {
    obj(vec![
        ("requests", Json::Num(s.requests as f64)),
        ("images", Json::Num(s.images as f64)),
        ("batches", Json::Num(s.batches as f64)),
        ("batch_fill", Json::Num(s.batch_fill)),
        ("padded_slots", Json::Num(s.padded_slots as f64)),
        ("failed_requests", Json::Num(s.failed_requests as f64)),
        ("dropped_responses", Json::Num(s.dropped_responses as f64)),
        ("wall_s", Json::Num(s.wall_s)),
        ("queue_depth_avg", Json::Num(s.queue_depth_avg)),
        ("queue_depth_max", Json::Num(s.queue_depth_max as f64)),
        ("latency_p50_s", Json::Num(s.latency_p50_s)),
        ("latency_p95_s", Json::Num(s.latency_p95_s)),
        ("calib_cache_hits", Json::Num(s.calib_cache_hits as f64)),
        ("calib_cache_misses", Json::Num(s.calib_cache_misses as f64)),
        ("calib_cold_start_ms", Json::Num(s.calib_cold_start_ms)),
        ("enqueued", Json::Num(s.enqueued as f64)),
        ("dispatched", Json::Num(s.dispatched as f64)),
        ("purged", Json::Num(s.purged as f64)),
        ("pending", Json::Num(s.pending as f64)),
        ("requeued", Json::Num(s.requeued as f64)),
        ("nodes_lost", Json::Num(s.nodes_lost as f64)),
        ("nodes_readmitted", Json::Num(s.nodes_readmitted as f64)),
        ("rungs", Json::Arr(s.rungs.iter().map(rung_to_json).collect())),
        (
            "workers",
            Json::Arr(s.workers.iter().map(worker_to_json).collect()),
        ),
    ])
}

/// Parse a [`ServerStats`]; validates every field with typed errors.
pub fn stats_from_json(j: &Json) -> Result<ServerStats> {
    let rungs = j
        .get("rungs")
        .and_then(Json::as_arr)
        .context("stats missing `rungs`")?
        .iter()
        .map(rung_from_json)
        .collect::<Result<Vec<_>>>()?;
    let workers = j
        .get("workers")
        .and_then(Json::as_arr)
        .context("stats missing `workers`")?
        .iter()
        .map(worker_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok(ServerStats {
        requests: count_field(j, "requests")?,
        images: count_field(j, "images")?,
        batches: count_field(j, "batches")?,
        batch_fill: num_field(j, "batch_fill")?,
        padded_slots: count_field(j, "padded_slots")?,
        failed_requests: count_field(j, "failed_requests")?,
        dropped_responses: count_field(j, "dropped_responses")?,
        wall_s: num_field(j, "wall_s")?,
        queue_depth_avg: num_field(j, "queue_depth_avg")?,
        queue_depth_max: count_field(j, "queue_depth_max")? as usize,
        latency_p50_s: num_field(j, "latency_p50_s")?,
        latency_p95_s: num_field(j, "latency_p95_s")?,
        calib_cache_hits: count_field(j, "calib_cache_hits")?,
        calib_cache_misses: count_field(j, "calib_cache_misses")?,
        calib_cold_start_ms: num_field(j, "calib_cold_start_ms")?,
        enqueued: count_field(j, "enqueued")?,
        dispatched: count_field(j, "dispatched")?,
        purged: count_field(j, "purged")?,
        pending: count_field(j, "pending")?,
        requeued: count_field(j, "requeued")?,
        nodes_lost: count_field(j, "nodes_lost")?,
        nodes_readmitted: count_field(j, "nodes_readmitted")?,
        rungs,
        workers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, Gen};

    fn roundtrip(msg: &Msg) -> Msg {
        Msg::decode(&msg.encode()).expect("decode what we encoded")
    }

    fn random_stats(g: &mut Gen) -> ServerStats {
        let mut s = ServerStats {
            requests: g.usize_in(0, 1000) as u64,
            images: g.usize_in(0, 10_000) as u64,
            batches: g.usize_in(0, 500) as u64,
            batch_fill: g.f32_in(0.0, 1.0) as f64,
            padded_slots: g.usize_in(0, 100) as u64,
            failed_requests: g.usize_in(0, 10) as u64,
            dropped_responses: g.usize_in(0, 10) as u64,
            wall_s: g.f32_in(0.0, 100.0) as f64,
            queue_depth_avg: g.f32_in(0.0, 50.0) as f64,
            queue_depth_max: g.usize_in(0, 200),
            latency_p50_s: g.f32_in(0.0, 2.0) as f64,
            latency_p95_s: g.f32_in(0.0, 5.0) as f64,
            calib_cache_hits: g.usize_in(0, 1) as u64,
            calib_cache_misses: g.usize_in(0, 1) as u64,
            calib_cold_start_ms: g.f32_in(0.0, 5e3) as f64,
            enqueued: g.usize_in(0, 10_000) as u64,
            dispatched: g.usize_in(0, 10_000) as u64,
            purged: g.usize_in(0, 100) as u64,
            pending: g.usize_in(0, 100) as u64,
            requeued: g.usize_in(0, 20) as u64,
            nodes_lost: g.usize_in(0, 3) as u64,
            nodes_readmitted: g.usize_in(0, 3) as u64,
            rungs: Vec::new(),
            workers: Vec::new(),
        };
        for i in 0..g.usize_in(0, 4) {
            s.rungs.push(RungStats {
                rung: 1 << i,
                batches: g.usize_in(0, 50) as u64,
                images: g.usize_in(0, 500) as u64,
                padded_slots: g.usize_in(0, 50) as u64,
                busy_s: g.f32_in(0.0, 10.0) as f64,
            });
        }
        for w in 0..g.usize_in(0, 3) {
            s.workers.push(WorkerStats {
                worker: w,
                batches: g.usize_in(0, 50) as u64,
                images: g.usize_in(0, 500) as u64,
                padded_slots: g.usize_in(0, 50) as u64,
                busy_s: g.f32_in(0.0, 10.0) as f64,
                rungs: vec![RungStats {
                    rung: 4,
                    batches: g.usize_in(0, 10) as u64,
                    images: g.usize_in(0, 40) as u64,
                    padded_slots: g.usize_in(0, 8) as u64,
                    busy_s: g.f32_in(0.0, 2.0) as f64,
                }],
                ready: g.bool(),
                failed: g.bool(),
            });
        }
        s
    }

    fn random_error(g: &mut Gen) -> ServeError {
        match g.usize_in(0, 7) {
            0 => ServeError::ShuttingDown,
            1 => ServeError::QueueFull {
                queued: g.usize_in(0, 999),
                cap: g.usize_in(1, 999),
            },
            2 => ServeError::RequestTooLarge {
                n: g.usize_in(1, 999),
                cap: g.usize_in(1, 999),
            },
            3 => ServeError::WorkerInitFailed {
                worker: g.usize_in(0, 7),
                cause: "artifacts \"missing\"\n(line 2)".into(),
            },
            4 => ServeError::WorkerFailed {
                worker: g.usize_in(0, 7),
                cause: "execute: OOM".into(),
            },
            5 => ServeError::AllWorkersDead { cause: "init".into() },
            6 => ServeError::NodeLost { cause: "timeout".into() },
            _ => ServeError::Protocol { cause: "bad frame".into() },
        }
    }

    #[test]
    fn prop_messages_roundtrip() {
        check("proto message roundtrip", 200, |g: &mut Gen| {
            let msg = match g.usize_in(0, 7) {
                6 => Msg::Hello {
                    role: if g.bool() { Role::Data } else { Role::Control },
                },
                0 => Msg::Submit {
                    id: g.usize_in(0, 1 << 30) as u64,
                    class: g.usize_in(0, 2000) as i32 - 1000,
                    n: g.usize_in(0, 64),
                },
                1 => {
                    let n = g.usize_in(0, 48);
                    Msg::Response {
                        id: g.usize_in(0, 1 << 30) as u64,
                        latency_s: g.f32_in(0.0, 10.0) as f64,
                        // f32 pixels must survive the wire bit-for-bit
                        images: g.vec_normal(n),
                    }
                }
                2 => Msg::ErrorResp {
                    id: g.usize_in(0, 1 << 30) as u64,
                    err: random_error(g),
                },
                3 => Msg::Ping { seq: g.usize_in(0, 1 << 20) as u64 },
                4 => Msg::Pong {
                    seq: g.usize_in(0, 1 << 20) as u64,
                    queue_depth: g.usize_in(0, 4096),
                    live_workers: g.usize_in(0, 16),
                    ready_workers: g.usize_in(0, 16),
                },
                5 => Msg::StatsReq { seq: g.usize_in(0, 99) as u64 },
                _ => Msg::Stats {
                    seq: g.usize_in(0, 99) as u64,
                    stats: random_stats(g),
                },
            };
            let back = Msg::decode(&msg.encode())
                .map_err(|e| format!("{e:#}"))?;
            if back != msg {
                return Err(format!(
                    "roundtrip mismatch:\n  sent {msg:?}\n  got  {back:?}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn pixels_survive_the_wire_bit_for_bit() {
        let images = vec![0.1f32, -17.125, f32::MIN_POSITIVE, 0.0, 255.0];
        let msg = Msg::Response { id: 7, latency_s: 0.25, images: images.clone() };
        match roundtrip(&msg) {
            Msg::Response { images: back, .. } => {
                for (a, b) in images.iter().zip(&back) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn every_error_variant_roundtrips() {
        for err in [
            ServeError::ShuttingDown,
            ServeError::QueueFull { queued: 9, cap: 8 },
            ServeError::RequestTooLarge { n: 99, cap: 8 },
            ServeError::WorkerInitFailed { worker: 1, cause: "x".into() },
            ServeError::WorkerFailed { worker: 2, cause: "y".into() },
            ServeError::AllWorkersDead { cause: "z".into() },
            ServeError::NodeLost { cause: "gone".into() },
            ServeError::Protocol { cause: "junk".into() },
        ] {
            let back =
                serve_error_from_json(&serve_error_to_json(&err)).unwrap();
            assert_eq!(back, err);
        }
    }

    #[test]
    fn decode_rejects_malformed_inputs_typed() {
        // not UTF-8
        assert!(Msg::decode(&[0xff, 0xfe, 0x00]).is_err());
        // not JSON
        assert!(Msg::decode(b"{not json").is_err());
        // unknown type
        assert!(Msg::decode(br#"{"type":"warp","id":1}"#).is_err());
        // unknown connection role
        assert!(Msg::decode(br#"{"type":"hello","role":"warp"}"#).is_err());
        // hello without a role
        assert!(Msg::decode(br#"{"type":"hello"}"#).is_err());
        // missing field
        assert!(Msg::decode(br#"{"type":"submit","id":1,"n":2}"#).is_err());
        // fractional count
        assert!(
            Msg::decode(br#"{"type":"ping","seq":1.5}"#).is_err()
        );
        // negative count
        assert!(Msg::decode(
            br#"{"type":"submit","id":-1,"class":0,"n":1}"#
        )
        .is_err());
        // non-finite pixel (null after canonical dump)
        assert!(Msg::decode(
            br#"{"type":"response","id":1,"latency_s":0.1,"images":[1,null]}"#
        )
        .is_err());
        // unknown error kind
        assert!(serve_error_from_json(
            &Json::parse(r#"{"kind":"mystery","cause":"?"}"#).unwrap()
        )
        .is_err());
        // stats with a fractional counter
        let stats =
            ServerStats { requests: 3, ..ServerStats::default() };
        let text = stats_to_json(&stats)
            .dump()
            .replace("\"requests\":3", "\"requests\":3.5");
        assert!(stats_from_json(&Json::parse(&text).unwrap()).is_err());
    }

    #[test]
    fn submit_class_may_be_negative() {
        // padding uses class 0, but the protocol must not mangle
        // negative conditioning labels
        match roundtrip(&Msg::Submit { id: 1, class: -3, n: 2 }) {
            Msg::Submit { class: -3, .. } => {}
            other => panic!("{other:?}"),
        }
    }
}
