//! Shard health tracking + load-aware placement.
//!
//! [`Health`] is the cluster dispatcher's pure bookkeeping core: which
//! shards are alive, how recently each answered a heartbeat, and how
//! loaded each claims to be. Everything is a function of explicit
//! `Instant`s passed in by the caller — no clocks, no sockets, no
//! locks — in the same spirit as [`crate::serve::policy`], so every
//! liveness/placement property is unit-tested deterministically. The
//! [`Cluster`](crate::serve::net::cluster::Cluster) holds a `Health`
//! under its state mutex and feeds it pongs, errors and `now`.
//!
//! Liveness rule: a shard starts alive with a full grace window (its
//! connect instant counts as a heartbeat); it dies when the caller
//! reports a connection error ([`Health::mark_dead`]) or when its last
//! heartbeat is older than the policy timeout ([`Health::expired`]).
//! Death is permanent — re-admitting flapping nodes is a deliberate
//! non-goal (restart the frontend to re-pick up a recovered shard).
//!
//! Placement rule ([`Health::pick`]): the alive shard minimizing
//! *reported queue depth* (its last pong) *plus local in-flight*
//! (slots this frontend sent it that have not come back — covers the
//! window before the next pong reflects them), ties to the lowest
//! index.

use std::time::{Duration, Instant};

/// Heartbeat cadence + liveness deadline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HealthPolicy {
    /// How often the monitor pings each live shard.
    pub heartbeat: Duration,
    /// A shard whose last heartbeat (or connect) is older than this is
    /// declared dead.
    pub timeout: Duration,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            heartbeat: Duration::from_millis(500),
            timeout: Duration::from_millis(2500),
        }
    }
}

/// Last known state of one shard.
#[derive(Clone, Copy, Debug)]
pub struct ShardHealth {
    pub alive: bool,
    /// Last pong (or the connect instant before the first pong).
    pub last_seen: Instant,
    /// Queue depth the shard reported in its last pong.
    pub queue_depth: usize,
    pub live_workers: usize,
    pub ready_workers: usize,
}

/// Liveness + load book for a fixed shard set.
#[derive(Clone, Debug)]
pub struct Health {
    policy: HealthPolicy,
    shards: Vec<ShardHealth>,
}

impl Health {
    /// All `n` shards start alive with `now` as their grace heartbeat.
    pub fn new(n: usize, policy: HealthPolicy, now: Instant) -> Health {
        Health {
            policy,
            shards: (0..n)
                .map(|_| ShardHealth {
                    alive: true,
                    last_seen: now,
                    queue_depth: 0,
                    live_workers: 0,
                    ready_workers: 0,
                })
                .collect(),
        }
    }

    pub fn policy(&self) -> HealthPolicy {
        self.policy
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn shard(&self, i: usize) -> &ShardHealth {
        &self.shards[i]
    }

    pub fn is_alive(&self, i: usize) -> bool {
        self.shards[i].alive
    }

    pub fn alive_count(&self) -> usize {
        self.shards.iter().filter(|s| s.alive).count()
    }

    /// Indices of shards currently alive (heartbeat targets).
    pub fn alive_indices(&self) -> Vec<usize> {
        (0..self.shards.len()).filter(|&i| self.shards[i].alive).collect()
    }

    /// Record a heartbeat reply. A pong from a shard already declared
    /// dead is ignored (death is permanent; see module docs).
    pub fn pong(&mut self, i: usize, queue_depth: usize,
                live_workers: usize, ready_workers: usize, now: Instant) {
        let s = &mut self.shards[i];
        if !s.alive {
            return;
        }
        s.last_seen = now;
        s.queue_depth = queue_depth;
        s.live_workers = live_workers;
        s.ready_workers = ready_workers;
    }

    /// Declare a shard dead (connection error, heartbeat expiry).
    /// Returns false when it already was — callers use this to make
    /// the lost-node cleanup run exactly once per shard.
    pub fn mark_dead(&mut self, i: usize) -> bool {
        let s = &mut self.shards[i];
        let was_alive = s.alive;
        s.alive = false;
        was_alive
    }

    /// Alive shards whose last heartbeat is older than the timeout as
    /// of `now` (the caller then runs its lost-node path on each).
    pub fn expired(&self, now: Instant) -> Vec<usize> {
        (0..self.shards.len())
            .filter(|&i| {
                let s = &self.shards[i];
                s.alive
                    && now.saturating_duration_since(s.last_seen)
                        > self.policy.timeout
            })
            .collect()
    }

    /// Least-loaded alive shard: minimal reported depth + local
    /// in-flight estimate (`extra[i]`), ties to the lowest index.
    /// `None` when every shard is dead.
    pub fn pick(&self, extra: &[usize]) -> Option<usize> {
        debug_assert_eq!(extra.len(), self.shards.len());
        (0..self.shards.len())
            .filter(|&i| self.shards[i].alive)
            .min_by_key(|&i| self.shards[i].queue_depth + extra[i])
    }

    /// Sum of the last-reported live worker counts over alive shards.
    pub fn live_workers_total(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.alive)
            .map(|s| s.live_workers)
            .sum()
    }

    /// Sum of the last-reported ready worker counts over alive shards.
    pub fn ready_workers_total(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.alive)
            .map(|s| s.ready_workers)
            .sum()
    }

    /// Sum of the last-reported queue depths over alive shards.
    pub fn depth_total(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.alive)
            .map(|s| s.queue_depth)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy_ms(hb: u64, to: u64) -> HealthPolicy {
        HealthPolicy {
            heartbeat: Duration::from_millis(hb),
            timeout: Duration::from_millis(to),
        }
    }

    #[test]
    fn starts_alive_with_grace_window() {
        let t0 = Instant::now();
        let h = Health::new(3, policy_ms(10, 50), t0);
        assert_eq!(h.alive_count(), 3);
        // inside the grace window nothing expires…
        assert!(h.expired(t0 + Duration::from_millis(50)).is_empty());
        // …one tick past it, everything silent does
        assert_eq!(h.expired(t0 + Duration::from_millis(51)), vec![0, 1, 2]);
    }

    #[test]
    fn pong_refreshes_only_its_shard() {
        let t0 = Instant::now();
        let mut h = Health::new(2, policy_ms(10, 50), t0);
        h.pong(1, 7, 2, 2, t0 + Duration::from_millis(40));
        assert_eq!(h.expired(t0 + Duration::from_millis(60)), vec![0]);
        assert_eq!(h.shard(1).queue_depth, 7);
        assert_eq!(h.live_workers_total(), 2);
    }

    #[test]
    fn mark_dead_is_idempotent_and_permanent() {
        let t0 = Instant::now();
        let mut h = Health::new(2, policy_ms(10, 50), t0);
        assert!(h.mark_dead(0), "first death reported once");
        assert!(!h.mark_dead(0), "second report is a no-op");
        assert_eq!(h.alive_count(), 1);
        // a late pong from the dead shard must not resurrect it
        h.pong(0, 0, 4, 4, t0 + Duration::from_millis(1));
        assert!(!h.is_alive(0));
        assert_eq!(h.alive_indices(), vec![1]);
        // dead shards never show up as expired again
        assert!(h.expired(t0 + Duration::from_secs(9)) == vec![1]);
    }

    #[test]
    fn pick_minimizes_reported_plus_inflight() {
        let t0 = Instant::now();
        let mut h = Health::new(3, policy_ms(10, 50), t0);
        h.pong(0, 5, 1, 1, t0);
        h.pong(1, 2, 1, 1, t0);
        h.pong(2, 2, 1, 1, t0);
        // reported depth ties between 1 and 2 → lowest index
        assert_eq!(h.pick(&[0, 0, 0]), Some(1));
        // local in-flight breaks the tie the other way
        assert_eq!(h.pick(&[0, 4, 0]), Some(2));
        // and can overcome a lower reported depth
        assert_eq!(h.pick(&[0, 4, 9]), Some(0));
    }

    #[test]
    fn pick_skips_dead_shards_and_empty_cluster_is_none() {
        let t0 = Instant::now();
        let mut h = Health::new(2, policy_ms(10, 50), t0);
        h.pong(0, 0, 1, 1, t0);
        h.pong(1, 99, 1, 1, t0);
        h.mark_dead(0);
        assert_eq!(h.pick(&[0, 0]), Some(1));
        h.mark_dead(1);
        assert_eq!(h.pick(&[0, 0]), None);
        assert_eq!(h.live_workers_total(), 0);
        assert_eq!(h.depth_total(), 0);
    }
}
