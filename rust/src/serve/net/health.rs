//! Shard health tracking + load-aware placement.
//!
//! [`Health`] is the cluster dispatcher's pure bookkeeping core: which
//! shards are serving, how recently each answered a heartbeat, and how
//! loaded each claims to be. Everything is a function of explicit
//! `Instant`s passed in by the caller — no clocks, no sockets, no
//! locks — in the same spirit as [`crate::serve::policy`], so every
//! liveness/placement property is unit-tested deterministically. The
//! [`Cluster`](crate::serve::net::cluster::Cluster) holds a `Health`
//! under its state mutex and feeds it pongs, errors and `now`.
//!
//! # State machine
//!
//! Node death is *recoverable*: each shard walks
//!
//! ```text
//!          pong                    reconnect
//!   ┌──────────────┐         ┌──────────────────┐
//!   ▼              │         ▼                  │
//! Alive ──────▶ Suspect ──▶ Dead ◀────────── Probation
//!   ▲    silent        timeout │    conn error /    │
//!   │    > timeout/2   or conn │    silent > timeout│
//!   │                  error   └────────────────────┤
//!   └───────────────────────────────────────────────┘
//!                 K consecutive pongs (readmit_pongs)
//! ```
//!
//! * **Alive** — serving; placed by [`Health::pick`].
//! * **Suspect** — missed heartbeats for more than half the timeout:
//!   still serving (a busy node is not a dead node), but only placed
//!   when no Alive shard exists; one pong restores Alive.
//! * **Dead** — timed out or its connection errored. The cluster
//!   re-homes its in-flight work once ([`Health::mark_dead`] reports
//!   the previous state so the cleanup runs exactly once per death)
//!   and its reconnect loop starts probing the address.
//! * **Probation** — reconnected, not yet trusted: pinged but never
//!   placed. After [`HealthPolicy::readmit_pongs`] *consecutive* pongs
//!   on the (control) connection it is re-admitted to Alive with a
//!   ramp-up handicap — [`RAMP_START`] halvings that decay one per
//!   pong — so a flapping node re-enters placement gradually instead
//!   of oscillating the scheduler.

use std::time::{Duration, Instant};

/// Placement handicap a re-admitted shard starts with: its effective
/// load is left-shifted by the remaining ramp (×16 at re-admission
/// with the default of 4), decaying one halving per pong — roughly one
/// heartbeat-interval per step — until it competes at face value.
pub const RAMP_START: u32 = 4;

/// Heartbeat cadence + liveness deadlines + re-admission policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HealthPolicy {
    /// How often the monitor pings each non-dead shard.
    pub heartbeat: Duration,
    /// A shard whose last heartbeat (or connect) is older than this is
    /// declared dead; older than *half* of it, suspect.
    pub timeout: Duration,
    /// Consecutive pongs a reconnected (probation) shard must answer
    /// before it is re-admitted into placement.
    pub readmit_pongs: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            heartbeat: Duration::from_millis(500),
            timeout: Duration::from_millis(2500),
            readmit_pongs: 3,
        }
    }
}

impl HealthPolicy {
    /// Silence threshold for Alive → Suspect (half the death timeout).
    pub fn suspect_after(&self) -> Duration {
        self.timeout / 2
    }
}

/// One shard's position in the liveness state machine (module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardState {
    Alive,
    Suspect,
    Dead,
    Probation,
}

/// Last known state of one shard.
#[derive(Clone, Copy, Debug)]
pub struct ShardHealth {
    pub state: ShardState,
    /// Last pong (or the connect instant before the first pong).
    pub last_seen: Instant,
    /// Queue depth the shard reported in its last pong.
    pub queue_depth: usize,
    pub live_workers: usize,
    pub ready_workers: usize,
    /// Consecutive pongs answered while in probation.
    pub probation_pongs: u32,
    /// Remaining ramp-up handicap (halvings of placement appeal).
    pub ramp: u32,
}

impl ShardHealth {
    /// Serving = currently trusted with requests (Alive or Suspect).
    pub fn serving(&self) -> bool {
        matches!(self.state, ShardState::Alive | ShardState::Suspect)
    }
}

/// Liveness + load book for a fixed shard set.
#[derive(Clone, Debug)]
pub struct Health {
    policy: HealthPolicy,
    shards: Vec<ShardHealth>,
}

impl Health {
    /// All `n` shards start alive with `now` as their grace heartbeat.
    pub fn new(n: usize, policy: HealthPolicy, now: Instant) -> Health {
        Health {
            policy,
            shards: (0..n)
                .map(|_| ShardHealth {
                    state: ShardState::Alive,
                    last_seen: now,
                    queue_depth: 0,
                    live_workers: 0,
                    ready_workers: 0,
                    probation_pongs: 0,
                    ramp: 0,
                })
                .collect(),
        }
    }

    pub fn policy(&self) -> HealthPolicy {
        self.policy
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn shard(&self, i: usize) -> &ShardHealth {
        &self.shards[i]
    }

    pub fn state(&self, i: usize) -> ShardState {
        self.shards[i].state
    }

    /// Shards currently trusted with requests (Alive or Suspect).
    pub fn serving_count(&self) -> usize {
        self.shards.iter().filter(|s| s.serving()).count()
    }

    /// Indices of serving shards (final-stats sweep targets).
    pub fn serving_indices(&self) -> Vec<usize> {
        (0..self.shards.len())
            .filter(|&i| self.shards[i].serving())
            .collect()
    }

    /// Indices the heartbeat monitor pings: everything with a live
    /// connection — serving shards *and* probation shards (whose pongs
    /// are their path back in).
    pub fn ping_targets(&self) -> Vec<usize> {
        (0..self.shards.len())
            .filter(|&i| self.shards[i].state != ShardState::Dead)
            .collect()
    }

    /// Indices the reconnect loop should probe.
    pub fn dead_indices(&self) -> Vec<usize> {
        (0..self.shards.len())
            .filter(|&i| self.shards[i].state == ShardState::Dead)
            .collect()
    }

    /// Record a heartbeat reply; returns `true` when this pong
    /// *re-admitted* a probation shard (the caller logs/counts it). A
    /// pong from a Dead shard is ignored — with no connection it can
    /// only be a stale delivery racing the death.
    pub fn pong(&mut self, i: usize, queue_depth: usize,
                live_workers: usize, ready_workers: usize,
                now: Instant) -> bool {
        let policy = self.policy;
        let s = &mut self.shards[i];
        if s.state == ShardState::Dead {
            return false;
        }
        s.last_seen = now;
        s.queue_depth = queue_depth;
        s.live_workers = live_workers;
        s.ready_workers = ready_workers;
        match s.state {
            ShardState::Probation => {
                s.probation_pongs += 1;
                if s.probation_pongs >= policy.readmit_pongs {
                    s.state = ShardState::Alive;
                    s.probation_pongs = 0;
                    s.ramp = RAMP_START;
                    return true;
                }
            }
            ShardState::Suspect => {
                // recovered before the timeout: a busy node, not a
                // dead one
                s.state = ShardState::Alive;
                s.ramp = s.ramp.saturating_sub(1);
            }
            ShardState::Alive => {
                s.ramp = s.ramp.saturating_sub(1);
            }
            // early-returned at the top of this fn; nothing to do, and
            // nothing worth panicking over if that ever changes
            ShardState::Dead => {}
        }
        false
    }

    /// Declare a shard dead (connection error, heartbeat expiry).
    /// Returns the *previous* state — callers run the in-flight
    /// re-home cleanup only when it was serving (`Alive`/`Suspect`),
    /// and exactly once per death episode (`Dead` means a racing path
    /// already handled it).
    pub fn mark_dead(&mut self, i: usize) -> ShardState {
        let s = &mut self.shards[i];
        let prev = s.state;
        s.state = ShardState::Dead;
        s.probation_pongs = 0;
        s.ramp = 0;
        prev
    }

    /// A reconnect succeeded: Dead → Probation, with `now` starting
    /// the silence clock (a mute reconnected node expires again).
    /// No-op from any other state.
    pub fn begin_probation(&mut self, i: usize, now: Instant) {
        let s = &mut self.shards[i];
        if s.state != ShardState::Dead {
            return;
        }
        s.state = ShardState::Probation;
        s.last_seen = now;
        s.probation_pongs = 0;
        s.queue_depth = 0;
        s.live_workers = 0;
        s.ready_workers = 0;
    }

    /// Advance time-driven transitions: Alive shards silent for more
    /// than half the timeout become Suspect (deprioritized, still
    /// serving), and a Probation shard that skipped a heartbeat loses
    /// its pong streak — the re-admission gate is *consecutive* pongs,
    /// so a sick node answering every few pings cannot accumulate its
    /// way back into placement. The monitor calls this each beat
    /// before `expired`.
    pub fn tick(&mut self, now: Instant) {
        let suspect_after = self.policy.suspect_after();
        // one full beat of slack: at tick time the current beat's pong
        // is typically still in flight, so a healthy shard's silence
        // measures ~one heartbeat
        let streak_break = self.policy.heartbeat * 2;
        for s in &mut self.shards {
            let silent = now.saturating_duration_since(s.last_seen);
            match s.state {
                ShardState::Alive if silent > suspect_after => {
                    s.state = ShardState::Suspect;
                }
                ShardState::Probation if silent > streak_break => {
                    s.probation_pongs = 0;
                }
                // explicitly unchanged by the beat — a new state added
                // to the machine must decide its tick behavior here
                // rather than fall through a wildcard
                ShardState::Alive
                | ShardState::Suspect
                | ShardState::Dead
                | ShardState::Probation => {}
            }
        }
    }

    /// Non-dead shards whose last heartbeat is older than the timeout
    /// as of `now` (the caller then runs its lost-node path on each —
    /// for a mute Probation shard that just tears the connection down
    /// and goes back to reconnecting).
    pub fn expired(&self, now: Instant) -> Vec<usize> {
        (0..self.shards.len())
            .filter(|&i| {
                let s = &self.shards[i];
                s.state != ShardState::Dead
                    && now.saturating_duration_since(s.last_seen)
                        > self.policy.timeout
            })
            .collect()
    }

    /// Effective placement cost: reported depth + local in-flight,
    /// inflated by the remaining ramp-up handicap (each step doubles
    /// the apparent load of a freshly re-admitted shard).
    fn cost(&self, i: usize, extra: &[usize]) -> usize {
        let s = &self.shards[i];
        (s.queue_depth + extra[i] + 1) << s.ramp.min(16)
    }

    /// Least-loaded placeable shard: minimal effective cost among
    /// Alive shards, falling back to Suspect ones (busy beats dead)
    /// when no Alive shard exists; ties to the lowest index. `None`
    /// when nothing is serving.
    pub fn pick(&self, extra: &[usize]) -> Option<usize> {
        debug_assert_eq!(extra.len(), self.shards.len());
        let best = |target: ShardState| {
            (0..self.shards.len())
                .filter(|&i| self.shards[i].state == target)
                .min_by_key(|&i| self.cost(i, extra))
        };
        best(ShardState::Alive).or_else(|| best(ShardState::Suspect))
    }

    /// Sum of the last-reported live worker counts over serving shards.
    pub fn live_workers_total(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.serving())
            .map(|s| s.live_workers)
            .sum()
    }

    /// Sum of the last-reported ready worker counts over serving shards.
    pub fn ready_workers_total(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.serving())
            .map(|s| s.ready_workers)
            .sum()
    }

    /// Sum of the last-reported queue depths over serving shards.
    pub fn depth_total(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.serving())
            .map(|s| s.queue_depth)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy_ms(hb: u64, to: u64) -> HealthPolicy {
        HealthPolicy {
            heartbeat: Duration::from_millis(hb),
            timeout: Duration::from_millis(to),
            readmit_pongs: 2,
        }
    }

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn starts_alive_with_grace_window() {
        let t0 = Instant::now();
        let h = Health::new(3, policy_ms(10, 50), t0);
        assert_eq!(h.serving_count(), 3);
        // inside the grace window nothing expires…
        assert!(h.expired(t0 + ms(50)).is_empty());
        // …one tick past it, everything silent does
        assert_eq!(h.expired(t0 + ms(51)), vec![0, 1, 2]);
    }

    #[test]
    fn pong_refreshes_only_its_shard() {
        let t0 = Instant::now();
        let mut h = Health::new(2, policy_ms(10, 50), t0);
        h.pong(1, 7, 2, 2, t0 + ms(40));
        assert_eq!(h.expired(t0 + ms(60)), vec![0]);
        assert_eq!(h.shard(1).queue_depth, 7);
        assert_eq!(h.live_workers_total(), 2);
    }

    #[test]
    fn silence_past_half_timeout_is_suspect_not_dead() {
        let t0 = Instant::now();
        let mut h = Health::new(2, policy_ms(10, 100), t0);
        h.pong(1, 0, 1, 1, t0 + ms(60));
        h.tick(t0 + ms(60));
        // shard 0 silent 60 ms > 50 ms (timeout/2): suspect, still
        // serving, still counted — but not expired yet
        assert_eq!(h.state(0), ShardState::Suspect);
        assert_eq!(h.state(1), ShardState::Alive);
        assert_eq!(h.serving_count(), 2);
        assert!(h.expired(t0 + ms(60)).is_empty());
        // suspects lose placement to alive shards even when *less*
        // loaded…
        h.pong(1, 9, 1, 1, t0 + ms(60));
        assert_eq!(h.pick(&[0, 0]), Some(1));
        // …but carry the cluster alone when nothing is alive
        h.mark_dead(1);
        assert_eq!(h.pick(&[0, 0]), Some(0));
        // one pong fully restores the suspect
        h.pong(0, 0, 1, 1, t0 + ms(70));
        assert_eq!(h.state(0), ShardState::Alive);
    }

    #[test]
    fn mark_dead_reports_previous_state_once() {
        let t0 = Instant::now();
        let mut h = Health::new(2, policy_ms(10, 50), t0);
        assert_eq!(h.mark_dead(0), ShardState::Alive,
                   "first death reports the serving state");
        assert_eq!(h.mark_dead(0), ShardState::Dead,
                   "second report sees the death already handled");
        assert_eq!(h.serving_count(), 1);
        // a late pong from the dead shard must not resurrect it
        h.pong(0, 0, 4, 4, t0 + ms(1));
        assert_eq!(h.state(0), ShardState::Dead);
        assert_eq!(h.serving_indices(), vec![1]);
        assert_eq!(h.dead_indices(), vec![0]);
        // dead shards never show up as expired again
        assert_eq!(h.expired(t0 + Duration::from_secs(9)), vec![1]);
    }

    #[test]
    fn probation_readmits_after_k_consecutive_pongs() {
        let t0 = Instant::now();
        let mut h = Health::new(2, policy_ms(10, 50), t0);
        h.mark_dead(0);
        h.begin_probation(0, t0 + ms(5));
        assert_eq!(h.state(0), ShardState::Probation);
        // pinged but never placed
        assert!(h.ping_targets().contains(&0));
        assert_eq!(h.serving_count(), 1);
        assert_eq!(h.pick(&[0, 0]), Some(1));
        // K = 2 consecutive pongs re-admit (the first must not)
        assert!(!h.pong(0, 0, 1, 1, t0 + ms(10)));
        assert_eq!(h.state(0), ShardState::Probation);
        assert!(h.pong(0, 0, 1, 1, t0 + ms(20)),
                "second pong re-admits");
        assert_eq!(h.state(0), ShardState::Alive);
        assert_eq!(h.shard(0).ramp, RAMP_START);
        assert_eq!(h.serving_count(), 2);
    }

    #[test]
    fn probation_streak_is_consecutive_not_cumulative() {
        // readmit_pongs = 2, heartbeat 10 ms: a probation shard that
        // answers one ping, goes quiet for several beats, then answers
        // again must NOT be re-admitted on that second (non-
        // consecutive) pong
        let t0 = Instant::now();
        let mut h = Health::new(1, policy_ms(10, 100), t0);
        h.mark_dead(0);
        h.begin_probation(0, t0);
        assert!(!h.pong(0, 0, 1, 1, t0 + ms(10)));
        // three silent beats: the monitor's tick breaks the streak
        h.tick(t0 + ms(40));
        assert_eq!(h.shard(0).probation_pongs, 0);
        assert!(!h.pong(0, 0, 1, 1, t0 + ms(45)),
                "a pong after a gap restarts the streak");
        // two genuinely consecutive pongs do re-admit
        assert!(h.pong(0, 0, 1, 1, t0 + ms(55)));
        assert_eq!(h.state(0), ShardState::Alive);
    }

    #[test]
    fn probation_death_resets_the_pong_streak() {
        let t0 = Instant::now();
        let mut h = Health::new(1, policy_ms(10, 50), t0);
        h.mark_dead(0);
        h.begin_probation(0, t0);
        h.pong(0, 0, 1, 1, t0 + ms(5));
        // the connection drops again before the streak completes
        assert_eq!(h.mark_dead(0), ShardState::Probation);
        h.begin_probation(0, t0 + ms(30));
        // the streak starts over: one pong is not enough
        assert!(!h.pong(0, 0, 1, 1, t0 + ms(35)));
        assert_eq!(h.state(0), ShardState::Probation);
        // and a mute probation shard expires like anything else
        assert_eq!(h.expired(t0 + ms(90)), vec![0]);
    }

    #[test]
    fn readmitted_shard_ramps_up_instead_of_swamping() {
        let t0 = Instant::now();
        let mut h = Health::new(2, policy_ms(10, 50), t0);
        h.pong(1, 4, 1, 1, t0); // modest standing load on shard 1
        h.mark_dead(0);
        h.begin_probation(0, t0);
        h.pong(0, 0, 1, 1, t0 + ms(10));
        assert!(h.pong(0, 0, 1, 1, t0 + ms(20)));
        // freshly re-admitted: empty but handicapped ×2^RAMP_START, so
        // the loaded veteran still wins placement
        assert_eq!(h.pick(&[0, 0]), Some(1));
        // the handicap decays one halving per pong until the empty
        // shard wins on merit: (0+0+1)<<r < (4+0+1)<<0 needs r <= 2
        for k in 0..RAMP_START {
            h.pong(0, 0, 1, 1, t0 + ms(30 + 10 * k as u64));
            h.pong(1, 4, 1, 1, t0 + ms(30 + 10 * k as u64));
        }
        assert_eq!(h.shard(0).ramp, 0);
        assert_eq!(h.pick(&[0, 0]), Some(0));
    }

    #[test]
    fn pick_minimizes_reported_plus_inflight() {
        let t0 = Instant::now();
        let mut h = Health::new(3, policy_ms(10, 50), t0);
        h.pong(0, 5, 1, 1, t0);
        h.pong(1, 2, 1, 1, t0);
        h.pong(2, 2, 1, 1, t0);
        // reported depth ties between 1 and 2 → lowest index
        assert_eq!(h.pick(&[0, 0, 0]), Some(1));
        // local in-flight breaks the tie the other way
        assert_eq!(h.pick(&[0, 4, 0]), Some(2));
        // and can overcome a lower reported depth
        assert_eq!(h.pick(&[0, 4, 9]), Some(0));
    }

    #[test]
    fn pick_skips_dead_shards_and_empty_cluster_is_none() {
        let t0 = Instant::now();
        let mut h = Health::new(2, policy_ms(10, 50), t0);
        h.pong(0, 0, 1, 1, t0);
        h.pong(1, 99, 1, 1, t0);
        h.mark_dead(0);
        assert_eq!(h.pick(&[0, 0]), Some(1));
        h.mark_dead(1);
        assert_eq!(h.pick(&[0, 0]), None);
        assert_eq!(h.live_workers_total(), 0);
        assert_eq!(h.depth_total(), 0);
    }
}
