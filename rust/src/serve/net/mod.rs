//! Cross-node serving: the serve stack stretched over TCP.
//!
//! The PR 1–3 stack shards across *threads* in one process; this
//! subsystem shards across *processes and hosts* with nothing but
//! `std::net` and the existing thread pool — no async runtime:
//!
//! ```text
//! clients ──▶ Cluster (Dispatch)                      frontend process
//!               │  least-loaded placement (heartbeat depth + in-flight)
//!               │  re-queue on node loss, NodeLost only when none left
//!               ▼
//!           wire frames (length-prefixed, versioned, checksummed)
//!           proto messages (canonical JSON: submit/response/error/
//!                           ping/pong/stats)
//!               ▼
//!           NodeServer (TCP listener)                   shard process
//!               │  one handler thread per connection,
//!               │  forwarder pool for responses
//!               ▼
//!           Dispatch (GenServer → Router → Batcher → samplers)
//! ```
//!
//! Layering, bottom-up:
//!
//! * [`wire`] — the byte layer: framed, versioned, checksummed, every
//!   malformed input a typed [`wire::WireError`]. Knows nothing about
//!   messages.
//! * [`proto`] — the message layer: [`proto::Msg`] as canonical JSON
//!   inside frames, plus the [`ServerStats`](crate::serve::ServerStats)
//!   / [`ServeError`](crate::serve::ServeError) serde the stats
//!   protocol and `--stats-json` share. Knows nothing about sockets.
//! * [`health`] — pure liveness/placement bookkeeping (heartbeat
//!   expiry, least-loaded pick), unit-tested with explicit clocks.
//! * [`node`] — a [`Dispatch`](crate::serve::Dispatch) service behind
//!   a listener.
//! * [`cluster`] — the frontend: same `Dispatch` surface, requests
//!   spread over shard nodes, failover per [`health`].
//!
//! The loopback topology (nodes and cluster in one process over
//! `127.0.0.1`) is first-class: the cluster tests, the
//! `benches/runtime.rs` smoke section and `serve_demo --nodes N` all
//! run it, including mid-load node kills.

pub mod cluster;
pub mod health;
pub mod node;
pub mod proto;
pub mod wire;

#[cfg(test)]
pub(crate) mod testutil;

pub use cluster::{Cluster, ClusterOpts};
pub use health::{Health, HealthPolicy};
pub use node::{NodeOpts, NodeServer};
pub use proto::Msg;
pub use wire::WireError;
