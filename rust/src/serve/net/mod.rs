//! Cross-node serving: the serve stack stretched over TCP.
//!
//! The PR 1–3 stack shards across *threads* in one process; this
//! subsystem shards across *processes and hosts* with nothing but
//! `std::net` and the existing thread pool — no async runtime. Every
//! process hosts at most **one reactor thread** (`poll(2)` readiness
//! loop over non-blocking sockets) that owns all of that process's
//! connections; compute stays on the thread pool:
//!
//! ```text
//! clients ──▶ Cluster (Dispatch) ──── or ──▶ NetClient   frontend process
//!               │  least-loaded placement        │ one connection,
//!               │  re-queue on node loss         │ many in-flight ids,
//!               │  reconnector = blocking-dial   │ per-request deadline
//!               │  quarantine (Probation→Alive)  │ → ServeError::Deadline
//!               ▼                                ▼
//!           ┌─ reactor thread ─────────────────────────────────────┐
//!           │ poll(2) loop: conn state machines keyed by epoch,    │
//!           │ buffered writes w/ backpressure, timer wheel drives  │
//!           │ heartbeats + deadlines; ctrl-priority lane — a pong  │
//!           │ never queues behind a bulk response frame            │
//!           └──────────────────────────────────────────────────────┘
//!               │ data plane          │ control plane (Hello{role})
//!               │ submits out,        │ ping/pong + stats *deltas*
//!               │ responses back      │ pushed by the node; snapshot
//!               │ (binary tensors at  │ poll only as the threaded-
//!               │  wire ≥ 3, chunked  │ node fallback
//!               │  past CHUNK_LEN)    │
//!               ▼                     ▼
//!           wire frames (length-prefixed, versioned, checksummed)
//!           proto messages (canonical JSON control; negotiated
//!                           binary image payloads at wire ≥ 3)
//!               ▼
//!           NodeServer (TCP listener)                   shard process
//!               │  reactor accepts + frames all connections
//!               │  (or legacy one-thread-per-connection mode);
//!               │  thread pool runs compute, forwarders respond
//!               ▼
//!           Dispatch (GenServer → Router → Batcher → samplers)
//! ```
//!
//! Both transport modes speak the same wire protocol and are selected
//! per process ([`NodeOpts::reactor`], [`ClusterOpts::reactor`],
//! `--reactor` on the CLI — reactor is the CLI default, threaded is
//! the `--reactor false` fallback); a reactor cluster serves threaded nodes
//! and vice versa. [`reactor::ReactorOpts::max_conns`] (`--max-conns`)
//! caps accepted connections — the reactor holds thousands of idle
//! connections at O(workers) threads, where the legacy mode spends a
//! thread per connection.
//!
//! Layering, bottom-up:
//!
//! * [`wire`] — the byte layer: framed, versioned, checksummed, every
//!   malformed input a typed [`wire::WireError`]. Messages past
//!   [`wire::CHUNK_LEN`] travel as sequence-numbered chunk runs
//!   (standalone frames may interleave between chunks — the liveness
//!   escape hatch), reassembled by [`wire::MessageReader`] under the
//!   [`wire::MAX_FRAME_LEN`] cap. Knows nothing about messages.
//! * [`proto`] — the message layer: [`proto::Msg`] as canonical JSON
//!   inside frames — including the [`proto::Role`] handshake that tags
//!   control connections and negotiates the wire level (image tensors
//!   go binary at [`proto::WIRE_BINARY`], control stays JSON) — plus
//!   the [`ServerStats`](crate::serve::ServerStats) /
//!   [`ServeError`](crate::serve::ServeError) serde the stats protocol
//!   and `--stats-json` share. Knows nothing about sockets.
//! * [`reactor`] — the event loop: one thread, `poll(2)` over
//!   non-blocking sockets, per-connection read/write state machines,
//!   buffered writer with backpressure caps, a timer wheel, and a
//!   [`reactor::Handle`] any thread can send/register/close through.
//!   Knows nothing about the serve protocol — drivers implement
//!   [`reactor::Driver`].
//! * [`health`] — pure liveness/placement bookkeeping: the
//!   `Alive → Suspect → Dead → Probation → Alive` state machine,
//!   heartbeat expiry, K-pong re-admission, ramped least-loaded pick —
//!   unit-tested with explicit clocks.
//! * [`node`] — a [`Dispatch`](crate::serve::Dispatch) service behind
//!   a listener.
//! * [`cluster`] — the frontend: same `Dispatch` surface, requests
//!   spread over shard nodes, failover *and* recovery per [`health`].
//! * [`client`] — the thin per-node SDK: one reactor-backed
//!   connection, many in-flight requests, typed per-request deadlines.
//!
//! The loopback topology (nodes and cluster in one process over
//! `127.0.0.1`) is first-class: the cluster tests, the
//! `benches/runtime.rs` smoke section and `serve_demo --nodes N` all
//! run it, including mid-load node kills and kill-then-restart
//! re-admission.

pub mod client;
pub mod cluster;
pub mod health;
pub mod node;
pub mod proto;
pub mod reactor;
pub mod wire;

use std::net::TcpStream;
use std::sync::Mutex;

/// Write one message under the two-lock discipline every connection
/// writer in this layer shares — the one place the chunk-interleaving
/// protocol lives. Small messages take only the frame lock; a message
/// past [`wire::CHUNK_LEN`] additionally serializes on `bulk` (chunks
/// of two messages must never interleave) while *releasing* the frame
/// lock between chunks, so standalone frames — pongs, typed errors —
/// slip in between and liveness never waits behind more than one
/// chunk. A `None` stream slot means the connection is gone (typed
/// I/O error, the caller's lost-connection path takes over).
pub(crate) fn send_message(stream: &Mutex<Option<TcpStream>>,
                           bulk: &Mutex<()>, payload: &[u8])
                           -> Result<(), wire::WireError> {
    // frames are encoded one at a time from the plan, outside the
    // locks — a multi-MiB message is never buffered twice
    let plan = wire::chunk_plan(payload.len())?;
    let write_one = |range: std::ops::Range<usize>, ctrl: u16|
                     -> Result<(), wire::WireError> {
        let frame = wire::encode_frame_ctrl(&payload[range], ctrl)?;
        let mut g = crate::util::lock(stream);
        let Some(s) = g.as_mut() else {
            return Err(wire::WireError::Io(
                "connection already closed".into()));
        };
        // tq-lint: allow(lock-across-blocking): by design — one frame
        // is bounded by CHUNK_LEN and the chunk protocol releases the
        // frame lock between frames, so no writer waits behind more
        // than one bounded write (module doc above)
        wire::write_encoded(s, &frame)
    };
    // a single-frame message skips the bulk lock entirely: nothing to
    // interleave with
    let _bulk = if plan.len() > 1 {
        Some(crate::util::lock(bulk))
    } else {
        None
    };
    for (range, ctrl) in plan {
        write_one(range, ctrl)?;
    }
    Ok(())
}

#[cfg(test)]
pub(crate) mod testutil;

pub use client::{NetClient, NetClientOpts};
pub use cluster::{Cluster, ClusterOpts};
pub use health::{Health, HealthPolicy, ShardState};
pub use node::{NodeOpts, NodeServer};
pub use proto::{Msg, Role};
pub use reactor::{Reactor, ReactorOpts};
pub use wire::{MessageReader, WireError};
