//! Cross-node serving: the serve stack stretched over TCP.
//!
//! The PR 1–3 stack shards across *threads* in one process; this
//! subsystem shards across *processes and hosts* with nothing but
//! `std::net` and the existing thread pool — no async runtime:
//!
//! ```text
//! clients ──▶ Cluster (Dispatch)                      frontend process
//!               │  least-loaded placement (heartbeat depth + in-flight,
//!               │  ramp-up handicap on re-admitted shards)
//!               │  re-queue on node loss, NodeLost only when none left
//!               │  reconnector revives dead shards (Probation → Alive)
//!               ├────────────────┬─────────────────────────────────────
//!               ▼ data plane     ▼ control plane (Hello{role})
//!           submits out,     ping/pong/stats only — a pong never
//!           responses back   queues behind a response frame
//!           (chunked past CHUNK_LEN, per-chunk checksums)
//!               ▼                ▼
//!           wire frames (length-prefixed, versioned, checksummed)
//!           proto messages (canonical JSON: hello/submit/response/
//!                           error/ping/pong/stats)
//!               ▼
//!           NodeServer (TCP listener)                   shard process
//!               │  one handler thread per connection,
//!               │  forwarder pool for responses
//!               ▼
//!           Dispatch (GenServer → Router → Batcher → samplers)
//! ```
//!
//! Layering, bottom-up:
//!
//! * [`wire`] — the byte layer: framed, versioned, checksummed, every
//!   malformed input a typed [`wire::WireError`]. Messages past
//!   [`wire::CHUNK_LEN`] travel as sequence-numbered chunk runs
//!   (standalone frames may interleave between chunks — the liveness
//!   escape hatch), reassembled by [`wire::MessageReader`] under the
//!   [`wire::MAX_FRAME_LEN`] cap. Knows nothing about messages.
//! * [`proto`] — the message layer: [`proto::Msg`] as canonical JSON
//!   inside frames — including the [`proto::Role`] handshake that tags
//!   control connections — plus the
//!   [`ServerStats`](crate::serve::ServerStats) /
//!   [`ServeError`](crate::serve::ServeError) serde the stats protocol
//!   and `--stats-json` share. Knows nothing about sockets.
//! * [`health`] — pure liveness/placement bookkeeping: the
//!   `Alive → Suspect → Dead → Probation → Alive` state machine,
//!   heartbeat expiry, K-pong re-admission, ramped least-loaded pick —
//!   unit-tested with explicit clocks.
//! * [`node`] — a [`Dispatch`](crate::serve::Dispatch) service behind
//!   a listener.
//! * [`cluster`] — the frontend: same `Dispatch` surface, requests
//!   spread over shard nodes, failover *and* recovery per [`health`].
//!
//! The loopback topology (nodes and cluster in one process over
//! `127.0.0.1`) is first-class: the cluster tests, the
//! `benches/runtime.rs` smoke section and `serve_demo --nodes N` all
//! run it, including mid-load node kills and kill-then-restart
//! re-admission.

pub mod cluster;
pub mod health;
pub mod node;
pub mod proto;
pub mod wire;

use std::net::TcpStream;
use std::sync::Mutex;

/// Write one message under the two-lock discipline every connection
/// writer in this layer shares — the one place the chunk-interleaving
/// protocol lives. Small messages take only the frame lock; a message
/// past [`wire::CHUNK_LEN`] additionally serializes on `bulk` (chunks
/// of two messages must never interleave) while *releasing* the frame
/// lock between chunks, so standalone frames — pongs, typed errors —
/// slip in between and liveness never waits behind more than one
/// chunk. A `None` stream slot means the connection is gone (typed
/// I/O error, the caller's lost-connection path takes over).
pub(crate) fn send_message(stream: &Mutex<Option<TcpStream>>,
                           bulk: &Mutex<()>, payload: &[u8])
                           -> Result<(), wire::WireError> {
    // frames are encoded one at a time from the plan, outside the
    // locks — a multi-MiB message is never buffered twice
    let plan = wire::chunk_plan(payload.len())?;
    let write_one = |range: std::ops::Range<usize>, ctrl: u16|
                     -> Result<(), wire::WireError> {
        let frame = wire::encode_frame_ctrl(&payload[range], ctrl)?;
        let mut g = stream.lock().unwrap_or_else(|p| p.into_inner());
        let Some(s) = g.as_mut() else {
            return Err(wire::WireError::Io(
                "connection already closed".into()));
        };
        wire::write_encoded(s, &frame)
    };
    if plan.len() == 1 {
        let (range, ctrl) = plan.into_iter().next().expect("len 1");
        return write_one(range, ctrl);
    }
    let _bulk = bulk.lock().unwrap_or_else(|p| p.into_inner());
    for (range, ctrl) in plan {
        write_one(range, ctrl)?;
    }
    Ok(())
}

#[cfg(test)]
pub(crate) mod testutil;

pub use cluster::{Cluster, ClusterOpts};
pub use health::{Health, HealthPolicy, ShardState};
pub use node::{NodeOpts, NodeServer};
pub use proto::{Msg, Role};
pub use wire::{MessageReader, WireError};
