//! A hand-rolled `poll(2)` reactor: one thread drives every socket in
//! the serve boundary, so connection count stops costing OS threads.
//!
//! No async runtime — the loop is `poll(2)` over raw fds
//! (`std::os::fd`), non-blocking sockets, and per-connection state
//! machines:
//!
//! * **Reads** feed a [`FrameDecoder`](crate::serve::net::wire::
//!   FrameDecoder) (the incremental twin of `MessageReader`), so chunk
//!   reassembly and interleaved standalone frames behave exactly as on
//!   the blocking path.
//! * **Writes** go through a two-priority outbox per connection:
//!   small control frames (pongs, typed errors) drain before the next
//!   bulk chunk, reproducing the threaded layer's lock-interleave
//!   discipline — a heartbeat reply never waits behind more than one
//!   chunk. The outbox is byte-capped (backpressure): a peer that
//!   stops reading is disconnected instead of ballooning memory, and a
//!   connection whose writes make no progress for
//!   [`ReactorOpts::write_stall`] is closed like the threaded path's
//!   `SO_SNDTIMEO` would have done.
//! * **Timers** live on a hashed timer wheel ([`TimerWheel`]):
//!   heartbeat cadence, stats pushes, per-request deadlines, and the
//!   stall probe all fire from `poll`'s timeout, no sleeper threads.
//!
//! The owning layer implements [`Driver`] — called only on the reactor
//! thread, so it needs no locking of its own connection state — and
//! talks to the reactor from other threads through a cloneable
//! [`Handle`] (command queue + a `UnixStream` wake pipe). That is how
//! threadpool compute results re-enter the loop: a completion enqueues
//! `Cmd::Send` and writes one wake byte.
//!
//! POSIX-only by construction (`poll(2)`, `std::os::fd`); the serve
//! stack targets Linux hosts. The two `extern "C"` declarations bind
//! symbols std already links through libc.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::serve::net::wire::{
    chunk_plan, encode_frame, encode_frame_ctrl, FrameDecoder, WireError,
    CHUNK_LEN,
};
use crate::warn_log;

// ---------------------------------------------------------------------
// poll(2) + rlimit FFI (symbols std links via libc; no crate needed)

#[repr(C)]
struct PollFd {
    fd: RawFd,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

#[repr(C)]
struct RLimit {
    cur: u64,
    max: u64,
}

/// Linux resource id for the open-file-descriptor limit.
const RLIMIT_NOFILE: i32 = 7;

extern "C" {
    // Linux: nfds_t is unsigned long == pointer width.
    fn poll(fds: *mut PollFd, nfds: usize, timeout: i32) -> i32;
    fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
}

/// Raise `RLIMIT_NOFILE` toward `want` (capped at the hard limit) and
/// return the resulting soft limit. The C10k tests hold >2k sockets in
/// one process; default soft limits (often 1024) would fail `accept`
/// long before the reactor does.
pub fn raise_nofile_limit(want: u64) -> u64 {
    let mut lim = RLimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return 0;
    }
    if lim.cur < want {
        let new = RLimit { cur: want.min(lim.max), max: lim.max };
        if unsafe { setrlimit(RLIMIT_NOFILE, &new) } == 0 {
            lim.cur = new.cur;
        }
    }
    lim.cur
}

/// Live thread count of this process (`/proc/self/status`), the number
/// the C10k smoke asserts is O(workers) — `None` off Linux.
pub fn process_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

// ---------------------------------------------------------------------
// Timer wheel

/// Wheel granularity: deadlines are rounded *up* to a 4 ms tick, so a
/// timer never fires early and heartbeat-scale cadences (≥10 ms in the
/// tests, ≥100 ms in production) stay accurate to within one tick.
const TICK: Duration = Duration::from_millis(4);
/// Slot count; ticks hash onto slots modulo this, with the absolute
/// due tick stored per entry, so deadlines past one rotation
/// (512 × 4 ms ≈ 2 s) still fire correctly — they just wait in their
/// slot across rotations.
const WHEEL_SLOTS: usize = 512;

/// Hashed timer wheel over opaque `u64` keys. Scheduling is O(1);
/// expiry visits at most one full rotation of slots per call and
/// returns due keys in deadline order. Cancellation is deliberately
/// absent — drivers invalidate lazily (a fired key whose purpose has
/// passed is ignored), which keeps the wheel allocation-light.
pub(crate) struct TimerWheel {
    start: Instant,
    /// First tick not yet swept.
    cursor: u64,
    /// `(absolute due tick, key)` entries, hashed by due tick.
    slots: Vec<Vec<(u64, u64)>>,
    len: usize,
}

impl TimerWheel {
    pub fn new(now: Instant) -> TimerWheel {
        TimerWheel {
            start: now,
            cursor: 0,
            slots: vec![Vec::new(); WHEEL_SLOTS],
            len: 0,
        }
    }

    /// Tick a deadline rounds up to, clamped forward to the sweep
    /// cursor so past deadlines fire on the next [`expire`] call.
    fn tick_of(&self, at: Instant) -> u64 {
        let dt = at.saturating_duration_since(self.start).as_nanos();
        let g = TICK.as_nanos();
        let tick = ((dt + g - 1) / g) as u64;
        tick.max(self.cursor)
    }

    pub fn schedule(&mut self, at: Instant, key: u64) {
        let tick = self.tick_of(at);
        self.slots[(tick % WHEEL_SLOTS as u64) as usize]
            .push((tick, key));
        self.len += 1;
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Earliest pending deadline, if any (O(entries) scan — entry
    /// counts are O(connections with timers), small).
    pub fn next_deadline(&self) -> Option<Instant> {
        if self.len == 0 {
            return None;
        }
        let mut best: Option<u64> = None;
        for slot in &self.slots {
            for &(tick, _) in slot {
                best = Some(best.map_or(tick, |b| b.min(tick)));
            }
        }
        best.map(|t| {
            self.start
                + Duration::from_nanos(
                    (t as u128 * TICK.as_nanos()) as u64,
                )
        })
    }

    /// Pop every key due at or before `now`, in deadline order.
    pub fn expire(&mut self, now: Instant) -> Vec<u64> {
        let dt = now.saturating_duration_since(self.start).as_nanos();
        let now_tick = (dt / TICK.as_nanos()) as u64;
        if now_tick < self.cursor {
            return Vec::new();
        }
        let mut due = Vec::new();
        if self.len > 0 {
            // one full rotation covers every slot, however far the
            // cursor jumped
            let span =
                (now_tick - self.cursor + 1).min(WHEEL_SLOTS as u64);
            for i in 0..span {
                let idx =
                    ((self.cursor + i) % WHEEL_SLOTS as u64) as usize;
                let slot = &mut self.slots[idx];
                let mut j = 0;
                while j < slot.len() {
                    if slot[j].0 <= now_tick {
                        due.push(slot.swap_remove(j));
                        self.len -= 1;
                    } else {
                        j += 1;
                    }
                }
            }
        }
        self.cursor = now_tick + 1;
        due.sort_by_key(|&(tick, _)| tick);
        due.into_iter().map(|(_, k)| k).collect()
    }
}

// ---------------------------------------------------------------------
// Connections

/// Opaque connection id, unique over a reactor's lifetime (never
/// reused, so a stale token in a late command refers to nothing rather
/// than to somebody else's connection).
pub type Token = u64;

/// How a connection's byte stream is interpreted. `Framed` runs the
/// length-prefixed wire protocol through the incremental
/// `FrameDecoder`; `Raw` hands read chunks straight to
/// [`Driver::on_raw`] and writes queued via [`Ctl::send_raw`] go out
/// without frame headers — the class a plain-HTTP `/metrics` listener
/// uses. Decided per *listener* ([`Driver::conn_class`]) at accept;
/// connections registered through [`Handle::register`] are framed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum ConnClass {
    Framed,
    Raw,
}

/// Per-connection write queue with two priorities. `ctrl` frames
/// (standalone, small) drain before the next `bulk` frame; bulk
/// messages are enqueued as their full chunk run at once, so chunks of
/// different messages never interleave — the invariant `MessageReader`
/// relies on.
#[derive(Default)]
struct Outbox {
    ctrl: VecDeque<Vec<u8>>,
    bulk: VecDeque<Vec<u8>>,
    /// Frame currently on the wire: buffer + bytes already written.
    cur: Option<(Vec<u8>, usize)>,
    /// Total queued bytes (including the unwritten tail of `cur`).
    bytes: usize,
}

impl Outbox {
    fn is_empty(&self) -> bool {
        self.cur.is_none() && self.ctrl.is_empty() && self.bulk.is_empty()
    }

    /// Next frame to put on the wire, honoring ctrl priority.
    fn refill(&mut self) {
        if self.cur.is_none() {
            self.cur = self
                .ctrl
                .pop_front()
                .or_else(|| self.bulk.pop_front())
                .map(|f| (f, 0));
        }
    }
}

struct Conn {
    stream: TcpStream,
    class: ConnClass,
    decoder: FrameDecoder,
    outbox: Outbox,
    /// Total payload bytes read — the reactor-mode replacement for the
    /// threaded cluster's `CountingReader` stall watermark.
    bytes_in: u64,
    /// Last instant a write made progress (or the outbox was empty).
    write_progress: Instant,
    /// Close as soon as the outbox drains (typed reject-then-close).
    close_after_flush: bool,
}

// ---------------------------------------------------------------------
// Driver + control surface

/// The layer a reactor hosts. Every method runs on the reactor thread,
/// so implementations mutate their connection bookkeeping without
/// locks; anything slow (compute, blocking dials) must be handed to
/// other threads, which re-enter through a [`Handle`].
pub(crate) trait Driver: Send + 'static {
    /// Context delivered with connections registered via
    /// [`Handle::register`].
    type Tag: Send + 'static;

    /// Tag for a listener-accepted connection.
    fn accept_tag(&mut self, listener: Token, peer: SocketAddr)
                  -> Self::Tag;

    /// Byte-stream class for connections accepted on `listener`
    /// (default: every listener speaks the framed wire protocol).
    fn conn_class(&mut self, _listener: Token) -> ConnClass {
        ConnClass::Framed
    }

    /// A connection entered the loop (accepted or registered).
    fn on_open(&mut self, ctl: &mut Ctl<'_>, token: Token,
               tag: Self::Tag);

    /// One complete wire message arrived on `token`.
    fn on_message(&mut self, ctl: &mut Ctl<'_>, token: Token,
                  payload: Vec<u8>);

    /// A read chunk arrived on a [`ConnClass::Raw`] connection —
    /// unframed bytes, delivered as they come off the socket. The
    /// default drops them (a driver without raw listeners never sees
    /// this).
    fn on_raw(&mut self, _ctl: &mut Ctl<'_>, _token: Token,
              _chunk: &[u8]) {
    }

    /// `token` left the loop: peer close, wire error, write stall, or
    /// outbox overflow. Not called for closes the driver itself
    /// requested through [`Ctl::close`] / [`Handle::close`].
    fn on_close(&mut self, ctl: &mut Ctl<'_>, token: Token,
                cause: WireError);

    /// A timer scheduled through [`Ctl::set_timer`] /
    /// [`Handle::timer`] fired.
    fn on_timer(&mut self, ctl: &mut Ctl<'_>, key: u64);
}

/// The reactor's mutable surface handed to [`Driver`] callbacks:
/// enqueue writes, close connections, schedule timers, stop the loop.
pub(crate) struct Ctl<'a> {
    conns: &'a mut HashMap<Token, Conn>,
    timers: &'a mut TimerWheel,
    opts: &'a ReactorOpts,
    now: Instant,
    stopping: &'a mut bool,
}

impl Ctl<'_> {
    pub fn now(&self) -> Instant {
        self.now
    }

    pub fn conn_count(&self) -> usize {
        self.conns.len()
    }

    /// Payload bytes read on `token` so far (stall-probe watermark).
    pub fn bytes_in(&self, token: Token) -> u64 {
        self.conns.get(&token).map_or(0, |c| c.bytes_in)
    }

    /// Queue a message on `token`'s bulk lane (chunked past
    /// `CHUNK_LEN`). On overflow the connection is dropped — the
    /// driver gets the error here instead of an `on_close`, since it
    /// initiated the send.
    pub fn send(&mut self, token: Token, payload: &[u8])
                -> Result<(), WireError> {
        enqueue(self.conns, self.opts, token, payload, false)
    }

    /// Queue a small control frame at ctrl priority (pongs, typed
    /// errors); payloads past `CHUNK_LEN` fall back to the bulk lane.
    pub fn send_ctrl(&mut self, token: Token, payload: &[u8])
                     -> Result<(), WireError> {
        enqueue(self.conns, self.opts, token, payload, true)
    }

    /// Queue bytes verbatim — no frame header — on `token`'s bulk
    /// lane: the write path for [`ConnClass::Raw`] connections (e.g.
    /// an HTTP response). Same overflow discipline as [`Ctl::send`].
    pub fn send_raw(&mut self, token: Token, payload: &[u8])
                    -> Result<(), WireError> {
        enqueue_raw(self.conns, self.opts, token, payload)
    }

    /// Drop `token` now; queued output is discarded. No `on_close`.
    pub fn close(&mut self, token: Token) {
        self.conns.remove(&token);
    }

    /// Close `token` once its outbox drains (reject-then-close).
    pub fn close_after_flush(&mut self, token: Token) {
        if let Some(c) = self.conns.get_mut(&token) {
            c.close_after_flush = true;
        }
    }

    pub fn set_timer(&mut self, at: Instant, key: u64) {
        self.timers.schedule(at, key);
    }

    /// End the loop after this callback round; remaining connections
    /// are dropped (the owning layer drains work *before* stopping).
    pub fn stop(&mut self) {
        *self.stopping = true;
    }
}

/// Shared enqueue for `Ctl` and command processing.
fn enqueue(conns: &mut HashMap<Token, Conn>, opts: &ReactorOpts,
           token: Token, payload: &[u8], ctrl: bool)
           -> Result<(), WireError> {
    let conn = match conns.get_mut(&token) {
        Some(c) => c,
        None => return Err(WireError::Closed),
    };
    // ctrl priority only for frames that stay standalone; a chunked
    // run always rides the bulk lane (chunks of different messages
    // must never interleave)
    let as_ctrl = ctrl && payload.len() <= CHUNK_LEN;
    let frames: Vec<Vec<u8>> = if as_ctrl {
        vec![encode_frame(payload)?]
    } else {
        chunk_plan(payload.len())?
            .into_iter()
            .map(|(range, bits)| {
                encode_frame_ctrl(&payload[range], bits)
            })
            .collect::<Result<_, _>>()?
    };
    let add: usize = frames.iter().map(Vec::len).sum();
    if conn.outbox.bytes + add > opts.max_outbox {
        conns.remove(&token);
        return Err(WireError::Io(format!(
            "outbox overflow ({add} bytes over the {} cap): \
             slow consumer dropped",
            opts.max_outbox
        )));
    }
    if conn.outbox.is_empty() {
        // outbox was idle — restart the stall clock
        conn.write_progress = Instant::now();
    }
    conn.outbox.bytes += add;
    for f in frames {
        if as_ctrl {
            conn.outbox.ctrl.push_back(f);
        } else {
            conn.outbox.bulk.push_back(f);
        }
    }
    Ok(())
}

/// [`Ctl::send_raw`]'s enqueue: the payload goes out byte-for-byte,
/// so it rides the bulk lane whole (raw peers have no framing to
/// interleave around).
fn enqueue_raw(conns: &mut HashMap<Token, Conn>, opts: &ReactorOpts,
               token: Token, payload: &[u8])
               -> Result<(), WireError> {
    let conn = match conns.get_mut(&token) {
        Some(c) => c,
        None => return Err(WireError::Closed),
    };
    if conn.outbox.bytes + payload.len() > opts.max_outbox {
        conns.remove(&token);
        return Err(WireError::Io(format!(
            "outbox overflow ({} bytes over the {} cap): \
             slow consumer dropped",
            payload.len(),
            opts.max_outbox
        )));
    }
    if conn.outbox.is_empty() {
        conn.write_progress = Instant::now();
    }
    conn.outbox.bytes += payload.len();
    conn.outbox.bulk.push_back(payload.to_vec());
    Ok(())
}

// ---------------------------------------------------------------------
// Handle: the cross-thread command surface

enum Cmd<T> {
    Register { stream: TcpStream, tag: T },
    Send { token: Token, payload: Vec<u8>, ctrl: bool },
    Close { token: Token },
    /// Close every connection (listeners stay) — the reactor analogue
    /// of the threaded node's `sever_connections`.
    SeverAll,
    Timer { at: Instant, key: u64 },
    Stop,
}

/// Wake pipe: one byte into a non-blocking `UnixStream` pops the
/// reactor out of `poll`. A full pipe means a wake is already pending,
/// so `WouldBlock` is success.
struct WakePipe(UnixStream);

impl WakePipe {
    fn wake(&self) {
        let _ = (&self.0).write(&[1u8]);
    }
}

/// Cloneable cross-thread mailbox into a running reactor. Every
/// method returns whether the reactor was still alive to receive the
/// command (false after [`Handle::stop`] or a reactor panic).
pub(crate) struct Handle<T> {
    tx: Sender<Cmd<T>>,
    wake: Arc<WakePipe>,
}

impl<T> Clone for Handle<T> {
    fn clone(&self) -> Self {
        Handle { tx: self.tx.clone(), wake: self.wake.clone() }
    }
}

impl<T: Send + 'static> Handle<T> {
    fn push(&self, cmd: Cmd<T>) -> bool {
        let ok = self.tx.send(cmd).is_ok();
        if ok {
            self.wake.wake();
        }
        ok
    }

    /// Hand a connected stream to the reactor; `tag` comes back in
    /// `Driver::on_open`.
    pub fn register(&self, stream: TcpStream, tag: T) -> bool {
        self.push(Cmd::Register { stream, tag })
    }

    /// Queue a bulk message (chunked) on `token`. A token that has
    /// since closed drops the message, as the threaded path's "reply
    /// dropped" did.
    pub fn send(&self, token: Token, payload: Vec<u8>) -> bool {
        self.push(Cmd::Send { token, payload, ctrl: false })
    }

    /// Queue a small control frame at ctrl priority on `token`.
    pub fn send_ctrl(&self, token: Token, payload: Vec<u8>) -> bool {
        self.push(Cmd::Send { token, payload, ctrl: true })
    }

    pub fn close(&self, token: Token) -> bool {
        self.push(Cmd::Close { token })
    }

    pub fn sever_all(&self) -> bool {
        self.push(Cmd::SeverAll)
    }

    pub fn timer(&self, at: Instant, key: u64) -> bool {
        self.push(Cmd::Timer { at, key })
    }

    /// Stop the loop; open connections are dropped.
    pub fn stop(&self) -> bool {
        self.push(Cmd::Stop)
    }
}

// ---------------------------------------------------------------------
// Reactor

pub(crate) struct ReactorOpts {
    /// Accepting pauses (listener left out of the poll set, backlog
    /// takes the pressure) while this many connections are open.
    pub max_conns: usize,
    /// Per-connection outbox byte cap; past it the peer is dropped as
    /// a slow consumer.
    pub max_outbox: usize,
    /// Close a connection whose pending writes make no progress for
    /// this long (mirrors the threaded path's write timeout).
    pub write_stall: Duration,
}

impl Default for ReactorOpts {
    fn default() -> ReactorOpts {
        ReactorOpts {
            max_conns: 4096,
            max_outbox: 256 << 20,
            write_stall: Duration::from_secs(30),
        }
    }
}

/// A running reactor thread. Stop it with [`Handle::stop`], then
/// [`Reactor::join`].
pub(crate) struct Reactor {
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Reactor {
    /// Start the loop over `listeners` (may be empty; more connections
    /// arrive via [`Handle::register`]). Returns the handle and the
    /// listener tokens, in `listeners` order.
    pub fn spawn<D: Driver>(driver: D, listeners: Vec<TcpListener>,
                            opts: ReactorOpts)
                            -> std::io::Result<(Reactor, Handle<D::Tag>,
                                                Vec<Token>)> {
        let (wake_tx, wake_rx) = UnixStream::pair()?;
        wake_tx.set_nonblocking(true)?;
        wake_rx.set_nonblocking(true)?;
        for l in &listeners {
            l.set_nonblocking(true)?;
        }
        let (tx, rx) = channel();
        let handle =
            Handle { tx, wake: Arc::new(WakePipe(wake_tx)) };
        let ltokens: Vec<Token> =
            (1..=listeners.len() as u64).collect();
        let lpairs: Vec<(Token, TcpListener)> =
            ltokens.iter().copied().zip(listeners).collect();
        let thread = std::thread::Builder::new()
            .name("tqdit-net-reactor".into())
            .spawn(move || run_loop(driver, lpairs, wake_rx, rx, opts))?;
        Ok((Reactor { thread: Some(thread) }, handle, ltokens))
    }

    pub fn join(mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Read-side scratch buffer size per `read(2)` call.
const READ_BUF: usize = 64 << 10;
/// Poll timeout cap while any outbox is non-empty, so the write-stall
/// sweep runs even when the peer never becomes writable again.
const STALL_SWEEP: Duration = Duration::from_millis(250);

fn run_loop<D: Driver>(mut driver: D,
                       listeners: Vec<(Token, TcpListener)>,
                       wake_rx: UnixStream, cmds: Receiver<Cmd<D::Tag>>,
                       opts: ReactorOpts) {
    let mut conns: HashMap<Token, Conn> = HashMap::new();
    let mut timers = TimerWheel::new(Instant::now());
    let mut next_token: Token = listeners.len() as u64 + 1;
    let mut stopping = false;
    let mut scratch = vec![0u8; READ_BUF];
    // reused poll set; rebuilt every iteration (tokens parallel to fds)
    let mut pfds: Vec<PollFd> = Vec::new();
    let mut ptokens: Vec<Token> = Vec::new();

    macro_rules! ctl {
        () => {
            Ctl {
                conns: &mut conns,
                timers: &mut timers,
                opts: &opts,
                now: Instant::now(),
                stopping: &mut stopping,
            }
        };
    }

    loop {
        // -- commands from other threads ------------------------------
        while let Ok(cmd) = cmds.try_recv() {
            match cmd {
                Cmd::Register { stream, tag } => {
                    if let Err(e) = stream.set_nonblocking(true) {
                        warn_log!("reactor: set_nonblocking failed: {e}");
                        continue;
                    }
                    let token = next_token;
                    next_token += 1;
                    conns.insert(
                        token,
                        new_conn(stream, ConnClass::Framed),
                    );
                    driver.on_open(&mut ctl!(), token, tag);
                }
                Cmd::Send { token, payload, ctrl } => {
                    match enqueue(&mut conns, &opts, token, &payload,
                                  ctrl) {
                        Ok(()) => {}
                        // token already gone: reply dropped, exactly
                        // like the threaded path's dead-stream send
                        Err(WireError::Closed) => {}
                        // overflow (conn already removed) or an
                        // unencodable message: drop the connection —
                        // the sender is remote from the loop, so
                        // surface it as a close event
                        Err(e) => {
                            conns.remove(&token);
                            driver.on_close(&mut ctl!(), token, e);
                        }
                    }
                }
                Cmd::Close { token } => {
                    conns.remove(&token);
                }
                Cmd::SeverAll => {
                    let tokens: Vec<Token> =
                        conns.keys().copied().collect();
                    for t in tokens {
                        conns.remove(&t);
                        driver.on_close(
                            &mut ctl!(),
                            t,
                            WireError::Io(
                                "connection severed".into(),
                            ),
                        );
                    }
                }
                Cmd::Timer { at, key } => timers.schedule(at, key),
                Cmd::Stop => stopping = true,
            }
        }
        if stopping {
            break;
        }

        // -- timers ---------------------------------------------------
        for key in timers.expire(Instant::now()) {
            driver.on_timer(&mut ctl!(), key);
            if stopping {
                break;
            }
        }
        if stopping {
            break;
        }

        // -- flush pending writes, sweep stalls -----------------------
        let now = Instant::now();
        let mut dead: Vec<(Token, WireError)> = Vec::new();
        let mut flushed: Vec<Token> = Vec::new();
        for (&t, conn) in conns.iter_mut() {
            if conn.outbox.is_empty() {
                if conn.close_after_flush {
                    flushed.push(t);
                }
                continue;
            }
            match flush_conn(conn) {
                Ok(()) => {
                    if conn.outbox.is_empty() && conn.close_after_flush
                    {
                        flushed.push(t);
                    } else if !conn.outbox.is_empty()
                        && now.duration_since(conn.write_progress)
                            > opts.write_stall
                    {
                        dead.push((
                            t,
                            WireError::Io(format!(
                                "write stalled for {:?}",
                                opts.write_stall
                            )),
                        ));
                    }
                }
                Err(e) => dead.push((t, e)),
            }
        }
        for t in flushed {
            conns.remove(&t);
        }
        for (t, e) in dead {
            conns.remove(&t);
            driver.on_close(&mut ctl!(), t, e);
        }

        // -- build the poll set ---------------------------------------
        pfds.clear();
        ptokens.clear();
        pfds.push(PollFd {
            fd: wake_rx.as_raw_fd(),
            events: POLLIN,
            revents: 0,
        });
        ptokens.push(0);
        let accepting = conns.len() < opts.max_conns;
        if accepting {
            for (t, l) in &listeners {
                pfds.push(PollFd {
                    fd: l.as_raw_fd(),
                    events: POLLIN,
                    revents: 0,
                });
                ptokens.push(*t);
            }
        }
        let mut any_outbox = false;
        for (&t, conn) in conns.iter() {
            let mut ev = POLLIN;
            if !conn.outbox.is_empty() {
                ev |= POLLOUT;
                any_outbox = true;
            }
            pfds.push(PollFd {
                fd: conn.stream.as_raw_fd(),
                events: ev,
                revents: 0,
            });
            ptokens.push(t);
        }

        // -- poll -----------------------------------------------------
        let now = Instant::now();
        let mut timeout: Option<Duration> =
            timers.next_deadline().map(|d| d.saturating_duration_since(now));
        if any_outbox {
            let cap = timeout.map_or(STALL_SWEEP, |t| t.min(STALL_SWEEP));
            timeout = Some(cap);
        }
        let timeout_ms: i32 = match timeout {
            None => -1,
            Some(d) => d.as_millis().min(i32::MAX as u128) as i32,
        };
        let rc = unsafe {
            poll(pfds.as_mut_ptr(), pfds.len(), timeout_ms)
        };
        if rc < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                continue;
            }
            warn_log!("reactor: poll failed: {e}; stopping");
            break;
        }

        // -- dispatch readiness ---------------------------------------
        let ready: Vec<(Token, i16)> = pfds
            .iter()
            .zip(ptokens.iter())
            .filter(|(p, _)| p.revents != 0)
            .map(|(p, &t)| (t, p.revents))
            .collect();
        for (t, revents) in ready {
            if t == 0 {
                // wake pipe: drain it
                loop {
                    match (&wake_rx).read(&mut scratch) {
                        Ok(0) => break,
                        Ok(_) => {}
                        Err(_) => break,
                    }
                }
                continue;
            }
            if let Some((lt, l)) =
                listeners.iter().find(|(lt, _)| *lt == t)
            {
                accept_ready(*lt, l, &mut conns, &mut next_token,
                             &opts, &mut driver, &mut timers,
                             &mut stopping);
                continue;
            }
            if revents & POLLNVAL != 0 {
                // fd vanished under us (should not happen: tokens are
                // removed with their conns) — drop the bookkeeping
                conns.remove(&t);
                continue;
            }
            if revents & (POLLIN | POLLHUP | POLLERR) != 0 {
                read_ready(t, &mut conns, &mut scratch, &mut driver,
                           &mut timers, &opts, &mut stopping);
            }
            if stopping {
                break;
            }
            if revents & POLLOUT != 0 {
                if let Some(conn) = conns.get_mut(&t) {
                    if let Err(e) = flush_conn(conn) {
                        conns.remove(&t);
                        driver.on_close(&mut ctl!(), t, e);
                    } else if conn.outbox.is_empty()
                        && conn.close_after_flush
                    {
                        conns.remove(&t);
                    }
                }
            }
        }
        if stopping {
            break;
        }
    }
}

fn new_conn(stream: TcpStream, class: ConnClass) -> Conn {
    let _ = stream.set_nodelay(true);
    Conn {
        stream,
        class,
        decoder: FrameDecoder::new(),
        outbox: Outbox::default(),
        bytes_in: 0,
        write_progress: Instant::now(),
        close_after_flush: false,
    }
}

fn accept_ready<D: Driver>(ltoken: Token, listener: &TcpListener,
                           conns: &mut HashMap<Token, Conn>,
                           next_token: &mut Token, opts: &ReactorOpts,
                           driver: &mut D, timers: &mut TimerWheel,
                           stopping: &mut bool) {
    // accept until WouldBlock or the connection cap; leftover backlog
    // stays queued in the kernel until capacity frees up
    loop {
        if conns.len() >= opts.max_conns {
            return;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let token = *next_token;
                *next_token += 1;
                let class = driver.conn_class(ltoken);
                conns.insert(token, new_conn(stream, class));
                let tag = driver.accept_tag(ltoken, peer);
                let mut ctl = Ctl {
                    conns,
                    timers,
                    opts,
                    now: Instant::now(),
                    stopping,
                };
                driver.on_open(&mut ctl, token, tag);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                return;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                warn_log!("reactor: accept failed: {e}");
                return;
            }
        }
    }
}

fn read_ready<D: Driver>(token: Token,
                         conns: &mut HashMap<Token, Conn>,
                         scratch: &mut [u8], driver: &mut D,
                         timers: &mut TimerWheel, opts: &ReactorOpts,
                         stopping: &mut bool) {
    // pull everything available, decode complete messages (or, on a
    // raw-class connection, collect the chunks as they are), then
    // dispatch — dispatching after the borrow ends lets the driver
    // write back to this very connection
    let mut msgs: Vec<Vec<u8>> = Vec::new();
    let raw = match conns.get(&token) {
        Some(c) => c.class == ConnClass::Raw,
        None => return,
    };
    let mut close: Option<WireError> = None;
    {
        let conn = match conns.get_mut(&token) {
            Some(c) => c,
            None => return,
        };
        'read: loop {
            match conn.stream.read(scratch) {
                Ok(0) => {
                    close = Some(if raw {
                        WireError::Closed
                    } else {
                        conn.decoder.close_error()
                    });
                    break;
                }
                Ok(n) => {
                    conn.bytes_in += n as u64;
                    if raw {
                        msgs.push(scratch[..n].to_vec());
                        continue;
                    }
                    conn.decoder.push(&scratch[..n]);
                    loop {
                        match conn.decoder.next() {
                            Ok(Some(m)) => msgs.push(m),
                            Ok(None) => break,
                            Err(e) => {
                                close = Some(e);
                                break 'read;
                            }
                        }
                    }
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::WouldBlock =>
                {
                    break;
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    close = Some(WireError::Io(e.to_string()));
                    break;
                }
            }
        }
    }
    for m in msgs {
        if !conns.contains_key(&token) {
            return; // driver closed it mid-burst
        }
        let mut ctl = Ctl {
            conns,
            timers,
            opts,
            now: Instant::now(),
            stopping,
        };
        if raw {
            driver.on_raw(&mut ctl, token, &m);
        } else {
            driver.on_message(&mut ctl, token, m);
        }
        if *stopping {
            return;
        }
    }
    if let Some(cause) = close {
        if conns.remove(&token).is_some() {
            let mut ctl = Ctl {
                conns,
                timers,
                opts,
                now: Instant::now(),
                stopping,
            };
            driver.on_close(&mut ctl, token, cause);
        }
    }
}

/// Write queued frames until the socket would block or the outbox
/// drains. Progress (any bytes accepted) resets the stall clock.
fn flush_conn(conn: &mut Conn) -> Result<(), WireError> {
    loop {
        conn.outbox.refill();
        let (buf, off) = match conn.outbox.cur.as_mut() {
            Some(c) => c,
            None => return Ok(()),
        };
        match conn.stream.write(&buf[*off..]) {
            Ok(0) => {
                return Err(WireError::Io(
                    "write returned zero bytes".into(),
                ));
            }
            Ok(n) => {
                *off += n;
                conn.outbox.bytes -= n;
                conn.write_progress = Instant::now();
                if *off == buf.len() {
                    conn.outbox.cur = None;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                return Ok(());
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
}

// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::net::wire::{read_frame, write_frame};
    use std::sync::mpsc::Sender as MpscSender;

    // -- timer wheel ---------------------------------------------------

    #[test]
    fn timer_wheel_fires_in_deadline_order() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(t0);
        // shuffled schedule order; expiry must sort by deadline
        for (ms, key) in
            [(40u64, 4u64), (8, 1), (24, 3), (16, 2), (120, 5)]
        {
            w.schedule(t0 + Duration::from_millis(ms), key);
        }
        assert_eq!(w.expire(t0 + Duration::from_millis(1)), vec![]);
        assert_eq!(w.expire(t0 + Duration::from_millis(17)),
                   vec![1, 2]);
        assert_eq!(w.expire(t0 + Duration::from_millis(200)),
                   vec![3, 4, 5]);
        assert!(w.is_empty());
    }

    #[test]
    fn timer_wheel_handles_past_deadlines_and_long_horizons() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(t0);
        // never-early: a deadline between ticks rounds up to the next
        // tick boundary
        let at = t0 + Duration::from_millis(200) + Duration::from_micros(1);
        w.schedule(at, 11);
        assert_eq!(w.expire(t0 + Duration::from_millis(200)), vec![]);
        assert_eq!(w.expire(at + TICK), vec![11]);
        // sweep forward, then schedule "in the past": the deadline
        // clamps to the cursor and fires on the next sweep
        let _ = w.expire(t0 + Duration::from_millis(400));
        w.schedule(t0 + Duration::from_millis(100), 7);
        assert_eq!(w.expire(t0 + Duration::from_millis(420)), vec![7]);
        // past one wheel rotation (512 × 4 ms ≈ 2 s): must not fire
        // early, must fire eventually
        w.schedule(t0 + Duration::from_secs(10), 9);
        assert_eq!(w.expire(t0 + Duration::from_secs(9)), vec![]);
        assert_eq!(w.expire(t0 + Duration::from_secs(11)), vec![9]);
    }

    #[test]
    fn timer_wheel_next_deadline_tracks_earliest() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(t0);
        assert_eq!(w.next_deadline(), None);
        w.schedule(t0 + Duration::from_millis(100), 1);
        w.schedule(t0 + Duration::from_millis(40), 2);
        let d = w.next_deadline().unwrap();
        let dt = d.duration_since(t0);
        assert!(dt >= Duration::from_millis(40)
                    && dt <= Duration::from_millis(44),
                "next deadline {dt:?} should round 40ms up ≤ one tick");
    }

    // -- reactor over loopback ----------------------------------------

    /// Records lifecycle events and echoes every message back; a
    /// `big_replies` knob makes each request fan out into `n` large
    /// responses (backpressure tests).
    struct EchoDriver {
        events: MpscSender<String>,
        reply_bytes: usize,
        replies_per_msg: usize,
    }

    impl EchoDriver {
        fn plain(events: MpscSender<String>) -> EchoDriver {
            EchoDriver { events, reply_bytes: 0, replies_per_msg: 1 }
        }
    }

    impl Driver for EchoDriver {
        type Tag = ();
        fn accept_tag(&mut self, _l: Token, _p: SocketAddr) {}
        fn on_open(&mut self, _ctl: &mut Ctl<'_>, token: Token,
                   _tag: ()) {
            let _ = self.events.send(format!("open {token}"));
        }
        fn on_message(&mut self, ctl: &mut Ctl<'_>, token: Token,
                      payload: Vec<u8>) {
            if self.reply_bytes == 0 {
                let _ = ctl.send(token, &payload);
                return;
            }
            let reply: Vec<u8> = (0..self.reply_bytes)
                .map(|i| (i * 7 % 251) as u8)
                .collect();
            for _ in 0..self.replies_per_msg {
                if ctl.send(token, &reply).is_err() {
                    let _ = self.events.send(format!(
                        "overflow {token}"
                    ));
                    return;
                }
            }
        }
        fn on_close(&mut self, _ctl: &mut Ctl<'_>, token: Token,
                    cause: WireError) {
            let _ = self.events.send(format!("close {token} {cause}"));
        }
        fn on_timer(&mut self, _ctl: &mut Ctl<'_>, key: u64) {
            let _ = self.events.send(format!("timer {key}"));
        }
    }

    fn spawn_echo(driver: EchoDriver)
                  -> (Reactor, Handle<()>, SocketAddr) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let (r, h, _lt) =
            Reactor::spawn(driver, vec![l], ReactorOpts::default())
                .unwrap();
        (r, h, addr)
    }

    #[test]
    fn echo_roundtrip_and_clean_shutdown() {
        let (ev_tx, ev_rx) = channel();
        let (r, h, addr) = spawn_echo(EchoDriver::plain(ev_tx));
        let mut c = TcpStream::connect(addr).unwrap();
        write_frame(&mut c, b"hello reactor").unwrap();
        assert_eq!(read_frame(&mut c).unwrap(), b"hello reactor");
        // several messages on one connection, strictly ordered
        for i in 0..20u8 {
            write_frame(&mut c, &[i; 33]).unwrap();
        }
        for i in 0..20u8 {
            assert_eq!(read_frame(&mut c).unwrap(), vec![i; 33]);
        }
        drop(c);
        // the close is observed and typed as a clean boundary
        let evs: Vec<String> =
            std::iter::from_fn(|| {
                ev_rx.recv_timeout(Duration::from_secs(10)).ok()
            })
            .take_while(|e| !e.starts_with("close"))
            .chain(std::iter::once("close".into()))
            .collect();
        assert!(evs.iter().any(|e| e.starts_with("open")));
        h.stop();
        r.join();
    }

    #[test]
    fn chunked_messages_cross_the_reactor_both_ways() {
        let (ev_tx, _ev_rx) = channel();
        let (r, h, addr) = spawn_echo(EchoDriver::plain(ev_tx));
        let mut c = TcpStream::connect(addr).unwrap();
        let big: Vec<u8> =
            (0..CHUNK_LEN * 2 + 123).map(|i| (i % 251) as u8).collect();
        crate::serve::net::wire::write_message(&mut c, &big).unwrap();
        assert_eq!(read_frame(&mut c).unwrap(), big);
        h.stop();
        r.join();
    }

    #[test]
    fn driver_timers_fire_through_the_handle() {
        let (ev_tx, ev_rx) = channel();
        let (r, h, _addr) = spawn_echo(EchoDriver::plain(ev_tx));
        let now = Instant::now();
        h.timer(now + Duration::from_millis(30), 2);
        h.timer(now + Duration::from_millis(10), 1);
        h.timer(now + Duration::from_millis(60), 3);
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(
                ev_rx.recv_timeout(Duration::from_secs(10)).unwrap(),
            );
        }
        assert_eq!(got, vec!["timer 1", "timer 2", "timer 3"]);
        h.stop();
        r.join();
    }

    #[test]
    fn partial_writes_backpressure_then_complete_intact() {
        // the driver enqueues ~6 MiB toward a client that reads
        // nothing for a while: writes must park on WouldBlock
        // mid-frame, then resume and deliver every byte once the
        // client drains — no corruption, no stall-close (progress
        // resumes well inside write_stall)
        let (ev_tx, _ev_rx) = channel();
        let driver = EchoDriver {
            events: ev_tx,
            reply_bytes: 3 << 20,
            replies_per_msg: 2,
        };
        let (r, h, addr) = spawn_echo(driver);
        let mut c = TcpStream::connect(addr).unwrap();
        write_frame(&mut c, b"go").unwrap();
        // let the outbox fill against our unread socket
        std::thread::sleep(Duration::from_millis(300));
        let want: Vec<u8> =
            (0..3 << 20).map(|i| (i * 7 % 251) as u8).collect();
        assert_eq!(read_frame(&mut c).unwrap(), want);
        assert_eq!(read_frame(&mut c).unwrap(), want);
        h.stop();
        r.join();
    }

    #[test]
    fn outbox_overflow_drops_the_slow_consumer() {
        let (ev_tx, ev_rx) = channel();
        let driver = EchoDriver {
            events: ev_tx,
            reply_bytes: 1 << 20,
            replies_per_msg: 64, // 64 MiB >> the 4 MiB cap below
        };
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let opts = ReactorOpts {
            max_outbox: 4 << 20,
            ..ReactorOpts::default()
        };
        let (r, h, _lt) = Reactor::spawn(driver, vec![l], opts).unwrap();
        let mut c = TcpStream::connect(addr).unwrap();
        write_frame(&mut c, b"flood me").unwrap();
        // never read: the reactor must cut us loose, typed as overflow
        let ev = std::iter::from_fn(|| {
            ev_rx.recv_timeout(Duration::from_secs(10)).ok()
        })
        .find(|e| e.starts_with("overflow"))
        .expect("overflow event");
        assert!(ev.starts_with("overflow"));
        // and the socket really is closed: reads drain then EOF/reset
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut sink = vec![0u8; 1 << 16];
        loop {
            match c.read(&mut sink) {
                Ok(0) => break,
                Ok(_) => {}
                Err(_) => break,
            }
        }
        h.stop();
        r.join();
    }

    #[test]
    fn c10k_idle_connections_bounded_threads() {
        // ≥1k concurrent idle loopback connections on one reactor
        // thread; thread count must stay O(workers), not O(conns)
        raise_nofile_limit(8192);
        let before = process_thread_count().unwrap_or(0);
        let (ev_tx, _ev_rx) = channel();
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let opts =
            ReactorOpts { max_conns: 4096, ..ReactorOpts::default() };
        let (r, h, _lt) =
            Reactor::spawn(EchoDriver::plain(ev_tx), vec![l], opts)
                .unwrap();
        let n = 1024;
        let mut clients = Vec::with_capacity(n);
        for _ in 0..n {
            clients.push(TcpStream::connect(addr).unwrap());
        }
        // every connection is live: ping a sample spread across the
        // set, then prove all of them still round-trip
        for c in clients.iter_mut().step_by(97) {
            write_frame(c, b"ping").unwrap();
            assert_eq!(read_frame(c).unwrap(), b"ping");
        }
        let during = process_thread_count().unwrap_or(0);
        // the reactor added exactly one thread; generous slack for
        // concurrently-running tests in the same process
        assert!(
            during < before + 50,
            "thread count grew O(connections): {before} -> {during}"
        );
        for c in clients.iter_mut() {
            write_frame(c, b"x").unwrap();
        }
        for c in clients.iter_mut() {
            assert_eq!(read_frame(c).unwrap(), b"x");
        }
        drop(clients);
        h.stop();
        r.join();
    }

    #[test]
    fn max_conns_pauses_accepting_until_capacity_frees() {
        let (ev_tx, _ev_rx) = channel();
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let opts =
            ReactorOpts { max_conns: 2, ..ReactorOpts::default() };
        let (r, h, _lt) =
            Reactor::spawn(EchoDriver::plain(ev_tx), vec![l], opts)
                .unwrap();
        let mut a = TcpStream::connect(addr).unwrap();
        let mut b = TcpStream::connect(addr).unwrap();
        write_frame(&mut a, b"a").unwrap();
        assert_eq!(read_frame(&mut a).unwrap(), b"a");
        write_frame(&mut b, b"b").unwrap();
        assert_eq!(read_frame(&mut b).unwrap(), b"b");
        // third connection sits in the backlog: connect succeeds
        // (kernel accepts the SYN) but no echo arrives while full
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        write_frame(&mut c, b"c").unwrap();
        assert!(read_frame(&mut c).is_err(),
                "served past max_conns");
        // free a slot: the parked connection gets admitted and served
        drop(a);
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        assert_eq!(read_frame(&mut c).unwrap(), b"c");
        h.stop();
        r.join();
    }

    /// Echoes framed messages framed and raw chunks raw; classifies
    /// connections by their listener.
    struct MixedEcho {
        raw_listener: Token,
    }

    impl Driver for MixedEcho {
        type Tag = ();
        fn accept_tag(&mut self, _l: Token, _p: SocketAddr) {}
        fn conn_class(&mut self, listener: Token) -> ConnClass {
            if listener == self.raw_listener {
                ConnClass::Raw
            } else {
                ConnClass::Framed
            }
        }
        fn on_open(&mut self, _ctl: &mut Ctl<'_>, _t: Token,
                   _tag: ()) {
        }
        fn on_message(&mut self, ctl: &mut Ctl<'_>, token: Token,
                      payload: Vec<u8>) {
            let _ = ctl.send(token, &payload);
        }
        fn on_raw(&mut self, ctl: &mut Ctl<'_>, token: Token,
                  chunk: &[u8]) {
            let _ = ctl.send_raw(token, chunk);
        }
        fn on_close(&mut self, _ctl: &mut Ctl<'_>, _t: Token,
                    _c: WireError) {
        }
        fn on_timer(&mut self, _ctl: &mut Ctl<'_>, _k: u64) {}
    }

    #[test]
    fn raw_and_framed_classes_coexist_on_one_reactor() {
        let lf = TcpListener::bind("127.0.0.1:0").unwrap();
        let lr = TcpListener::bind("127.0.0.1:0").unwrap();
        let fa = lf.local_addr().unwrap();
        let ra = lr.local_addr().unwrap();
        let (r, h, ltokens) = Reactor::spawn(
            MixedEcho { raw_listener: 2 },
            vec![lf, lr],
            ReactorOpts::default(),
        )
        .unwrap();
        // the listener-token contract the node's metrics listener
        // relies on: tokens are 1..=n in `listeners` order
        assert_eq!(ltokens, vec![1, 2]);
        // the framed listener still frames
        let mut f = TcpStream::connect(fa).unwrap();
        write_frame(&mut f, b"framed").unwrap();
        assert_eq!(read_frame(&mut f).unwrap(), b"framed");
        // raw bytes come back without headers
        let mut c = TcpStream::connect(ra).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let want = b"GET /metrics HTTP/1.1\r\n\r\n";
        c.write_all(want).unwrap();
        let mut got = Vec::new();
        let mut buf = [0u8; 256];
        while got.len() < want.len() {
            let n = c.read(&mut buf).unwrap();
            assert!(n > 0, "eof before the raw echo completed");
            got.extend_from_slice(&buf[..n]);
        }
        assert_eq!(&got[..], &want[..]);
        h.stop();
        r.join();
    }

    #[test]
    fn handle_sends_reach_the_wire_from_other_threads() {
        // completion path: a non-reactor thread enqueues a reply
        let (ev_tx, ev_rx) = channel();
        let (r, h, addr) = spawn_echo(EchoDriver::plain(ev_tx));
        let mut c = TcpStream::connect(addr).unwrap();
        write_frame(&mut c, b"sync").unwrap();
        assert_eq!(read_frame(&mut c).unwrap(), b"sync");
        let token: Token = {
            // open event carries the token
            let ev = std::iter::from_fn(|| {
                ev_rx.recv_timeout(Duration::from_secs(10)).ok()
            })
            .find(|e| e.starts_with("open"))
            .unwrap();
            ev.split_whitespace().nth(1).unwrap().parse().unwrap()
        };
        let h2 = h.clone();
        let t = std::thread::spawn(move || {
            assert!(h2.send(token, b"from the pool".to_vec()));
            assert!(h2.send_ctrl(token, b"ctrl".to_vec()));
        });
        // both arrive; the earlier bulk frame was already queued, so
        // order here is bulk then ctrl
        let first = read_frame(&mut c).unwrap();
        let second = read_frame(&mut c).unwrap();
        let mut got = vec![first, second];
        got.sort();
        assert_eq!(got,
                   vec![b"ctrl".to_vec(), b"from the pool".to_vec()]);
        t.join().unwrap();
        h.stop();
        r.join();
    }
}
