//! Shard-node server: any [`Dispatch`] service behind a TCP listener.
//!
//! A node wraps the in-process serve stack (normally a
//! [`GenServer`](crate::serve::GenServer), a mock router in tests) and
//! speaks the [`proto`](crate::serve::net::proto) message set over
//! [`wire`](crate::serve::net::wire) frames, in one of two transport
//! modes selected by [`NodeOpts::reactor`]:
//!
//! **Threaded mode** (the library default here and the original PR 4
//! shape; note the CLI now defaults to `--reactor true` and reaches
//! this path only via `--reactor false`):
//!
//! * one **accept thread** takes connections;
//! * one **connection-handler thread per client** reads frames and
//!   multiplexes `Submit`s straight into the shared service (whose
//!   batcher then packs slots from *all* connections into rungs, same
//!   as local threads would) — `Ping` and `StatsReq` are answered
//!   inline so heartbeats stay prompt under load;
//! * completed responses are forwarded by a small fixed
//!   [`ThreadPool`]: each job blocks on one request's response channel
//!   and writes the reply under the connection's writer locks (frames
//!   from concurrent requests interleave whole, never torn).
//!
//! **Reactor mode** (`reactor: true`): every connection lives on one
//! [`reactor`](crate::serve::net::reactor) thread — accepting, frame
//! reassembly, and writes all run from the readiness loop, so
//! connection count stops costing OS threads (the
//! [`NodeOpts::max_conns`] cap pauses accepting, kernel backlog takes
//! the overflow). Compute is unchanged: `Submit`s feed the same shared
//! service, and the forwarder pool still blocks per in-flight request,
//! re-entering the loop through the reactor handle with the completed
//! reply. Pongs and typed errors ride the ctrl-priority outbox lane —
//! the same "a pong never waits behind more than one chunk" discipline
//! the threaded writer locks enforce. Control connections additionally
//! get [`Msg::StatsDelta`] pushes every [`NodeOpts::stats_push`], so a
//! reactor frontend never has to poll `StatsReq`.
//!
//! Both modes negotiate the wire feature level from `Hello::max_wire`
//! (see [`proto::WIRE_BINARY`]): a peer advertising binary support
//! gets raw-`f32` response payloads instead of JSON.
//!
//! **Control-plane isolation:** a frontend may tag a connection
//! `Hello{role: control}` — the node then expects only ping/stats
//! traffic on it (a submit is rejected typed), and since no response
//! bytes ever travel that connection, a pong cannot queue behind a
//! multi-MiB frame. On *data* connections the same liveness problem is
//! bounded by chunking: responses larger than [`wire::CHUNK_LEN`] are
//! written as chunk runs, the frame lock released between chunks (a
//! per-connection bulk lock keeps different messages' chunks from
//! interleaving), so an inline pong waits behind at most one chunk —
//! not one response.
//!
//! Failure containment mirrors the router's ethos: a malformed
//! *message* (valid frame, bad JSON) is logged and skipped — the
//! connection lives on; a broken *frame stream* closes only that
//! connection; a client hanging up drops only its own replies. The
//! node never panics on peer bytes.
//!
//! Writes carry a timeout so a peer that stops *reading* fails typed
//! instead of wedging the writer locks. [`NodeServer::sever_connections`]
//! force-closes every live connection without touching the service —
//! the fault injection the cluster tests and the loopback bench use to
//! simulate a network partition.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::obs::metrics;
use crate::obs::trace::{self, TraceCtx};
use crate::serve::dispatch::Dispatch;
use crate::serve::error::ServeError;
use crate::serve::net::proto::{Msg, Role, WIRE_TRACE};
use crate::serve::net::reactor::{
    ConnClass, Ctl, Driver, Handle, Reactor, ReactorOpts, Token,
};
use crate::serve::net::wire::{
    write_frame, MessageReader, WireError, WIRE_VERSION,
};
use crate::serve::router::{GenRequest, ServerStats};
use crate::util::threadpool::ThreadPool;
use crate::{debug_log, warn_log};

/// Node tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct NodeOpts {
    /// Response-forwarder pool size: how many completed requests can
    /// be serialized back to clients concurrently.
    pub forwarders: usize,
    /// Serve connections on the `poll(2)` reactor (one thread for all
    /// sockets) instead of a handler thread per connection.
    pub reactor: bool,
    /// Reactor mode: pause accepting while this many connections are
    /// open (kernel backlog absorbs the rest).
    pub max_conns: usize,
    /// Reactor mode: push a [`Msg::StatsDelta`] on every control
    /// connection at this cadence.
    pub stats_push: Duration,
    /// Reactor mode: also bind this address and serve Prometheus
    /// text exposition (`GET /metrics`) from the same reactor thread
    /// — raw HTTP as one more connection class, no extra threads.
    /// Ignored (with a warning) in threaded mode.
    pub metrics_addr: Option<SocketAddr>,
}

impl Default for NodeOpts {
    fn default() -> Self {
        NodeOpts {
            forwarders: 8,
            reactor: false,
            max_conns: 4096,
            stats_push: Duration::from_millis(250),
            metrics_addr: None,
        }
    }
}

/// A client that stops *reading* must fail our writes with a typed
/// error after this long instead of blocking the connection's writer
/// mutex forever (which would also block the inline pong path).
const WRITE_TIMEOUT: std::time::Duration =
    std::time::Duration::from_secs(30);

struct NodeShared {
    svc: Box<dyn Dispatch>,
    pool: ThreadPool,
    /// `(conn id, stream clone)` for every live connection, kept so
    /// shutdown (and fault injection) can force-close them and unblock
    /// the readers. Handlers remove their own entry on exit.
    streams: Mutex<Vec<(usize, TcpStream)>>,
    /// Handles of the connection-handler threads (appended by the
    /// accept thread, drained by shutdown).
    conn_handles: Mutex<Vec<JoinHandle<()>>>,
    closing: AtomicBool,
}

/// Reactor-mode compute core: what the driver and the forwarder pool
/// share. Holds no connection state — that lives in [`NodeDriver`] on
/// the reactor thread.
struct NodeCore {
    svc: Box<dyn Dispatch>,
    pool: ThreadPool,
}

/// Reactor-mode transport half of a [`NodeServer`].
struct ReactorPart {
    core: Arc<NodeCore>,
    handle: Handle<SocketAddr>,
    reactor: Option<Reactor>,
}

/// A serving shard node; dropped or [`NodeServer::shutdown`] stops it.
pub struct NodeServer {
    /// Threaded mode; `None` in reactor mode or after `shutdown`
    /// consumed it (the `Drop` impl forces fields behind options).
    shared: Option<Arc<NodeShared>>,
    /// Reactor mode; `None` in threaded mode.
    reactor: Option<ReactorPart>,
    addr: SocketAddr,
    /// Bound `/metrics` listener address (reactor mode with
    /// [`NodeOpts::metrics_addr`] set; resolves port 0).
    metrics_addr: Option<SocketAddr>,
    accept: Option<JoinHandle<()>>,
}

impl NodeServer {
    /// Bind `listen` (e.g. `127.0.0.1:7070`; port 0 picks a free one —
    /// read it back from [`NodeServer::addr`]) and serve `svc` until
    /// shutdown.
    pub fn start(svc: Box<dyn Dispatch>, listen: &str,
                 opts: NodeOpts) -> Result<NodeServer> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding node listener {listen}"))?;
        let addr = listener
            .local_addr()
            .context("reading node listener address")?;
        if opts.reactor {
            return Self::start_reactor(svc, listener, addr, opts);
        }
        if let Some(m) = opts.metrics_addr {
            warn_log!("node: --metrics-addr {m} needs reactor mode; \
                       not serving metrics");
        }
        let shared = Arc::new(NodeShared {
            svc,
            pool: ThreadPool::new(opts.forwarders.max(1)),
            streams: Mutex::new(Vec::new()),
            conn_handles: Mutex::new(Vec::new()),
            closing: AtomicBool::new(false),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("tqdit-net-accept".into())
            .spawn(move || accept_loop(accept_shared, listener))
            .context("spawning node accept thread")?;
        Ok(NodeServer {
            shared: Some(shared),
            reactor: None,
            addr,
            metrics_addr: None,
            accept: Some(accept),
        })
    }

    fn start_reactor(svc: Box<dyn Dispatch>, listener: TcpListener,
                     addr: SocketAddr, opts: NodeOpts)
                     -> Result<NodeServer> {
        let core = Arc::new(NodeCore {
            svc,
            pool: ThreadPool::new(opts.forwarders.max(1)),
        });
        let mut listeners = vec![listener];
        let mut metrics_addr = None;
        if let Some(m) = opts.metrics_addr {
            let ml = TcpListener::bind(m)
                .with_context(|| format!("binding metrics listener {m}"))?;
            metrics_addr = Some(
                ml.local_addr()
                    .context("reading metrics listener address")?,
            );
            listeners.push(ml);
        }
        // listener tokens are assigned 1..=n in `listeners` order (the
        // `Reactor::spawn` contract); the driver needs the metrics
        // token *before* spawn to classify accepts, so derive it from
        // the order above and assert the contract held afterwards
        let metrics_token: Option<Token> =
            metrics_addr.map(|_| listeners.len() as Token);
        // the handle only exists once the reactor is spawned, but the
        // driver (which spawns forwarder jobs needing it) is built
        // first — hand it over through a cell filled right after spawn
        let cell = Arc::new(OnceLock::new());
        let driver = NodeDriver {
            core: Arc::clone(&core),
            handle: Arc::clone(&cell),
            conns: HashMap::new(),
            stats_push: opts.stats_push,
            metrics_token,
            http: HashMap::new(),
        };
        let ropts = ReactorOpts {
            max_conns: opts.max_conns.max(1),
            ..ReactorOpts::default()
        };
        let (reactor, handle, ltokens) =
            Reactor::spawn(driver, listeners, ropts)
                .context("spawning node reactor")?;
        debug_assert_eq!(metrics_token,
                         metrics_token.and(ltokens.last().copied()));
        let _ = cell.set(handle.clone());
        Ok(NodeServer {
            shared: None,
            reactor: Some(ReactorPart {
                core,
                handle,
                reactor: Some(reactor),
            }),
            addr,
            metrics_addr,
            accept: None,
        })
    }

    /// The bound listen address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The bound `/metrics` address, when serving metrics.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    /// Force-close every live client connection *without* touching the
    /// wrapped service — from the frontend's point of view this node
    /// just fell off the network (fault injection for tests and the
    /// loopback bench; the service keeps draining whatever it already
    /// dispatched). The node still accepts new connections afterwards.
    pub fn sever_connections(&self) {
        if let Some(rp) = self.reactor.as_ref() {
            rp.handle.sever_all();
            return;
        }
        let Some(shared) = self.shared.as_ref() else { return };
        let streams: Vec<(usize, TcpStream)> = {
            let mut g = lock(&shared.streams);
            g.drain(..).collect()
        };
        for (_, s) in streams {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }

    /// Reactor mode: stop the loop (dropping every connection) and
    /// join its thread. Idempotent.
    fn stop_reactor(&mut self) {
        let Some(rp) = self.reactor.as_mut() else { return };
        rp.handle.stop();
        if let Some(r) = rp.reactor.take() {
            r.join();
        }
    }

    /// Stop the accept loop, close every connection and join the
    /// handler threads (idempotent; shared between shutdown and drop).
    fn stop_threads(&mut self) {
        let Some(shared) = self.shared.as_ref() else { return };
        shared.closing.store(true, Ordering::SeqCst);
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let streams: Vec<(usize, TcpStream)> = {
            let mut g = lock(&shared.streams);
            g.drain(..).collect()
        };
        for (_, s) in streams {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        let handles: Vec<JoinHandle<()>> = {
            let mut g = lock(&shared.conn_handles);
            g.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }

    /// Stop accepting, close every connection, drain the wrapped
    /// service and return its final statistics. Idempotent like
    /// `Cluster::teardown`: a node already shut down reports default
    /// stats instead of panicking.
    pub fn shutdown(mut self) -> ServerStats {
        self.stop_threads();
        self.stop_reactor();
        if let Some(rp) = self.reactor.take() {
            // same ordering as the threaded path: connections are down
            // (the reactor joined, its driver — the other NodeCore
            // reference — dropped with it), then the service drains;
            // dropping the pool last lets every in-flight forwarder
            // job resolve its answered channel
            return match Arc::try_unwrap(rp.core) {
                Ok(core) => {
                    let NodeCore { svc, pool } = core;
                    let stats = svc.shutdown();
                    drop(pool);
                    stats
                }
                Err(_) => {
                    warn_log!("node: the reactor outlived shutdown; \
                               stats unavailable");
                    ServerStats::default()
                }
            };
        }
        let Some(shared) = self.shared.take() else {
            return ServerStats::default();
        };
        // handler threads are joined, so ours is the last reference;
        // response forwarders never hold one
        match Arc::try_unwrap(shared) {
            Ok(sh) => {
                let stats = sh.svc.shutdown();
                // joins the forwarders: every queued reply job resolves
                // (the drained service answered every channel) and its
                // write fails fast on the closed sockets
                drop(sh.pool);
                stats
            }
            Err(_) => {
                warn_log!("node: a connection handler outlived shutdown; \
                           stats unavailable");
                ServerStats::default()
            }
        }
    }
}

impl Drop for NodeServer {
    /// A node dropped without `shutdown` still stops its threads (the
    /// wrapped service drains via its own drop).
    fn drop(&mut self) {
        self.stop_threads();
        self.stop_reactor();
    }
}

use crate::util::lock;

fn accept_loop(shared: Arc<NodeShared>, listener: TcpListener) {
    let mut next_conn = 0usize;
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                if shared.closing.load(Ordering::SeqCst) {
                    break; // the shutdown poke (or a raced client)
                }
                let _ = stream.set_nodelay(true);
                let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
                let conn_id = next_conn;
                next_conn += 1;
                if let Ok(clone) = stream.try_clone() {
                    lock(&shared.streams).push((conn_id, clone));
                }
                let conn_shared = Arc::clone(&shared);
                let name = format!("tqdit-net-conn-{conn_id}");
                match std::thread::Builder::new().name(name).spawn(
                    move || handle_conn(conn_shared, conn_id, stream,
                                        peer.to_string()),
                ) {
                    Ok(h) => {
                        let mut g = lock(&shared.conn_handles);
                        // reap handles of handlers that already
                        // returned (dropping a finished handle just
                        // detaches it) so a long-lived node doesn't
                        // grow a handle per connection it ever served
                        g.retain(|h| !h.is_finished());
                        g.push(h);
                    }
                    Err(e) => {
                        warn_log!("node: spawning handler for {peer} \
                                   failed: {e}");
                        // the spawn closure took the stream down with
                        // it; the registry clone still holds the
                        // socket, so refuse typed instead of letting
                        // the peer see a silent hangup
                        let cloned = {
                            let mut g = lock(&shared.streams);
                            g.iter()
                                .position(|(id, _)| *id == conn_id)
                                .map(|i| g.remove(i).1)
                        };
                        if let Some(mut s) = cloned {
                            let reject = Msg::Reject {
                                err: ServeError::Protocol {
                                    cause: format!(
                                        "node cannot serve this \
                                         connection: {e}"
                                    ),
                                },
                            };
                            let _ =
                                write_frame(&mut s, &reject.encode());
                            let _ = s
                                .shutdown(std::net::Shutdown::Both);
                        }
                    }
                }
            }
            Err(e) => {
                if shared.closing.load(Ordering::SeqCst) {
                    break;
                }
                warn_log!("node: accept failed: {e}");
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
    }
}

/// One connection's write half, driven through the layer-wide
/// [`send_message`](crate::serve::net::send_message) two-lock
/// discipline: the inline pong path never waits behind more than one
/// chunk of a large response.
struct ConnWriter {
    stream: Mutex<Option<TcpStream>>,
    bulk: Mutex<()>,
}

impl ConnWriter {
    /// Force-close the underlying socket (poisoned framing, conn exit).
    fn close(&self) {
        if let Some(s) = lock(&self.stream).take() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// Write one message under the connection's writer locks, at the
/// connection's negotiated wire feature level.
fn send(writer: &ConnWriter, msg: &Msg, wire: u16)
        -> Result<(), WireError> {
    crate::serve::net::send_message(&writer.stream, &writer.bulk,
                                    &msg.encode_at(wire))
}

/// One client connection: read frames, feed the service, answer
/// heartbeats/stats inline, hand responses to the forwarder pool.
/// On exit the socket is shut down explicitly (stream clones held by
/// in-flight forwarders or the registry would otherwise keep the
/// connection half-open) and the registry entry removed.
fn handle_conn(shared: Arc<NodeShared>, conn_id: usize,
               stream: TcpStream, peer: String) {
    let mut reader = match stream.try_clone() {
        Ok(r) => r,
        Err(e) => {
            warn_log!("node: cloning stream for {peer} failed: {e}");
            return;
        }
    };
    let writer = Arc::new(ConnWriter {
        stream: Mutex::new(Some(stream)),
        bulk: Mutex::new(()),
    });
    conn_loop(&shared, &writer, &mut reader, &peer);
    writer.close();
    lock(&shared.streams).retain(|(id, _)| *id != conn_id);
}

fn conn_loop(shared: &Arc<NodeShared>, writer: &Arc<ConnWriter>,
             reader: &mut TcpStream, peer: &str) {
    // untagged connections are data connections (raw clients,
    // pre-handshake frontends); a Hello can promote to control
    let mut role = Role::Data;
    // wire feature level, negotiated by the Hello (baseline = JSON)
    let mut wire = WIRE_VERSION;
    let mut messages = MessageReader::new();
    loop {
        let payload = match messages.read(reader) {
            Ok(p) => p,
            Err(WireError::Closed) => break,
            Err(e) => {
                if !shared.closing.load(Ordering::SeqCst) {
                    warn_log!("node: {peer}: closing connection: {e}");
                }
                break;
            }
        };
        // a bad *message* in a good frame degrades that message only:
        // framing is intact, so later frames on this connection are
        // still trustworthy
        let msg = match Msg::decode(&payload) {
            Ok(m) => m,
            Err(e) => {
                warn_log!("node: {peer}: skipping bad message: {e:#}");
                continue;
            }
        };
        match msg {
            Msg::Hello { role: tagged, max_wire } => {
                role = tagged;
                wire = max_wire.min(WIRE_TRACE);
                debug_log!("node: {peer}: connection tagged {} \
                            (wire {wire})", tagged.name());
                if max_wire > WIRE_VERSION {
                    // confirm the negotiated level (baseline peers
                    // never advertised, so they never see the ack)
                    let ack = Msg::HelloAck { wire };
                    if send(writer, &ack, WIRE_VERSION).is_err() {
                        break;
                    }
                }
            }
            Msg::Submit { id, .. } if role == Role::Control => {
                // control connections carry liveness only; shipping a
                // response over one would re-create the pong-behind-
                // frame wedge the split exists to prevent
                warn_log!("node: {peer}: submit on a control \
                           connection rejected");
                let err = ServeError::Protocol {
                    cause: "submit on a control connection".into(),
                };
                if send(writer, &Msg::ErrorResp { id, err }, wire)
                    .is_err()
                {
                    break;
                }
            }
            Msg::Submit { id, class, n, trace } => {
                // honor the trace only on a wire that negotiated it:
                // an old frontend never sends one, and a skewed peer's
                // ids (which it could not correlate) degrade to NONE
                let trace = if wire >= WIRE_TRACE {
                    trace
                } else {
                    TraceCtx::NONE
                };
                match shared.svc
                    .submit_traced(GenRequest { class, n }, trace)
                {
                    Ok((_, rx)) => {
                        let w = Arc::clone(writer);
                        // the job blocks on this one request's channel;
                        // a pool worker is busy for exactly as long as
                        // the request is in flight
                        shared.pool.execute(move || {
                            let reply = match rx.recv() {
                                Ok(Ok(resp)) => Msg::Response {
                                    id,
                                    latency_s: resp.latency_s,
                                    images: resp.images,
                                    // ship this request's spans home so
                                    // the frontend stitches one timeline
                                    spans: if trace.is_active() {
                                        trace::spans_for_trace(
                                            trace.trace,
                                        )
                                    } else {
                                        Vec::new()
                                    },
                                },
                                Ok(Err(err)) => Msg::ErrorResp { id, err },
                                Err(_) => Msg::ErrorResp {
                                    id,
                                    err: ServeError::Protocol {
                                        cause: "response channel closed \
                                                without a result"
                                            .into(),
                                    },
                                },
                            };
                            if let Err(e) = send(&w, &reply, wire) {
                                debug_log!("node: reply for request {id} \
                                            dropped: {e}");
                                // a failed (possibly partial) frame or
                                // chunk-run write poisons the stream
                                // framing — close so the peer
                                // re-routes instead of reading garbage
                                w.close();
                            }
                        });
                    }
                    Err(err) => {
                        // a rejected submit (backpressure, shutdown)
                        // answers immediately with the typed cause
                        if send(writer, &Msg::ErrorResp { id, err },
                                wire)
                            .is_err()
                        {
                            break;
                        }
                    }
                }
            }
            Msg::Ping { seq } => {
                let pong = Msg::Pong {
                    seq,
                    queue_depth: shared.svc.queue_depth(),
                    live_workers: shared.svc.live_workers(),
                    ready_workers: shared.svc.ready_workers(),
                };
                if send(writer, &pong, wire).is_err() {
                    break;
                }
            }
            Msg::StatsReq { seq } => {
                let stats = shared.svc.stats();
                if send(writer, &Msg::Stats { seq, stats }, wire)
                    .is_err()
                {
                    break;
                }
            }
            other => {
                // node-bound traffic only; a frontend-bound message
                // arriving here is a peer bug, not a reason to die
                warn_log!("node: {peer}: skipping unexpected {} message",
                          other.kind());
            }
        }
    }
}

// ---------------------------------------------------------------------
// Reactor mode

/// Per-connection state the reactor driver tracks (all mutated on the
/// reactor thread — no locks).
struct ConnState {
    peer: SocketAddr,
    role: Role,
    /// Negotiated wire feature level for node → peer traffic.
    wire: u16,
    /// Counter values at the last `StatsDelta` push (control conns);
    /// zero until the first push, which therefore carries the full
    /// cumulative value — the `StatsDelta` contract.
    pushed: ServerStats,
}

/// Counter increments since `prev`; gauges (`pending`, fills, depths,
/// latency quantiles, wall clock) and the rung/worker breakdowns stay
/// absolute. The latency *histogram* travels as a per-bucket
/// increment, so the frontend's merged histogram reconstructs the
/// node's exactly — bucket counts are counters like any other.
/// Summing deltas per connection reconstructs the node's cumulative
/// counters, conservation identity included
/// (`Σenqueued = Σdispatched + Σpurged + pending_now`).
fn stats_delta(prev: &ServerStats, cur: &ServerStats) -> ServerStats {
    let mut d = cur.clone();
    d.latency = cur.latency.delta_since(&prev.latency);
    d.requests = cur.requests.saturating_sub(prev.requests);
    d.images = cur.images.saturating_sub(prev.images);
    d.batches = cur.batches.saturating_sub(prev.batches);
    d.padded_slots = cur.padded_slots.saturating_sub(prev.padded_slots);
    d.failed_requests =
        cur.failed_requests.saturating_sub(prev.failed_requests);
    d.dropped_responses =
        cur.dropped_responses.saturating_sub(prev.dropped_responses);
    d.calib_cache_hits =
        cur.calib_cache_hits.saturating_sub(prev.calib_cache_hits);
    d.calib_cache_misses =
        cur.calib_cache_misses.saturating_sub(prev.calib_cache_misses);
    d.enqueued = cur.enqueued.saturating_sub(prev.enqueued);
    d.dispatched = cur.dispatched.saturating_sub(prev.dispatched);
    d.purged = cur.purged.saturating_sub(prev.purged);
    d.requeued = cur.requeued.saturating_sub(prev.requeued);
    d.nodes_lost = cur.nodes_lost.saturating_sub(prev.nodes_lost);
    d.nodes_readmitted =
        cur.nodes_readmitted.saturating_sub(prev.nodes_readmitted);
    d.reuse_hits = cur.reuse_hits.saturating_sub(prev.reuse_hits);
    d.steps_skipped =
        cur.steps_skipped.saturating_sub(prev.steps_skipped);
    d.uploads_saved =
        cur.uploads_saved.saturating_sub(prev.uploads_saved);
    d
}

/// Block (briefly) until `start_reactor` has filled the handle cell —
/// only ever awaited on forwarder-pool threads, and the fill races at
/// most the first connection's first completed request.
fn wait_handle(cell: &OnceLock<Handle<SocketAddr>>)
               -> Handle<SocketAddr> {
    loop {
        if let Some(h) = cell.get() {
            return h.clone();
        }
        std::thread::yield_now();
    }
}

/// The node's [`Driver`]: `conn_loop` re-expressed as reactor
/// callbacks. Inline answers (pong, typed errors, hello ack) ride the
/// ctrl-priority lane; responses ride bulk via the forwarder pool.
struct NodeDriver {
    core: Arc<NodeCore>,
    handle: Arc<OnceLock<Handle<SocketAddr>>>,
    conns: HashMap<Token, ConnState>,
    stats_push: Duration,
    /// Listener token of the raw-HTTP `/metrics` listener, if bound.
    metrics_token: Option<Token>,
    /// Request-head bytes accumulated per raw metrics connection.
    http: HashMap<Token, Vec<u8>>,
}

/// Longest request head a `/metrics` scraper may send before the
/// connection is dropped as garbage.
const MAX_HTTP_HEAD: usize = 16 << 10;

impl Driver for NodeDriver {
    type Tag = SocketAddr;

    fn accept_tag(&mut self, _listener: Token, peer: SocketAddr)
                  -> SocketAddr {
        peer
    }

    fn conn_class(&mut self, listener: Token) -> ConnClass {
        if Some(listener) == self.metrics_token {
            ConnClass::Raw
        } else {
            ConnClass::Framed
        }
    }

    fn on_raw(&mut self, ctl: &mut Ctl<'_>, token: Token,
              chunk: &[u8]) {
        let buf = self.http.entry(token).or_default();
        buf.extend_from_slice(chunk);
        if !metrics::http_request_complete(buf) {
            if buf.len() > MAX_HTTP_HEAD {
                self.http.remove(&token);
                ctl.close(token);
            }
            return;
        }
        let buf = self.http.remove(&token).unwrap_or_default();
        let path = metrics::http_request_path(&buf);
        // a node scrape has no shard table — it *is* the shard
        let body =
            metrics::render_prometheus(&self.core.svc.stats(), &[]);
        let resp = metrics::respond(path.as_deref(), &body);
        if ctl.send_raw(token, &resp).is_ok() {
            ctl.close_after_flush(token);
        }
    }

    fn on_open(&mut self, _ctl: &mut Ctl<'_>, token: Token,
               peer: SocketAddr) {
        self.conns.insert(token, ConnState {
            peer,
            role: Role::Data,
            wire: WIRE_VERSION,
            pushed: ServerStats::default(),
        });
    }

    fn on_message(&mut self, ctl: &mut Ctl<'_>, token: Token,
                  payload: Vec<u8>) {
        // a bad *message* in a good frame degrades that message only,
        // same as the threaded path
        let msg = match Msg::decode(&payload) {
            Ok(m) => m,
            Err(e) => {
                warn_log!("node: skipping bad message: {e:#}");
                return;
            }
        };
        let Some(st) = self.conns.get_mut(&token) else { return };
        match msg {
            Msg::Hello { role, max_wire } => {
                st.role = role;
                st.wire = max_wire.min(WIRE_TRACE);
                let wire = st.wire;
                debug_log!("node: {}: connection tagged {} \
                            (wire {wire})", st.peer, role.name());
                if max_wire > WIRE_VERSION {
                    let ack = Msg::HelloAck { wire }.encode();
                    if ctl.send_ctrl(token, &ack).is_err() {
                        self.conns.remove(&token);
                        return;
                    }
                }
                if role == Role::Control {
                    // start this connection's stats-push cadence; the
                    // timer key is the token (unique forever, so a
                    // fired key for a gone connection is inert)
                    ctl.set_timer(ctl.now() + self.stats_push, token);
                }
            }
            Msg::Submit { id, .. } if st.role == Role::Control => {
                warn_log!("node: {}: submit on a control connection \
                           rejected", st.peer);
                let err = ServeError::Protocol {
                    cause: "submit on a control connection".into(),
                };
                let resp = Msg::ErrorResp { id, err }.encode();
                if ctl.send_ctrl(token, &resp).is_err() {
                    self.conns.remove(&token);
                }
            }
            Msg::Submit { id, class, n, trace } => {
                let wire = st.wire;
                // same trace-only-when-negotiated rule as the
                // threaded path
                let trace = if wire >= WIRE_TRACE {
                    trace
                } else {
                    TraceCtx::NONE
                };
                match self.core.svc
                    .submit_traced(GenRequest { class, n }, trace)
                {
                    Ok((_, rx)) => {
                        let cell = Arc::clone(&self.handle);
                        // same shape as the threaded forwarder: the
                        // job blocks on this one request's channel,
                        // then re-enters the loop through the handle
                        self.core.pool.execute(move || {
                            let reply = match rx.recv() {
                                Ok(Ok(resp)) => Msg::Response {
                                    id,
                                    latency_s: resp.latency_s,
                                    images: resp.images,
                                    spans: if trace.is_active() {
                                        trace::spans_for_trace(
                                            trace.trace,
                                        )
                                    } else {
                                        Vec::new()
                                    },
                                },
                                Ok(Err(err)) => {
                                    Msg::ErrorResp { id, err }
                                }
                                Err(_) => Msg::ErrorResp {
                                    id,
                                    err: ServeError::Protocol {
                                        cause: "response channel \
                                                closed without a \
                                                result"
                                            .into(),
                                    },
                                },
                            };
                            let handle = wait_handle(&cell);
                            if !handle.send(token,
                                            reply.encode_at(wire)) {
                                debug_log!("node: reply for request \
                                            {id} dropped: reactor \
                                            stopped");
                            }
                        });
                    }
                    Err(err) => {
                        let resp = Msg::ErrorResp { id, err }.encode();
                        if ctl.send_ctrl(token, &resp).is_err() {
                            self.conns.remove(&token);
                        }
                    }
                }
            }
            Msg::Ping { seq } => {
                let pong = Msg::Pong {
                    seq,
                    queue_depth: self.core.svc.queue_depth(),
                    live_workers: self.core.svc.live_workers(),
                    ready_workers: self.core.svc.ready_workers(),
                };
                if ctl.send_ctrl(token, &pong.encode()).is_err() {
                    self.conns.remove(&token);
                }
            }
            Msg::StatsReq { seq } => {
                let stats = self.core.svc.stats();
                if st.role == Role::Control {
                    // a full snapshot re-baselines the delta stream:
                    // the peer replaces its accumulated value with
                    // this snapshot, so every later delta must be
                    // relative to it or the fold double-counts
                    st.pushed = stats.clone();
                }
                let resp = Msg::Stats { seq, stats }.encode();
                if ctl.send(token, &resp).is_err() {
                    self.conns.remove(&token);
                }
            }
            other => {
                warn_log!("node: {}: skipping unexpected {} message",
                          st.peer, other.kind());
            }
        }
    }

    fn on_close(&mut self, _ctl: &mut Ctl<'_>, token: Token,
                cause: WireError) {
        self.http.remove(&token);
        if let Some(st) = self.conns.remove(&token) {
            match cause {
                WireError::Closed => {
                    debug_log!("node: {}: connection closed", st.peer);
                }
                e => {
                    warn_log!("node: {}: closing connection: {e}",
                              st.peer);
                }
            }
        }
    }

    fn on_timer(&mut self, ctl: &mut Ctl<'_>, key: u64) {
        // timer keys are connection tokens (stats-push cadence); a
        // key whose connection is gone was lazily cancelled
        let Some(st) = self.conns.get_mut(&key) else { return };
        if st.role != Role::Control {
            return;
        }
        let cur = self.core.svc.stats();
        let delta = stats_delta(&st.pushed, &cur);
        st.pushed = cur;
        let push = Msg::StatsDelta { stats: delta }.encode();
        if ctl.send_ctrl(key, &push).is_err() {
            self.conns.remove(&key);
            return;
        }
        ctl.set_timer(ctl.now() + self.stats_push, key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::net::proto::WIRE_BINARY;
    use crate::serve::net::testutil::{
        mock_node, mock_node_opts, read_msg, send_msg,
    };
    use crate::serve::net::wire::{read_frame, write_frame, CHUNK_LEN};
    use std::time::Duration;

    /// Read frames until `pred` matches (heartbeat replies may
    /// interleave with responses on a live connection).
    fn read_until<F: Fn(&Msg) -> bool>(stream: &mut TcpStream, pred: F)
                                       -> Msg {
        loop {
            let msg = read_msg(stream);
            if pred(&msg) {
                return msg;
            }
        }
    }

    /// An untraced submit — the common case in these tests.
    fn submit(id: u64, class: i32, n: usize) -> Msg {
        Msg::Submit { id, class, n, trace: TraceCtx::NONE }
    }

    #[test]
    fn node_serves_submit_ping_stats_over_one_socket() {
        let (node, addr) = mock_node(vec![4], 3, Duration::ZERO);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

        send_msg(&mut c, &submit(42, 5, 2));
        send_msg(&mut c, &Msg::Ping { seq: 9 });

        // ping answered inline; the response forwarded when computed —
        // order between them is not part of the contract
        let pong = read_until(&mut c, |m| matches!(m, Msg::Pong { .. }));
        match pong {
            Msg::Pong { seq: 9, .. } => {}
            other => panic!("wrong pong: {other:?}"),
        }
        let resp =
            read_until(&mut c, |m| matches!(m, Msg::Response { .. }));
        match resp {
            Msg::Response { id: 42, images, .. } => {
                assert_eq!(images.len(), 2 * 3);
                assert!(images.iter().all(|&p| p == 5.0));
            }
            other => panic!("wrong response: {other:?}"),
        }

        send_msg(&mut c, &Msg::StatsReq { seq: 1 });
        let stats = read_until(&mut c, |m| matches!(m, Msg::Stats { .. }));
        match stats {
            Msg::Stats { seq: 1, stats } => {
                assert_eq!(stats.requests, 1);
                assert_eq!(stats.enqueued,
                           stats.dispatched + stats.purged + stats.pending);
            }
            other => panic!("wrong stats: {other:?}"),
        }

        let final_stats = node.shutdown();
        assert_eq!(final_stats.requests, 1);
        assert_eq!(final_stats.images, 2);
    }

    #[test]
    fn concurrent_connections_share_one_service() {
        let (node, addr) = mock_node(vec![8], 2, Duration::ZERO);
        std::thread::scope(|s| {
            for client in 0..3i32 {
                s.spawn(move || {
                    let mut c = TcpStream::connect(addr).unwrap();
                    c.set_read_timeout(Some(Duration::from_secs(10)))
                        .unwrap();
                    for i in 0..4u64 {
                        let class = client + 1;
                        send_msg(&mut c, &submit(i, class, 3));
                        match read_until(&mut c,
                                         |m| matches!(m,
                                                      Msg::Response { .. }
                                                      | Msg::ErrorResp {
                                                          ..
                                                      })) {
                            Msg::Response { id, images, .. } => {
                                assert_eq!(id, i);
                                assert!(
                                    images.iter().all(|&p| p
                                                      == class as f32),
                                    "cross-connection pixel mixup"
                                );
                            }
                            other => panic!("request failed: {other:?}"),
                        }
                    }
                });
            }
        });
        let stats = node.shutdown();
        assert_eq!(stats.requests, 12);
        assert_eq!(stats.images, 36);
        assert_eq!(stats.failed_requests, 0);
    }

    #[test]
    fn bad_message_in_good_frame_is_skipped_connection_lives() {
        let (node, addr) = mock_node(vec![2], 2, Duration::ZERO);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // valid frame, garbage JSON — the node must skip it
        write_frame(&mut c, b"{ not json").unwrap();
        // and a well-formed submit on the same connection still works
        send_msg(&mut c, &submit(1, 3, 1));
        match read_until(&mut c, |m| matches!(m, Msg::Response { .. })) {
            Msg::Response { id: 1, images, .. } => {
                assert_eq!(images, vec![3.0, 3.0]);
            }
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn wire_garbage_closes_only_that_connection() {
        let (node, addr) = mock_node(vec![2], 2, Duration::ZERO);
        {
            use std::io::Write;
            let mut bad = TcpStream::connect(addr).unwrap();
            bad.write_all(b"XXXXXXXX not a frame XXXXXXXX").unwrap();
            bad.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            // the node closes the broken connection
            match read_frame(&mut bad) {
                Err(WireError::Closed) | Err(WireError::Io(_))
                | Err(WireError::Truncated { .. }) => {}
                other => panic!("expected a closed stream, got {other:?}"),
            }
        }
        // a fresh connection is unaffected
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c, &submit(2, 1, 1));
        match read_until(&mut c, |m| matches!(m, Msg::Response { .. })) {
            Msg::Response { id: 2, .. } => {}
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn rejected_submit_relays_the_typed_cause() {
        // queue cap 4: a 5-slot request can never fit
        let (node, addr) =
            crate::serve::net::testutil::mock_node_capped(
                vec![2], 2, Duration::ZERO, 4);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c, &submit(7, 1, 5));
        match read_until(&mut c, |m| matches!(m, Msg::ErrorResp { .. })) {
            Msg::ErrorResp {
                id: 7,
                err: ServeError::RequestTooLarge { n: 5, cap: 4 },
            } => {}
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn control_connection_serves_liveness_and_rejects_submits() {
        let (node, addr) = mock_node(vec![4], 3, Duration::ZERO);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c, &Msg::Hello {
            role: Role::Control,
            max_wire: WIRE_VERSION,
        });
        // liveness + stats flow normally
        send_msg(&mut c, &Msg::Ping { seq: 5 });
        match read_until(&mut c, |m| matches!(m, Msg::Pong { .. })) {
            Msg::Pong { seq: 5, .. } => {}
            other => panic!("wrong pong: {other:?}"),
        }
        send_msg(&mut c, &Msg::StatsReq { seq: 1 });
        read_until(&mut c, |m| matches!(m, Msg::Stats { .. }));
        // but a submit is a peer bug: rejected typed, connection lives
        send_msg(&mut c, &submit(9, 1, 1));
        match read_until(&mut c, |m| matches!(m, Msg::ErrorResp { .. })) {
            Msg::ErrorResp { id: 9, err: ServeError::Protocol { .. } } => {}
            other => panic!("{other:?}"),
        }
        send_msg(&mut c, &Msg::Ping { seq: 6 });
        read_until(&mut c, |m| matches!(m, Msg::Pong { seq: 6, .. }));
        let stats = node.shutdown();
        assert_eq!(stats.requests, 0, "control traffic reached the \
                                       service");
    }

    #[test]
    fn large_response_travels_chunked_and_reassembles() {
        // ~400 KiB of response JSON (> CHUNK_LEN) exercises the
        // chunked write path + reader-side reassembly end to end
        let il = 100_000usize;
        let (node, addr) = mock_node(vec![2], il, Duration::ZERO);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        send_msg(&mut c, &submit(3, 7, 2));
        match read_until(&mut c, |m| matches!(m, Msg::Response { .. })) {
            Msg::Response { id: 3, images, .. } => {
                assert_eq!(images.len(), 2 * il);
                assert!(images.iter().all(|&p| p == 7.0));
                // the point of the fixture: this really was chunked
                assert!(images.len() * 2 > CHUNK_LEN,
                        "fixture no longer exceeds one chunk");
            }
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn severed_connection_leaves_the_service_running() {
        let (node, addr) = mock_node(vec![2], 2, Duration::ZERO);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c, &Msg::Ping { seq: 1 });
        read_until(&mut c, |m| matches!(m, Msg::Pong { .. }));
        node.sever_connections();
        // our side observes the close
        match read_frame(&mut c) {
            Err(_) => {}
            Ok(_) => panic!("severed connection still delivered"),
        }
        // the node accepts and serves new connections afterwards
        let mut c2 = TcpStream::connect(addr).unwrap();
        c2.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c2, &submit(1, 2, 1));
        match read_until(&mut c2, |m| matches!(m, Msg::Response { .. })) {
            Msg::Response { id: 1, images, .. } => {
                assert_eq!(images, vec![2.0, 2.0]);
            }
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    // -- reactor mode --------------------------------------------------

    fn reactor_opts() -> NodeOpts {
        NodeOpts {
            reactor: true,
            stats_push: Duration::from_millis(40),
            ..NodeOpts::default()
        }
    }

    #[test]
    fn reactor_node_serves_submit_ping_stats_over_one_socket() {
        let (node, addr) =
            mock_node_opts(vec![4], 3, Duration::ZERO, reactor_opts());
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

        send_msg(&mut c, &submit(42, 5, 2));
        send_msg(&mut c, &Msg::Ping { seq: 9 });
        match read_until(&mut c, |m| matches!(m, Msg::Pong { .. })) {
            Msg::Pong { seq: 9, .. } => {}
            other => panic!("wrong pong: {other:?}"),
        }
        match read_until(&mut c, |m| matches!(m, Msg::Response { .. }))
        {
            Msg::Response { id: 42, images, .. } => {
                assert_eq!(images.len(), 2 * 3);
                assert!(images.iter().all(|&p| p == 5.0));
            }
            other => panic!("wrong response: {other:?}"),
        }
        send_msg(&mut c, &Msg::StatsReq { seq: 1 });
        match read_until(&mut c, |m| matches!(m, Msg::Stats { .. })) {
            Msg::Stats { seq: 1, stats } => {
                assert_eq!(stats.requests, 1);
                assert_eq!(stats.enqueued,
                           stats.dispatched + stats.purged
                               + stats.pending);
            }
            other => panic!("wrong stats: {other:?}"),
        }
        let final_stats = node.shutdown();
        assert_eq!(final_stats.requests, 1);
        assert_eq!(final_stats.images, 2);
    }

    #[test]
    fn reactor_node_negotiates_binary_responses() {
        let (node, addr) =
            mock_node_opts(vec![4], 3, Duration::ZERO, reactor_opts());
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c, &Msg::Hello {
            role: Role::Data,
            max_wire: WIRE_BINARY,
        });
        match read_msg(&mut c) {
            Msg::HelloAck { wire } => assert_eq!(wire, WIRE_BINARY),
            other => panic!("expected hello ack, got {other:?}"),
        }
        send_msg(&mut c, &submit(5, 3, 2));
        // the response payload must really be binary (marker byte),
        // not merely decodable
        let payload = loop {
            let p = read_frame(&mut c).unwrap();
            if p.first() == Some(&0u8) {
                break p;
            }
            // skip interleaved JSON control traffic, if any
            Msg::decode(&p).unwrap();
        };
        match Msg::decode(&payload).unwrap() {
            Msg::Response { id: 5, images, .. } => {
                assert_eq!(images.len(), 2 * 3);
                assert!(images.iter().all(|&p| p == 3.0));
            }
            other => panic!("{other:?}"),
        }
        // control traffic stays JSON at every feature level
        send_msg(&mut c, &Msg::Ping { seq: 1 });
        let p = read_frame(&mut c).unwrap();
        assert_eq!(p.first(), Some(&b'{'), "pong went binary");
        match Msg::decode(&p).unwrap() {
            Msg::Pong { seq: 1, .. } => {}
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn reactor_control_connection_pushes_stats_deltas() {
        let (node, addr) =
            mock_node_opts(vec![4], 2, Duration::ZERO, reactor_opts());
        let mut ctl = TcpStream::connect(addr).unwrap();
        ctl.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut ctl, &Msg::Hello {
            role: Role::Control,
            max_wire: WIRE_VERSION,
        });
        // a submit on the control plane is a peer bug, typed
        send_msg(&mut ctl, &submit(9, 1, 1));
        match read_until(&mut ctl,
                         |m| matches!(m, Msg::ErrorResp { .. })) {
            Msg::ErrorResp {
                id: 9,
                err: ServeError::Protocol { .. },
            } => {}
            other => panic!("{other:?}"),
        }
        // real work flows on a data connection
        let mut data = TcpStream::connect(addr).unwrap();
        data.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        for id in 0..2u64 {
            send_msg(&mut data, &submit(id, 4, 2));
            read_until(&mut data,
                       |m| matches!(m, Msg::Response { .. }));
        }
        // deltas arrive unprompted (no StatsReq was ever sent on this
        // connection) and sum to the cumulative counters
        let (mut req_sum, mut enq_sum, mut dis_sum, mut pur_sum) =
            (0u64, 0u64, 0u64, 0u64);
        let mut pending = 0u64;
        loop {
            match read_until(&mut ctl,
                             |m| matches!(m, Msg::StatsDelta { .. })) {
                Msg::StatsDelta { stats } => {
                    req_sum += stats.requests;
                    enq_sum += stats.enqueued;
                    dis_sum += stats.dispatched;
                    pur_sum += stats.purged;
                    pending = stats.pending; // gauge: absolute
                    if req_sum >= 2 {
                        break;
                    }
                }
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(req_sum, 2, "delta sum over-counts");
        assert_eq!(enq_sum, dis_sum + pur_sum + pending,
                   "conservation identity lost in delta form");
        let stats = node.shutdown();
        assert_eq!(stats.requests, 2);
    }

    #[test]
    fn reactor_large_response_travels_chunked() {
        // baseline (JSON) path: a multi-chunk response through the
        // reactor's bulk outbox lane, reassembled by the client
        let il = 100_000usize;
        let (node, addr) =
            mock_node_opts(vec![2], il, Duration::ZERO, reactor_opts());
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        send_msg(&mut c, &submit(3, 7, 2));
        match read_until(&mut c, |m| matches!(m, Msg::Response { .. }))
        {
            Msg::Response { id: 3, images, .. } => {
                assert_eq!(images.len(), 2 * il);
                assert!(images.iter().all(|&p| p == 7.0));
                assert!(images.len() * 2 > CHUNK_LEN,
                        "fixture no longer exceeds one chunk");
            }
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn reactor_node_ships_spans_home_on_a_trace_wire() {
        trace::set_enabled(true);
        let (node, addr) =
            mock_node_opts(vec![4], 2, Duration::ZERO, reactor_opts());
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c, &Msg::Hello {
            role: Role::Data,
            max_wire: WIRE_TRACE,
        });
        match read_msg(&mut c) {
            Msg::HelloAck { wire } => assert_eq!(wire, WIRE_TRACE),
            other => panic!("expected hello ack, got {other:?}"),
        }
        // the ids a frontend would mint: its trace + dispatch span
        let ctx = TraceCtx {
            trace: trace::next_id(),
            span: trace::next_id(),
        };
        send_msg(&mut c,
                 &Msg::Submit { id: 6, class: 2, n: 1, trace: ctx });
        match read_until(&mut c, |m| matches!(m, Msg::Response { .. }))
        {
            Msg::Response { id: 6, images, spans, .. } => {
                assert_eq!(images, vec![2.0, 2.0]);
                // a traced response stays JSON and carries the node's
                // spans for exactly this trace, rooted under the
                // frontend's dispatch span
                assert!(!spans.is_empty(), "no spans came home");
                assert!(spans.iter().all(|s| s.trace == ctx.trace));
                let root = spans
                    .iter()
                    .find(|s| s.parent == ctx.span)
                    .expect("request root under the dispatch span");
                assert_eq!(root.kind,
                           crate::obs::trace::SpanKind::Request);
            }
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn trace_ids_degrade_gracefully_below_the_trace_wire() {
        trace::set_enabled(true);
        let (node, addr) =
            mock_node_opts(vec![4], 2, Duration::ZERO, reactor_opts());
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // no Hello: the connection stays at the baseline wire, so the
        // trace ids in the submit must be ignored, not half-honored
        let ctx = TraceCtx {
            trace: trace::next_id(),
            span: trace::next_id(),
        };
        send_msg(&mut c,
                 &Msg::Submit { id: 4, class: 3, n: 1, trace: ctx });
        match read_until(&mut c, |m| matches!(m, Msg::Response { .. }))
        {
            Msg::Response { id: 4, images, spans, .. } => {
                assert_eq!(images, vec![3.0, 3.0]);
                assert!(spans.is_empty(), "spans on a baseline wire");
            }
            other => panic!("{other:?}"),
        }
        assert!(trace::spans_for_trace(ctx.trace).is_empty(),
                "a baseline-wire submit must not record server spans");
        node.shutdown();
    }

    #[test]
    fn reactor_node_serves_prometheus_metrics_over_raw_http() {
        use std::io::{Read as _, Write as _};
        let mut opts = reactor_opts();
        opts.metrics_addr = Some("127.0.0.1:0".parse().unwrap());
        let (node, addr) =
            mock_node_opts(vec![4], 2, Duration::ZERO, opts);
        let maddr =
            node.metrics_addr().expect("metrics listener bound");
        // drive traffic so the scrape shows live counters
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        for id in 0..3u64 {
            send_msg(&mut c, &submit(id, 2, 2));
            read_until(&mut c, |m| matches!(m, Msg::Response { .. }));
        }
        let mut h = TcpStream::connect(maddr).unwrap();
        h.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        h.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n")
            .unwrap();
        let mut text = String::new();
        h.read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        let body = text.split("\r\n\r\n").nth(1).unwrap_or("");
        let series = metrics::parse_exposition(body);
        assert_eq!(series.get("tqdit_requests_total"), Some(&3.0));
        assert_eq!(series.get("tqdit_images_total"), Some(&6.0));
        assert_eq!(
            series.get("tqdit_request_latency_seconds_count"),
            Some(&3.0)
        );
        // the scrape did not disturb the data plane
        send_msg(&mut c, &submit(9, 1, 1));
        read_until(&mut c, |m| matches!(m, Msg::Response { .. }));
        node.shutdown();
    }

    #[test]
    fn reactor_severed_connection_leaves_the_service_running() {
        let (node, addr) =
            mock_node_opts(vec![2], 2, Duration::ZERO, reactor_opts());
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c, &Msg::Ping { seq: 1 });
        read_until(&mut c, |m| matches!(m, Msg::Pong { .. }));
        node.sever_connections();
        match read_frame(&mut c) {
            Err(_) => {}
            Ok(_) => panic!("severed connection still delivered"),
        }
        let mut c2 = TcpStream::connect(addr).unwrap();
        c2.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c2, &submit(1, 2, 1));
        match read_until(&mut c2,
                         |m| matches!(m, Msg::Response { .. })) {
            Msg::Response { id: 1, images, .. } => {
                assert_eq!(images, vec![2.0, 2.0]);
            }
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }
}
