//! Shard-node server: any [`Dispatch`] service behind a TCP listener.
//!
//! A node wraps the in-process serve stack (normally a
//! [`GenServer`](crate::serve::GenServer), a mock router in tests) and
//! speaks the [`proto`](crate::serve::net::proto) message set over
//! [`wire`](crate::serve::net::wire) frames:
//!
//! * one **accept thread** takes connections;
//! * one **connection-handler thread per client** reads frames and
//!   multiplexes `Submit`s straight into the shared service (whose
//!   batcher then packs slots from *all* connections into rungs, same
//!   as local threads would) — `Ping` and `StatsReq` are answered
//!   inline so heartbeats stay prompt under load;
//! * completed responses are forwarded by a small fixed
//!   [`ThreadPool`]: each job blocks on one request's response channel
//!   and writes the reply under the connection's writer locks (frames
//!   from concurrent requests interleave whole, never torn).
//!
//! **Control-plane isolation:** a frontend may tag a connection
//! `Hello{role: control}` — the node then expects only ping/stats
//! traffic on it (a submit is rejected typed), and since no response
//! bytes ever travel that connection, a pong cannot queue behind a
//! multi-MiB frame. On *data* connections the same liveness problem is
//! bounded by chunking: responses larger than [`wire::CHUNK_LEN`] are
//! written as chunk runs, the frame lock released between chunks (a
//! per-connection bulk lock keeps different messages' chunks from
//! interleaving), so an inline pong waits behind at most one chunk —
//! not one response.
//!
//! Failure containment mirrors the router's ethos: a malformed
//! *message* (valid frame, bad JSON) is logged and skipped — the
//! connection lives on; a broken *frame stream* closes only that
//! connection; a client hanging up drops only its own replies. The
//! node never panics on peer bytes.
//!
//! Writes carry a timeout so a peer that stops *reading* fails typed
//! instead of wedging the writer locks. [`NodeServer::sever_connections`]
//! force-closes every live connection without touching the service —
//! the fault injection the cluster tests and the loopback bench use to
//! simulate a network partition.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::serve::dispatch::Dispatch;
use crate::serve::error::ServeError;
use crate::serve::net::proto::{Msg, Role};
use crate::serve::net::wire::{MessageReader, WireError};
use crate::serve::router::{GenRequest, ServerStats};
use crate::util::threadpool::ThreadPool;
use crate::{debug_log, warn_log};

/// Node tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct NodeOpts {
    /// Response-forwarder pool size: how many completed requests can
    /// be serialized back to clients concurrently.
    pub forwarders: usize,
}

impl Default for NodeOpts {
    fn default() -> Self {
        NodeOpts { forwarders: 8 }
    }
}

/// A client that stops *reading* must fail our writes with a typed
/// error after this long instead of blocking the connection's writer
/// mutex forever (which would also block the inline pong path).
const WRITE_TIMEOUT: std::time::Duration =
    std::time::Duration::from_secs(30);

struct NodeShared {
    svc: Box<dyn Dispatch>,
    pool: ThreadPool,
    /// `(conn id, stream clone)` for every live connection, kept so
    /// shutdown (and fault injection) can force-close them and unblock
    /// the readers. Handlers remove their own entry on exit.
    streams: Mutex<Vec<(usize, TcpStream)>>,
    /// Handles of the connection-handler threads (appended by the
    /// accept thread, drained by shutdown).
    conn_handles: Mutex<Vec<JoinHandle<()>>>,
    closing: AtomicBool,
}

/// A serving shard node; dropped or [`NodeServer::shutdown`] stops it.
pub struct NodeServer {
    /// `None` only after `shutdown` consumed it (the `Drop` impl
    /// forces fields behind options).
    shared: Option<Arc<NodeShared>>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl NodeServer {
    /// Bind `listen` (e.g. `127.0.0.1:7070`; port 0 picks a free one —
    /// read it back from [`NodeServer::addr`]) and serve `svc` until
    /// shutdown.
    pub fn start(svc: Box<dyn Dispatch>, listen: &str,
                 opts: NodeOpts) -> Result<NodeServer> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding node listener {listen}"))?;
        let addr = listener
            .local_addr()
            .context("reading node listener address")?;
        let shared = Arc::new(NodeShared {
            svc,
            pool: ThreadPool::new(opts.forwarders.max(1)),
            streams: Mutex::new(Vec::new()),
            conn_handles: Mutex::new(Vec::new()),
            closing: AtomicBool::new(false),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("tqdit-net-accept".into())
            .spawn(move || accept_loop(accept_shared, listener))
            .context("spawning node accept thread")?;
        Ok(NodeServer {
            shared: Some(shared),
            addr,
            accept: Some(accept),
        })
    }

    /// The bound listen address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Force-close every live client connection *without* touching the
    /// wrapped service — from the frontend's point of view this node
    /// just fell off the network (fault injection for tests and the
    /// loopback bench; the service keeps draining whatever it already
    /// dispatched). The node still accepts new connections afterwards.
    pub fn sever_connections(&self) {
        let Some(shared) = self.shared.as_ref() else { return };
        let streams: Vec<(usize, TcpStream)> = {
            let mut g = lock(&shared.streams);
            g.drain(..).collect()
        };
        for (_, s) in streams {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }

    /// Stop the accept loop, close every connection and join the
    /// handler threads (idempotent; shared between shutdown and drop).
    fn stop_threads(&mut self) {
        let Some(shared) = self.shared.as_ref() else { return };
        shared.closing.store(true, Ordering::SeqCst);
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let streams: Vec<(usize, TcpStream)> = {
            let mut g = lock(&shared.streams);
            g.drain(..).collect()
        };
        for (_, s) in streams {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        let handles: Vec<JoinHandle<()>> = {
            let mut g = lock(&shared.conn_handles);
            g.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }

    /// Stop accepting, close every connection, drain the wrapped
    /// service and return its final statistics. Idempotent like
    /// `Cluster::teardown`: a node already shut down reports default
    /// stats instead of panicking.
    pub fn shutdown(mut self) -> ServerStats {
        self.stop_threads();
        let Some(shared) = self.shared.take() else {
            return ServerStats::default();
        };
        // handler threads are joined, so ours is the last reference;
        // response forwarders never hold one
        match Arc::try_unwrap(shared) {
            Ok(sh) => {
                let stats = sh.svc.shutdown();
                // joins the forwarders: every queued reply job resolves
                // (the drained service answered every channel) and its
                // write fails fast on the closed sockets
                drop(sh.pool);
                stats
            }
            Err(_) => {
                warn_log!("node: a connection handler outlived shutdown; \
                           stats unavailable");
                ServerStats::default()
            }
        }
    }
}

impl Drop for NodeServer {
    /// A node dropped without `shutdown` still stops its threads (the
    /// wrapped service drains via its own drop).
    fn drop(&mut self) {
        self.stop_threads();
    }
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn accept_loop(shared: Arc<NodeShared>, listener: TcpListener) {
    let mut next_conn = 0usize;
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                if shared.closing.load(Ordering::SeqCst) {
                    break; // the shutdown poke (or a raced client)
                }
                let _ = stream.set_nodelay(true);
                let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
                let conn_id = next_conn;
                next_conn += 1;
                if let Ok(clone) = stream.try_clone() {
                    lock(&shared.streams).push((conn_id, clone));
                }
                let conn_shared = Arc::clone(&shared);
                let name = format!("tqdit-net-conn-{conn_id}");
                match std::thread::Builder::new().name(name).spawn(
                    move || handle_conn(conn_shared, conn_id, stream,
                                        peer.to_string()),
                ) {
                    Ok(h) => {
                        let mut g = lock(&shared.conn_handles);
                        // reap handles of handlers that already
                        // returned (dropping a finished handle just
                        // detaches it) so a long-lived node doesn't
                        // grow a handle per connection it ever served
                        g.retain(|h| !h.is_finished());
                        g.push(h);
                    }
                    Err(e) => {
                        warn_log!("node: spawning handler for {peer} \
                                   failed: {e}");
                    }
                }
            }
            Err(e) => {
                if shared.closing.load(Ordering::SeqCst) {
                    break;
                }
                warn_log!("node: accept failed: {e}");
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
    }
}

/// One connection's write half, driven through the layer-wide
/// [`send_message`](crate::serve::net::send_message) two-lock
/// discipline: the inline pong path never waits behind more than one
/// chunk of a large response.
struct ConnWriter {
    stream: Mutex<Option<TcpStream>>,
    bulk: Mutex<()>,
}

impl ConnWriter {
    /// Force-close the underlying socket (poisoned framing, conn exit).
    fn close(&self) {
        if let Some(s) = lock(&self.stream).take() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// Write one message under the connection's writer locks.
fn send(writer: &ConnWriter, msg: &Msg) -> Result<(), WireError> {
    crate::serve::net::send_message(&writer.stream, &writer.bulk,
                                    &msg.encode())
}

/// One client connection: read frames, feed the service, answer
/// heartbeats/stats inline, hand responses to the forwarder pool.
/// On exit the socket is shut down explicitly (stream clones held by
/// in-flight forwarders or the registry would otherwise keep the
/// connection half-open) and the registry entry removed.
fn handle_conn(shared: Arc<NodeShared>, conn_id: usize,
               stream: TcpStream, peer: String) {
    let mut reader = match stream.try_clone() {
        Ok(r) => r,
        Err(e) => {
            warn_log!("node: cloning stream for {peer} failed: {e}");
            return;
        }
    };
    let writer = Arc::new(ConnWriter {
        stream: Mutex::new(Some(stream)),
        bulk: Mutex::new(()),
    });
    conn_loop(&shared, &writer, &mut reader, &peer);
    writer.close();
    lock(&shared.streams).retain(|(id, _)| *id != conn_id);
}

fn conn_loop(shared: &Arc<NodeShared>, writer: &Arc<ConnWriter>,
             reader: &mut TcpStream, peer: &str) {
    // untagged connections are data connections (raw clients,
    // pre-handshake frontends); a Hello can promote to control
    let mut role = Role::Data;
    let mut messages = MessageReader::new();
    loop {
        let payload = match messages.read(reader) {
            Ok(p) => p,
            Err(WireError::Closed) => break,
            Err(e) => {
                if !shared.closing.load(Ordering::SeqCst) {
                    warn_log!("node: {peer}: closing connection: {e}");
                }
                break;
            }
        };
        // a bad *message* in a good frame degrades that message only:
        // framing is intact, so later frames on this connection are
        // still trustworthy
        let msg = match Msg::decode(&payload) {
            Ok(m) => m,
            Err(e) => {
                warn_log!("node: {peer}: skipping bad message: {e:#}");
                continue;
            }
        };
        match msg {
            Msg::Hello { role: tagged } => {
                debug_log!("node: {peer}: connection tagged {}",
                           tagged.name());
                role = tagged;
            }
            Msg::Submit { id, .. } if role == Role::Control => {
                // control connections carry liveness only; shipping a
                // response over one would re-create the pong-behind-
                // frame wedge the split exists to prevent
                warn_log!("node: {peer}: submit on a control \
                           connection rejected");
                let err = ServeError::Protocol {
                    cause: "submit on a control connection".into(),
                };
                if send(writer, &Msg::ErrorResp { id, err }).is_err() {
                    break;
                }
            }
            Msg::Submit { id, class, n } => {
                match shared.svc.submit(GenRequest { class, n }) {
                    Ok((_, rx)) => {
                        let w = Arc::clone(writer);
                        // the job blocks on this one request's channel;
                        // a pool worker is busy for exactly as long as
                        // the request is in flight
                        shared.pool.execute(move || {
                            let reply = match rx.recv() {
                                Ok(Ok(resp)) => Msg::Response {
                                    id,
                                    latency_s: resp.latency_s,
                                    images: resp.images,
                                },
                                Ok(Err(err)) => Msg::ErrorResp { id, err },
                                Err(_) => Msg::ErrorResp {
                                    id,
                                    err: ServeError::Protocol {
                                        cause: "response channel closed \
                                                without a result"
                                            .into(),
                                    },
                                },
                            };
                            if let Err(e) = send(&w, &reply) {
                                debug_log!("node: reply for request {id} \
                                            dropped: {e}");
                                // a failed (possibly partial) frame or
                                // chunk-run write poisons the stream
                                // framing — close so the peer
                                // re-routes instead of reading garbage
                                w.close();
                            }
                        });
                    }
                    Err(err) => {
                        // a rejected submit (backpressure, shutdown)
                        // answers immediately with the typed cause
                        if send(writer, &Msg::ErrorResp { id, err })
                            .is_err()
                        {
                            break;
                        }
                    }
                }
            }
            Msg::Ping { seq } => {
                let pong = Msg::Pong {
                    seq,
                    queue_depth: shared.svc.queue_depth(),
                    live_workers: shared.svc.live_workers(),
                    ready_workers: shared.svc.ready_workers(),
                };
                if send(writer, &pong).is_err() {
                    break;
                }
            }
            Msg::StatsReq { seq } => {
                let stats = shared.svc.stats();
                if send(writer, &Msg::Stats { seq, stats }).is_err() {
                    break;
                }
            }
            other => {
                // node-bound traffic only; a frontend-bound message
                // arriving here is a peer bug, not a reason to die
                warn_log!("node: {peer}: skipping unexpected {} message",
                          other.kind());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::net::testutil::{mock_node, read_msg, send_msg};
    use crate::serve::net::wire::{read_frame, write_frame, CHUNK_LEN};
    use std::time::Duration;

    /// Read frames until `pred` matches (heartbeat replies may
    /// interleave with responses on a live connection).
    fn read_until<F: Fn(&Msg) -> bool>(stream: &mut TcpStream, pred: F)
                                       -> Msg {
        loop {
            let msg = read_msg(stream);
            if pred(&msg) {
                return msg;
            }
        }
    }

    #[test]
    fn node_serves_submit_ping_stats_over_one_socket() {
        let (node, addr) = mock_node(vec![4], 3, Duration::ZERO);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

        send_msg(&mut c, &Msg::Submit { id: 42, class: 5, n: 2 });
        send_msg(&mut c, &Msg::Ping { seq: 9 });

        // ping answered inline; the response forwarded when computed —
        // order between them is not part of the contract
        let pong = read_until(&mut c, |m| matches!(m, Msg::Pong { .. }));
        match pong {
            Msg::Pong { seq: 9, .. } => {}
            other => panic!("wrong pong: {other:?}"),
        }
        let resp =
            read_until(&mut c, |m| matches!(m, Msg::Response { .. }));
        match resp {
            Msg::Response { id: 42, images, .. } => {
                assert_eq!(images.len(), 2 * 3);
                assert!(images.iter().all(|&p| p == 5.0));
            }
            other => panic!("wrong response: {other:?}"),
        }

        send_msg(&mut c, &Msg::StatsReq { seq: 1 });
        let stats = read_until(&mut c, |m| matches!(m, Msg::Stats { .. }));
        match stats {
            Msg::Stats { seq: 1, stats } => {
                assert_eq!(stats.requests, 1);
                assert_eq!(stats.enqueued,
                           stats.dispatched + stats.purged + stats.pending);
            }
            other => panic!("wrong stats: {other:?}"),
        }

        let final_stats = node.shutdown();
        assert_eq!(final_stats.requests, 1);
        assert_eq!(final_stats.images, 2);
    }

    #[test]
    fn concurrent_connections_share_one_service() {
        let (node, addr) = mock_node(vec![8], 2, Duration::ZERO);
        std::thread::scope(|s| {
            for client in 0..3i32 {
                s.spawn(move || {
                    let mut c = TcpStream::connect(addr).unwrap();
                    c.set_read_timeout(Some(Duration::from_secs(10)))
                        .unwrap();
                    for i in 0..4u64 {
                        let class = client + 1;
                        send_msg(&mut c, &Msg::Submit {
                            id: i,
                            class,
                            n: 3,
                        });
                        match read_until(&mut c,
                                         |m| matches!(m,
                                                      Msg::Response { .. }
                                                      | Msg::ErrorResp {
                                                          ..
                                                      })) {
                            Msg::Response { id, images, .. } => {
                                assert_eq!(id, i);
                                assert!(
                                    images.iter().all(|&p| p
                                                      == class as f32),
                                    "cross-connection pixel mixup"
                                );
                            }
                            other => panic!("request failed: {other:?}"),
                        }
                    }
                });
            }
        });
        let stats = node.shutdown();
        assert_eq!(stats.requests, 12);
        assert_eq!(stats.images, 36);
        assert_eq!(stats.failed_requests, 0);
    }

    #[test]
    fn bad_message_in_good_frame_is_skipped_connection_lives() {
        let (node, addr) = mock_node(vec![2], 2, Duration::ZERO);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // valid frame, garbage JSON — the node must skip it
        write_frame(&mut c, b"{ not json").unwrap();
        // and a well-formed submit on the same connection still works
        send_msg(&mut c, &Msg::Submit { id: 1, class: 3, n: 1 });
        match read_until(&mut c, |m| matches!(m, Msg::Response { .. })) {
            Msg::Response { id: 1, images, .. } => {
                assert_eq!(images, vec![3.0, 3.0]);
            }
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn wire_garbage_closes_only_that_connection() {
        let (node, addr) = mock_node(vec![2], 2, Duration::ZERO);
        {
            use std::io::Write;
            let mut bad = TcpStream::connect(addr).unwrap();
            bad.write_all(b"XXXXXXXX not a frame XXXXXXXX").unwrap();
            bad.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            // the node closes the broken connection
            match read_frame(&mut bad) {
                Err(WireError::Closed) | Err(WireError::Io(_))
                | Err(WireError::Truncated { .. }) => {}
                other => panic!("expected a closed stream, got {other:?}"),
            }
        }
        // a fresh connection is unaffected
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c, &Msg::Submit { id: 2, class: 1, n: 1 });
        match read_until(&mut c, |m| matches!(m, Msg::Response { .. })) {
            Msg::Response { id: 2, .. } => {}
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn rejected_submit_relays_the_typed_cause() {
        // queue cap 4: a 5-slot request can never fit
        let (node, addr) =
            crate::serve::net::testutil::mock_node_capped(
                vec![2], 2, Duration::ZERO, 4);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c, &Msg::Submit { id: 7, class: 1, n: 5 });
        match read_until(&mut c, |m| matches!(m, Msg::ErrorResp { .. })) {
            Msg::ErrorResp {
                id: 7,
                err: ServeError::RequestTooLarge { n: 5, cap: 4 },
            } => {}
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn control_connection_serves_liveness_and_rejects_submits() {
        let (node, addr) = mock_node(vec![4], 3, Duration::ZERO);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c, &Msg::Hello { role: Role::Control });
        // liveness + stats flow normally
        send_msg(&mut c, &Msg::Ping { seq: 5 });
        match read_until(&mut c, |m| matches!(m, Msg::Pong { .. })) {
            Msg::Pong { seq: 5, .. } => {}
            other => panic!("wrong pong: {other:?}"),
        }
        send_msg(&mut c, &Msg::StatsReq { seq: 1 });
        read_until(&mut c, |m| matches!(m, Msg::Stats { .. }));
        // but a submit is a peer bug: rejected typed, connection lives
        send_msg(&mut c, &Msg::Submit { id: 9, class: 1, n: 1 });
        match read_until(&mut c, |m| matches!(m, Msg::ErrorResp { .. })) {
            Msg::ErrorResp { id: 9, err: ServeError::Protocol { .. } } => {}
            other => panic!("{other:?}"),
        }
        send_msg(&mut c, &Msg::Ping { seq: 6 });
        read_until(&mut c, |m| matches!(m, Msg::Pong { seq: 6, .. }));
        let stats = node.shutdown();
        assert_eq!(stats.requests, 0, "control traffic reached the \
                                       service");
    }

    #[test]
    fn large_response_travels_chunked_and_reassembles() {
        // ~400 KiB of response JSON (> CHUNK_LEN) exercises the
        // chunked write path + reader-side reassembly end to end
        let il = 100_000usize;
        let (node, addr) = mock_node(vec![2], il, Duration::ZERO);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        send_msg(&mut c, &Msg::Submit { id: 3, class: 7, n: 2 });
        match read_until(&mut c, |m| matches!(m, Msg::Response { .. })) {
            Msg::Response { id: 3, images, .. } => {
                assert_eq!(images.len(), 2 * il);
                assert!(images.iter().all(|&p| p == 7.0));
                // the point of the fixture: this really was chunked
                assert!(images.len() * 2 > CHUNK_LEN,
                        "fixture no longer exceeds one chunk");
            }
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }

    #[test]
    fn severed_connection_leaves_the_service_running() {
        let (node, addr) = mock_node(vec![2], 2, Duration::ZERO);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c, &Msg::Ping { seq: 1 });
        read_until(&mut c, |m| matches!(m, Msg::Pong { .. }));
        node.sever_connections();
        // our side observes the close
        match read_frame(&mut c) {
            Err(_) => {}
            Ok(_) => panic!("severed connection still delivered"),
        }
        // the node accepts and serves new connections afterwards
        let mut c2 = TcpStream::connect(addr).unwrap();
        c2.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_msg(&mut c2, &Msg::Submit { id: 1, class: 2, n: 1 });
        match read_until(&mut c2, |m| matches!(m, Msg::Response { .. })) {
            Msg::Response { id: 1, images, .. } => {
                assert_eq!(images, vec![2.0, 2.0]);
            }
            other => panic!("{other:?}"),
        }
        node.shutdown();
    }
}
